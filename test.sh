#!/usr/bin/env bash
# Tier-1 test entry point.
#
# 8 simulated host devices so the sharding / context-parallel tests see a
# mesh (the dry-run subprocesses override XLA_FLAGS themselves); repo code
# imports as `repro` via PYTHONPATH=src.
set -euo pipefail
cd "$(dirname "$0")"

export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -x -q "$@"

from .step import (loss_fn, chunked_ce_loss, make_train_step, make_compressed_grads, init_dp_error_state)

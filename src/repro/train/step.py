"""Training step builders: loss, grads, optimizer, microbatch accumulation,
and the compressed-DP variant (gradient compression + error feedback).

The loss computes logits in sequence chunks so the [B, S, vocab] tensor
(53 GB for llama4-scout at train_4k) never materializes — each chunk is
vocab-sharded over the model axis and reduced immediately.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.optim import (OptConfig, adamw_step, init_opt_state,
                         compress_and_reduce)
from repro.distributed.sharding import shard_map


def chunked_ce_loss(params, hidden: jax.Array, labels: jax.Array,
                    mask: jax.Array, cfg, ctx, chunk: int = 1024
                    ) -> jax.Array:
    """hidden [B, S, d] -> scalar mean CE.  Never materializes [B,S,V]."""
    b, s, d = hidden.shape
    if s % chunk != 0:
        chunk = s
    n = s // chunk
    h_c = hidden.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    l_c = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    m_c = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, lab, m = xs
        logits = lm.logits_fn(params, h, cfg, ctx)         # [B, c, V] f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None],
                                   axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * m)
        return (carry[0] + loss, carry[1] + jnp.sum(m)), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                             (h_c, l_c, m_c))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch: Dict[str, jax.Array], cfg, ctx,
            attn_impl: str = None) -> jax.Array:
    attn_impl = attn_impl or getattr(cfg, "attn_impl", "masked")
    hidden = lm.forward_train(params, batch, cfg, ctx, attn_impl=attn_impl)
    labels, mask = batch["labels"], batch["mask"]
    if cfg.frontend and "frontend_embeds" in batch:
        # loss only over text positions (frontend prefix is input-only)
        hidden = hidden[:, batch["frontend_embeds"].shape[1]:]
    return chunked_ce_loss(params, hidden, labels, mask, cfg, ctx)


def make_train_step(cfg, ctx, optc: OptConfig,
                    microbatch: Optional[int] = None,
                    attn_impl: str = None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, ctx, attn_impl))(params)

    def step(params, opt_state, batch):
        if microbatch is None:
            loss, grads = grads_of(params, batch)
        else:
            b = batch["tokens"].shape[0]
            n = b // microbatch
            mb = jax.tree_util.tree_map(
                lambda a: a.reshape(n, microbatch, *a.shape[1:]), batch)

            def acc_body(carry, xs):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, xs)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32) / n, g_acc, g)
                return (loss_acc + loss / n, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = lax.scan(acc_body, (jnp.zeros(()), g0), mb)
        params, opt_state, mets = adamw_step(grads, opt_state, optc,
                                             params_like=params)
        return params, opt_state, {"loss": loss, **mets}

    return step


# ---------------------------------------------------------------------------
# compressed-DP train step (gradient compression + error feedback)
# ---------------------------------------------------------------------------

def make_compressed_grads(cfg, ctx, scheme: str = "bf16",
                          attn_impl: str = "masked") -> Callable:
    """(params, err_state, batch) -> (loss, grads, new_err).

    Runs loss+backward per DP shard inside shard_map (manual over the DP
    axes, auto over model) and reduces compressed gradients explicitly —
    the DCN-crossing reduce operand in the HLO is bf16/int8, not fp32.
    Requires cfg.fsdp == False (params replicated across DP).
    """
    if cfg.fsdp:
        raise ValueError("compressed-DP requires DP-replicated params")
    mesh = ctx.mesh
    dp = ctx.rules.get("batch")
    dp = tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
    # manual over the DP axes; size-1 axes included so CPU test meshes run
    # full-manual (XLA CPU miscompiles partial-auto shard_map; on TPU the
    # model axis stays auto and composes with TP).
    manual = set(dp) | {a for a in mesh.axis_names if mesh.shape[a] == 1}
    # inside the manual region, sharding constraints must not mention
    # manual axes: strip them from the model-visible rules
    from repro.distributed.sharding import ShardCtx as _Ctx

    def _strip(v):
        axes = tuple(a for a in (v if isinstance(v, (tuple, list)) else (v,))
                     if a is not None and a not in manual)
        return axes if len(axes) > 1 else (axes[0] if axes else None)

    inner_rules = {k: _strip(v) for k, v in ctx.rules.items()}
    inner_ctx = _Ctx(None, {}) if all(v is None for v in
                                      inner_rules.values()) \
        else _Ctx(mesh, inner_rules)

    def body(params, err_local, batch_local):
        err = jax.tree_util.tree_map(lambda e: e[0], err_local)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch_local, cfg, inner_ctx,
                              attn_impl))(params)
        g_hat, new_err = compress_and_reduce(grads, err, dp, scheme)
        loss = jax.lax.pmean(loss, dp)
        new_err = jax.tree_util.tree_map(lambda e: e[None], new_err)
        return loss, g_hat, new_err

    rep = lambda tree: jax.tree_util.tree_map(lambda _: P(), tree)

    def fn(params, err_state, batch):
        return shard_map(
            body, mesh=mesh,
            in_specs=(rep(params),
                      jax.tree_util.tree_map(lambda _: P(dp), err_state),
                      jax.tree_util.tree_map(lambda _: P(dp), batch)),
            out_specs=(P(), rep(params),
                       jax.tree_util.tree_map(lambda _: P(dp), err_state)),
            axis_names=manual, check_vma=False,
        )(params, err_state, batch)

    return fn


def init_dp_error_state(params, dp_size: int):
    """Per-DP-shard error-feedback buffers: leading dp dim, sharded over DP."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((dp_size,) + p.shape, jnp.float32), params)

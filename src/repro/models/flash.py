"""Memory-bounded blocked attention in pure JAX (train/prefill path).

Flash-attention structure (online softmax over KV blocks) expressed with
``lax.map`` over query blocks + ``lax.scan`` over KV blocks, so peak memory
is one (bq x bkv) score panel per (B, H) instead of the full S^2 matrix.

Two schedules:

* ``masked``     — every (i, j) block pair is computed and causally masked.
  Simple, but does ~2x the causal-optimal FLOPs (the upper triangle is
  computed then thrown away).  This is the baseline the §Perf hillclimb
  starts from.
* ``triangular`` — only the ~nq(nq+1)/2 lower-triangle block pairs are
  enumerated (a static pair list driving dynamic slices), recovering the
  causal-optimal FLOP count at the cost of a scatter per step.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = jnp.float32(-1e30)


def _attn_block(qi, kj, vj, s_mask, m, l, acc, sm_scale):
    """One online-softmax update.  qi:(...,bq,D) kj:(...,bkv,D)."""
    s = jnp.einsum("...qd,...kd->...qk", qi.astype(jnp.float32),
                   kj.astype(jnp.float32)) * sm_scale
    if s_mask is not None:
        s = jnp.where(s_mask, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    if s_mask is not None:
        p = jnp.where(s_mask, p, 0.0)
    l_new = l * alpha + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "...qk,...kd->...qd", p, vj.astype(jnp.float32))
    return m_new, l_new, acc_new


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      sm_scale: float, causal: bool = True,
                      bq: int = 512, bkv: int = 512,
                      impl: str = "masked") -> jax.Array:
    """q [B,H,S,D], k/v [B,H,Skv,D] -> [B,H,S,D].  Requires S%bq==Skv%bkv==0."""
    b, h, s, d = q.shape
    skv = k.shape[2]
    bq = min(bq, s)
    bkv = min(bkv, skv)
    if s % bq != 0 or skv % bkv != 0:
        raise ValueError(f"seq {s}/{skv} not multiples of blocks {bq}/{bkv}")
    nq, nk = s // bq, skv // bkv
    if impl == "triangular" and causal:
        return _triangular(q, k, v, sm_scale, bq, bkv)

    qb = q.reshape(b, h, nq, bq, d).transpose(2, 0, 1, 3, 4)

    def per_q(args):
        i, qi = args

        def kv_step(carry, j):
            m, l, acc = carry
            kj = lax.dynamic_slice_in_dim(k, j * bkv, bkv, axis=2)
            vj = lax.dynamic_slice_in_dim(v, j * bkv, bkv, axis=2)
            mask = None
            if causal:
                qpos = i * bq + jnp.arange(bq)
                kpos = j * bkv + jnp.arange(bkv)
                mask = (qpos[:, None] >= kpos[None, :])[None, None]
            m, l, acc = _attn_block(qi, kj, vj, mask, m, l, acc, sm_scale)
            return (m, l, acc), None

        init = (jnp.full((b, h, bq), NEG_INF),
                jnp.zeros((b, h, bq), jnp.float32),
                jnp.zeros((b, h, bq, d), jnp.float32))
        (m, l, acc), _ = lax.scan(kv_step, init, jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = lax.map(per_q, (jnp.arange(nq), qb))      # (nq, B, H, bq, D)
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, h, s, d)
    return out.astype(q.dtype)


def _triangular(q, k, v, sm_scale, bq, bkv):
    """Causal-optimal schedule: static (i, j<=i) pair list, one scan."""
    b, h, s, d = q.shape
    nq, nk = s // bq, k.shape[2] // bkv
    ratio = bq // bkv if bq >= bkv else 1
    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if j * bkv < (i + 1) * bq]
    pi = jnp.array([p[0] for p in pairs], jnp.int32)
    pj = jnp.array([p[1] for p in pairs], jnp.int32)

    def step(carry, idx):
        m, l, acc = carry                       # (B,H,S), (B,H,S), (B,H,S,D)
        i, j = pi[idx], pj[idx]
        qi = lax.dynamic_slice_in_dim(q, i * bq, bq, axis=2)
        kj = lax.dynamic_slice_in_dim(k, j * bkv, bkv, axis=2)
        vj = lax.dynamic_slice_in_dim(v, j * bkv, bkv, axis=2)
        qpos = i * bq + jnp.arange(bq)
        kpos = j * bkv + jnp.arange(bkv)
        mask = (qpos[:, None] >= kpos[None, :])[None, None]
        mi = lax.dynamic_slice_in_dim(m, i * bq, bq, axis=2)
        li = lax.dynamic_slice_in_dim(l, i * bq, bq, axis=2)
        ai = lax.dynamic_slice_in_dim(acc, i * bq, bq, axis=2)
        mi, li, ai = _attn_block(qi, kj, vj, mask, mi, li, ai, sm_scale)
        m = lax.dynamic_update_slice_in_dim(m, mi, i * bq, axis=2)
        l = lax.dynamic_update_slice_in_dim(l, li, i * bq, axis=2)
        acc = lax.dynamic_update_slice_in_dim(acc, ai, i * bq, axis=2)
        return (m, l, acc), None

    init = (jnp.full((b, h, s), NEG_INF),
            jnp.zeros((b, h, s), jnp.float32),
            jnp.zeros((b, h, s, d), jnp.float32))
    (m, l, acc), _ = lax.scan(step, init, jnp.arange(len(pairs)))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def full_attention(q, k, v, sm_scale, causal=True,
                   kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Unblocked reference (small S / decode)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        sq, skv = q.shape[2], k.shape[2]
        mask = (jnp.arange(sq)[:, None] + (skv - sq)) >= jnp.arange(skv)[None]
        s = jnp.where(mask[None, None], s, NEG_INF)
    if kv_valid is not None:
        s = jnp.where(kv_valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

"""Mixture-of-Experts layer: top-k routing, capacity-based, sort-free dispatch.

Dispatch is memory-sane (no (T, E, C) one-hot einsum): per top-k slot, each
token's position in its expert queue comes from an exclusive cumsum over the
(T, E) one-hot, tokens are gathered into an (E, C, d) buffer, experts run as
a stacked einsum, and results scatter-add back with the routing weights.

Distribution (DESIGN.md §6): the dispatch math runs *per data shard* inside
``shard_map`` — tokens never cross the data axis (baseline; expert-parallel
all-to-all is the §Perf variant).  Expert FFNs are tensor-parallel on the ffn
dim with a single reduce(-scatter) per layer, Megatron-SP style when the
residual stream is sequence-sharded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.sparse_format import BlockSparseWeight, unpack
from repro.kernels import ops
from .module import ParamSpec
from .layers import mlp_specs, mlp_apply
from repro.distributed.sharding import shard_map


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.pdtype
    specs = {
        "router": ParamSpec((d, e), jnp.float32, ("embed", None)),
        "w_gate": ParamSpec((e, d, f), dt, ("experts", "embed", "ffn")),
        "w_up": ParamSpec((e, d, f), dt, ("experts", "embed", "ffn")),
        "w_down": ParamSpec((e, f, d), dt, ("experts", "ffn", "embed")),
    }
    if cfg.shared_expert:
        specs["shared"] = mlp_specs(cfg)
    return specs


def _expert_w(w, e: int):
    """Dense (E, K, N) view of a (possibly sparse) expert weight."""
    if isinstance(w, BlockSparseWeight):
        dense = unpack(w)                       # (E*K, N) — XLA fallback
        return dense.reshape(e, dense.shape[0] // e, dense.shape[1])
    return w


def _capacity(t: int, k: int, e: int, cf: float) -> int:
    c = int(-(-t * k * cf // e))
    return max(-(-c // 8) * 8, 8)


def moe_local(p, x: jax.Array, cfg, tp_axis: Optional[str] = None
              ) -> jax.Array:
    """Token dispatch + expert FFN on local tokens x [T, d].

    If ``tp_axis`` is set, w_gate/w_up/w_down arrive ffn-sliced and the
    partial down-projection output is NOT reduced here (caller reduces).
    """
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(t, k, e, cfg.capacity_factor)

    logits = jnp.dot(x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                    # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    wg = _expert_w(p["w_gate"], e)
    wu = _expert_w(p["w_up"], e)
    wd = _expert_w(p["w_down"], e)

    # static one-row sentinel pad, not a growing buffer
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)  # jitlint: disable=hot-path-op
    out = jnp.zeros((t + 1, d), jnp.float32)
    for slot in range(k):
        eid = top_i[:, slot]                                  # (T,)
        oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)          # (T, E)
        pos_all = jnp.cumsum(oh, axis=0) - oh
        pos = jnp.take_along_axis(pos_all, eid[:, None], axis=1)[:, 0]
        keep = pos < c
        buf = jnp.full((e, c), t, jnp.int32)
        buf = buf.at[eid, jnp.where(keep, pos, c)].set(
            jnp.arange(t, dtype=jnp.int32), mode="drop")      # (E, C)
        xg = x_pad[buf]                                       # (E, C, d)
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wg))
             * jnp.einsum("ecd,edf->ecf", xg, wu)).astype(x.dtype)
        o = jnp.einsum("ecf,efd->ecd", h, wd)                 # (E, C, d)
        wcomb = jnp.concatenate(  # jitlint: disable=hot-path-op
            [top_p[:, slot], jnp.zeros((1,), jnp.float32)])[buf]
        out = out.at[buf.reshape(-1)].add(
            (o * wcomb[..., None]).reshape(-1, d), mode="drop")
    return out[:t].astype(x.dtype)


def moe_apply(p, x: jax.Array, cfg, ctx) -> jax.Array:
    """x [B, S, d] -> [B, S, d].  shard_map'd dispatch when a mesh is live."""
    b, s, d = x.shape
    if ctx is None or ctx.mesh is None:
        out = moe_local(p, x.reshape(-1, d), cfg).reshape(b, s, d)
        if cfg.shared_expert:
            out = out + mlp_apply(p["shared"], x)
        return out
    if getattr(cfg, "ep_moe", False):
        out = moe_apply_ep(p, x, cfg, ctx)
        if out is not None:
            return out

    mesh = ctx.mesh
    dp = ctx.rules.get("batch")
    dp = tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
    dp = tuple(a for a in dp if a is not None)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if dp_size == 1 or b % dp_size != 0:
        dp = ()   # e.g. batch=1 long-context decode: replicate dispatch
    tp = ctx.rules.get("ffn")
    tp_size = mesh.shape[tp] if tp else 1
    seq_sharded = (cfg.seq_shard and tp and s % tp_size == 0 and s > 1)

    x_spec = P(dp if dp else None, tp if seq_sharded else None, None)
    w_col = P(None, None, tp)       # (E, d, f_local)
    w_row = P(None, tp, None)       # (E, f_local, d)
    p_specs = {"router": P(None, None), "w_gate": w_col, "w_up": w_col,
               "w_down": w_row}
    if cfg.shared_expert:
        p_specs["shared"] = {"w_gate": P(None, tp), "w_up": P(None, tp),
                             "w_down": P(tp, None)}
    moe_p = {k: p[k] for k in p_specs}

    def body(pl, xl):
        # xl: (B_local, S or S/tp, d)
        bl = xl.shape[0]
        if seq_sharded:
            xl = jax.lax.all_gather(xl, tp, axis=1, tiled=True)
        tok = xl.reshape(-1, d)
        out = moe_local(pl, tok, cfg, tp_axis=tp)
        if cfg.shared_expert:
            h = (jax.nn.silu(ops.linear(tok, pl["shared"]["w_gate"]))
                 * ops.linear(tok, pl["shared"]["w_up"]))
            out = out + ops.linear(h, pl["shared"]["w_down"])
        out = out.reshape(bl, -1, d)
        if tp:
            if seq_sharded:
                out = jax.lax.psum_scatter(out, tp, scatter_dimension=1,
                                           tiled=True)
            else:
                out = jax.lax.psum(out, tp)
        return out

    fn = shard_map(body, mesh=mesh, in_specs=(p_specs, x_spec),
                       out_specs=x_spec, check_vma=False)
    return fn(moe_p, x)


# ---------------------------------------------------------------------------
# expert-parallel variant (§Perf: kills the FSDP expert-weight all-gathers)
# ---------------------------------------------------------------------------

def moe_apply_ep(p, x: jax.Array, cfg, ctx):
    """Experts sharded over the DP axes (E/ep per group), ffn over TP.

    Weights stay resident (no per-step gathers).  Tokens are all-gathered
    over DP inside the region (activations << expert weights), each group
    computes only its local experts' contributions, and one
    psum(+scatter) over (dp, tp) combines.  Returns None when E doesn't
    divide the DP degree (caller falls back to the TP path)."""
    mesh = ctx.mesh
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    dp = ctx.rules.get("batch")
    dp = tuple(a for a in (dp if isinstance(dp, (tuple, list)) else (dp,))
               if a is not None)
    ep_size = 1
    for a in dp:
        ep_size *= mesh.shape[a]
    if ep_size <= 1 or e % ep_size != 0:
        return None
    e_loc = e // ep_size
    tp = ctx.rules.get("ffn")
    b_sharded = b % ep_size == 0
    x_spec = P(dp if b_sharded else None, None, None)
    w_col = P(dp, None, tp)      # (E_loc, d, f_loc)
    w_row = P(dp, tp, None)
    p_specs = {"router": P(None, None), "w_gate": w_col, "w_up": w_col,
               "w_down": w_row}
    if cfg.shared_expert:
        p_specs["shared"] = {"w_gate": P(None, tp), "w_up": P(None, tp),
                             "w_down": P(tp, None)}
    moe_p = {key: p[key] for key in p_specs}

    def body(pl, xl):
        bl = xl.shape[0]
        if b_sharded:
            xl = jax.lax.all_gather(xl, dp, axis=0, tiled=True)
        tok = xl.reshape(-1, d)
        t = tok.shape[0]
        c = _capacity(t, k, e, cfg.capacity_factor)
        idx = 0
        for a in dp:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        e0 = idx * e_loc

        logits = jnp.dot(tok.astype(jnp.float32), pl["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        wg = _expert_w(pl["w_gate"], e_loc)
        wu = _expert_w(pl["w_up"], e_loc)
        wd = _expert_w(pl["w_down"], e_loc)
        x_pad = jnp.concatenate([tok, jnp.zeros((1, d), tok.dtype)], axis=0)  # jitlint: disable=hot-path-op
        out = jnp.zeros((t + 1, d), jnp.float32)
        for slot in range(k):
            eid = top_i[:, slot]
            mine = (eid >= e0) & (eid < e0 + e_loc)
            le = jnp.where(mine, eid - e0, e_loc)          # E_loc = drop
            oh = jax.nn.one_hot(jnp.where(mine, le, e_loc), e_loc + 1,
                                dtype=jnp.int32)[:, :e_loc]
            pos_all = jnp.cumsum(oh, axis=0) - oh
            pos = jnp.take_along_axis(
                pos_all, jnp.minimum(le, e_loc - 1)[:, None], axis=1)[:, 0]
            keep = mine & (pos < c)
            buf = jnp.full((e_loc, c), t, jnp.int32)
            buf = buf.at[jnp.where(mine, le, e_loc),
                         jnp.where(keep, pos, c)].set(
                jnp.arange(t, dtype=jnp.int32), mode="drop")
            xg = x_pad[buf]
            h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wg))
                 * jnp.einsum("ecd,edf->ecf", xg, wu)).astype(tok.dtype)
            o = jnp.einsum("ecf,efd->ecd", h, wd)
            wcomb = jnp.concatenate(  # jitlint: disable=hot-path-op
                [top_p[:, slot], jnp.zeros((1,), jnp.float32)])[buf]
            out = out.at[buf.reshape(-1)].add(
                (o * wcomb[..., None]).reshape(-1, d), mode="drop")
        out = out[:t]
        if cfg.shared_expert:
            hsh = (jax.nn.silu(ops.linear(tok, pl["shared"]["w_gate"]))
                   * ops.linear(tok, pl["shared"]["w_up"]))
            sh = ops.linear(hsh, pl["shared"]["w_down"]).astype(jnp.float32)
            out = out + jnp.where(idx == 0, 1.0, 0.0) * sh
        out = out.reshape(-1, s, d)
        if tp:
            out = jax.lax.psum(out, tp)
        if b_sharded:
            out = jax.lax.psum_scatter(out, dp, scatter_dimension=0,
                                       tiled=True)
        else:
            out = jax.lax.psum(out, dp)
        return out.astype(x.dtype)

    fn = shard_map(body, mesh=mesh, in_specs=(p_specs, x_spec),
                       out_specs=x_spec, check_vma=False)
    return fn(moe_p, x)

"""Recurrent mixers: Mamba (Jamba's 7-of-8 layers) and RWKV-6 "Finch".

Both are expressed as chunked ``lax.scan`` over time with
``jax.checkpoint`` on the inner chunk, so the backward pass stores one
carry per ``cfg.scan_chunk`` steps instead of per step (this is what makes
train_4k fit; see EXPERIMENTS.md §Dry-run).  Decode is the single-step
recurrence — O(1) state, which is why these archs run the long_500k cell.

All projections route through ``ops.linear`` and are therefore
sparse-format capable (the paper's technique applies to every linear here;
for RWKV decode the model is *nothing but* these GEMVs — see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from .module import ParamSpec
from .layers import rms_norm


def _chunked_scan(step, carry, xs_t, chunk: int, remat: bool = True):
    """scan over leading time axis of xs_t in remat'd chunks."""
    t = jax.tree_util.tree_leaves(xs_t)[0].shape[0]
    if t % chunk != 0 or t <= chunk:
        return lax.scan(step, carry, xs_t)
    n = t // chunk
    xs_c = jax.tree_util.tree_map(
        lambda a: a.reshape(n, chunk, *a.shape[1:]), xs_t)

    def chunk_step(c, xs):
        return lax.scan(step, c, xs)

    if remat:
        chunk_step = jax.checkpoint(chunk_step,
                                    prevent_cse=False)
    carry, ys = lax.scan(chunk_step, carry, xs_c)
    ys = jax.tree_util.tree_map(
        lambda a: a.reshape(n * chunk, *a.shape[2:]), ys)
    return carry, ys


# ===========================================================================
# Mamba (selective SSM), as used by Jamba
# ===========================================================================

def mamba_specs(cfg) -> Dict[str, ParamSpec]:
    d, di, n, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    rank = max(d // 16, 8)
    dt = cfg.pdtype
    return {
        "w_in": ParamSpec((d, 2 * di), dt, ("embed", "ssm_inner")),
        "conv_w": ParamSpec((dc, di), jnp.float32, (None, "ssm_inner"),
                            init="small"),
        "conv_b": ParamSpec((di,), jnp.float32, ("ssm_inner",), init="zeros"),
        "w_bcdt": ParamSpec((di, rank + 2 * n), dt, ("ssm_inner", None)),
        "dt_w": ParamSpec((rank, di), jnp.float32, (None, "ssm_inner"),
                          init="small"),
        "dt_b": ParamSpec((di,), jnp.float32, ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((di, n), jnp.float32, ("ssm_inner", "state"),
                           init="zeros"),
        "d_skip": ParamSpec((di,), jnp.float32, ("ssm_inner",), init="ones"),
        "w_out": ParamSpec((di, d), dt, ("ssm_inner", "embed")),
    }


def _mamba_conv_train(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv over seq: x [B,S,di], w [dc,di]."""
    dc = w.shape[0]
    out = x * w[dc - 1]
    for i in range(1, dc):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[dc - 1 - i]
    return out + b


def _mamba_step(carry, xs, a, d_skip):
    """h' = dA h + dB x; y = C.h + D x.  Shapes: h [B,di,N]."""
    h = carry
    xc_t, dt_t, b_t, c_t = xs          # [B,di], [B,di], [B,N], [B,N]
    da = jnp.exp(dt_t[..., None] * a)                       # [B,di,N]
    db = dt_t[..., None] * b_t[:, None, :]                  # [B,di,N]
    h = da * h + db * xc_t[..., None]
    y = jnp.einsum("bdn,bn->bd", h, c_t) + d_skip * xc_t
    return h, y


def mamba_apply(p, x: jax.Array, cfg, ctx, return_state: bool = False):
    """Train/prefill path. x [B, S, d] -> [B, S, d]."""
    b, s, d = x.shape
    di, n = cfg.d_inner, cfg.d_state
    rank = p["dt_w"].shape[0]
    xz = ops.linear(x, p["w_in"])
    x_in, z = jnp.split(xz, 2, axis=-1)                      # [B,S,di]
    xc = jax.nn.silu(_mamba_conv_train(
        x_in.astype(jnp.float32), p["conv_w"], p["conv_b"]))
    bcdt = ops.linear(xc.astype(x.dtype), p["w_bcdt"]).astype(jnp.float32)
    dt_lo, b_ssm, c_ssm = jnp.split(bcdt, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt_lo, p["dt_w"])
                         + p["dt_b"])                        # [B,S,di]
    a = -jnp.exp(p["a_log"])                                 # [di,N]

    to_t = lambda v: jnp.moveaxis(v, 1, 0)                   # time-major
    xs_t = (to_t(xc), to_t(dt), to_t(b_ssm), to_t(c_ssm))
    h0 = jnp.zeros((b, di, n), jnp.float32)
    step = lambda c, xs: _mamba_step(c, xs, a, p["d_skip"])
    h_fin, ys = _chunked_scan(step, h0, xs_t, cfg.scan_chunk, cfg.remat)
    y = jnp.moveaxis(ys, 0, 1)                               # [B,S,di]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = ops.linear(y, p["w_out"])
    if return_state:
        dc = cfg.d_conv
        conv = x_in.astype(jnp.float32)[:, -(dc - 1):]
        return out, {"conv": conv, "ssm": h_fin}
    return out


def mamba_init_state(cfg, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), dtype),
    }


def mamba_decode(p, x_t: jax.Array, state, cfg) -> Tuple[jax.Array, Any]:
    """One-token step. x_t [B, d]."""
    b, d = x_t.shape
    di, n = cfg.d_inner, cfg.d_state
    rank = p["dt_w"].shape[0]
    xz = ops.linear(x_t, p["w_in"])
    x_in, z = jnp.split(xz, 2, axis=-1)                      # [B,di]
    # fixed-width conv window shift (static shapes; ssm is not pooled)
    window = jnp.concatenate(  # jitlint: disable=hot-path-op
        [state["conv"], x_in.astype(jnp.float32)[:, None]], axis=1)
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    bcdt = ops.linear(xc.astype(x_t.dtype), p["w_bcdt"]).astype(jnp.float32)
    dt_lo, b_ssm, c_ssm = jnp.split(bcdt, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(dt_lo @ p["dt_w"] + p["dt_b"])
    a = -jnp.exp(p["a_log"])
    h, y = _mamba_step(state["ssm"], (xc, dt, b_ssm, c_ssm), a, p["d_skip"])
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x_t.dtype)
    new_state = {"conv": window[:, 1:], "ssm": h}
    return ops.linear(y, p["w_out"]), new_state


# ===========================================================================
# RWKV-6 "Finch" (data-dependent decay)
# ===========================================================================

def rwkv_specs(cfg) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    dh = cfg.rwkv_head_dim
    h = d // dh
    dt = cfg.pdtype
    lora = 64 if d >= 1024 else 16
    return {
        # time-mix (attention analogue)
        "mu_r": ParamSpec((d,), jnp.float32, ("embed",), init="small"),
        "mu_k": ParamSpec((d,), jnp.float32, ("embed",), init="small"),
        "mu_v": ParamSpec((d,), jnp.float32, ("embed",), init="small"),
        "mu_w": ParamSpec((d,), jnp.float32, ("embed",), init="small"),
        "mu_g": ParamSpec((d,), jnp.float32, ("embed",), init="small"),
        "w_r": ParamSpec((d, d), dt, ("embed", "heads")),
        "w_k": ParamSpec((d, d), dt, ("embed", "heads")),
        "w_v": ParamSpec((d, d), dt, ("embed", "heads")),
        "w_g": ParamSpec((d, d), dt, ("embed", "heads")),
        "w_o": ParamSpec((d, d), dt, ("heads", "embed")),
        # data-dependent decay lora (the Finch hallmark)
        "decay_w0": ParamSpec((d,), jnp.float32, ("embed",), init="zeros"),
        "decay_a": ParamSpec((d, lora), jnp.float32, ("embed", None),
                             init="small"),
        "decay_b": ParamSpec((lora, d), jnp.float32, (None, "embed"),
                             init="small"),
        "bonus_u": ParamSpec((h, dh), jnp.float32, ("heads", None),
                             init="small"),
        "ln_x": ParamSpec((d,), jnp.float32, ("embed",), init="ones"),
        # channel-mix (FFN analogue)
        "mu_ck": ParamSpec((d,), jnp.float32, ("embed",), init="small"),
        "mu_cr": ParamSpec((d,), jnp.float32, ("embed",), init="small"),
        "w_ck": ParamSpec((d, f), dt, ("embed", "ffn")),
        "w_cv": ParamSpec((f, d), dt, ("ffn", "embed")),
        "w_cr": ParamSpec((d, d), dt, ("embed", "embed")),
    }


def _shift(x: jax.Array) -> jax.Array:
    """Token shift: previous timestep (zeros at t=0). x [B,S,d]."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def _rwkv_step(carry, xs, u):
    """WKV recurrence per head.  state [B,H,dh,dh] (i=key dim, j=val dim)."""
    state = carry
    r_t, k_t, v_t, w_t = xs      # [B,H,dh] each
    kv = k_t[..., :, None] * v_t[..., None, :]               # [B,H,dh,dh]
    y = jnp.einsum("bhi,bhij->bhj", r_t, u[..., :, None] * kv + state)
    state = w_t[..., :, None] * state + kv
    return state, y


def rwkv_time_mix(p, x: jax.Array, cfg, ctx, return_state: bool = False):
    b, s, d = x.shape
    dh = cfg.rwkv_head_dim
    h = d // dh
    xf = x.astype(jnp.float32)
    xs = _shift(xf)
    r = ops.linear(_lerp(xf, xs, p["mu_r"]).astype(x.dtype), p["w_r"])
    k = ops.linear(_lerp(xf, xs, p["mu_k"]).astype(x.dtype), p["w_k"])
    v = ops.linear(_lerp(xf, xs, p["mu_v"]).astype(x.dtype), p["w_v"])
    g = ops.linear(_lerp(xf, xs, p["mu_g"]).astype(x.dtype), p["w_g"])
    xw = _lerp(xf, xs, p["mu_w"])
    w = jnp.exp(-jnp.exp(
        p["decay_w0"] + jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]))

    heads = lambda t: t.astype(jnp.float32).reshape(b, s, h, dh)
    to_t = lambda t: jnp.moveaxis(heads(t), 1, 0)            # [S,B,H,dh]
    xs_t = (to_t(r), to_t(k), to_t(v), to_t(w))
    state0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    step = lambda c, xx: _rwkv_step(c, xx, p["bonus_u"])
    wkv_fin, ys = _chunked_scan(step, state0, xs_t, cfg.scan_chunk, cfg.remat)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)              # [B,S,d]
    y = rms_norm(y.astype(x.dtype), p["ln_x"])
    y = (y.astype(jnp.float32) * jax.nn.silu(g.astype(jnp.float32))
         ).astype(x.dtype)
    out = ops.linear(y, p["w_o"])
    if return_state:
        return out, {"wkv": wkv_fin, "tm_x": xf[:, -1]}
    return out


def rwkv_channel_mix(p, x: jax.Array, cfg) -> jax.Array:
    xf = x.astype(jnp.float32)
    xs = _shift(xf)
    xk = _lerp(xf, xs, p["mu_ck"]).astype(x.dtype)
    xr = _lerp(xf, xs, p["mu_cr"]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(ops.linear(xk, p["w_ck"])
                               .astype(jnp.float32))).astype(x.dtype)
    return jax.nn.sigmoid(ops.linear(xr, p["w_cr"]).astype(jnp.float32)
                          ).astype(x.dtype) * ops.linear(k, p["w_cv"])


def rwkv_init_state(cfg, batch: int):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    h = d // dh
    return {
        "wkv": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "tm_x": jnp.zeros((batch, d), jnp.float32),
        "cm_x": jnp.zeros((batch, d), jnp.float32),
    }


def rwkv_time_mix_decode(p, x_t: jax.Array, state, cfg
                         ) -> Tuple[jax.Array, Any]:
    b, d = x_t.shape
    dh = cfg.rwkv_head_dim
    h = d // dh
    xf = x_t.astype(jnp.float32)
    xs = state["tm_x"]
    r = ops.linear(_lerp(xf, xs, p["mu_r"]).astype(x_t.dtype), p["w_r"])
    k = ops.linear(_lerp(xf, xs, p["mu_k"]).astype(x_t.dtype), p["w_k"])
    v = ops.linear(_lerp(xf, xs, p["mu_v"]).astype(x_t.dtype), p["w_v"])
    g = ops.linear(_lerp(xf, xs, p["mu_g"]).astype(x_t.dtype), p["w_g"])
    xw = _lerp(xf, xs, p["mu_w"])
    w = jnp.exp(-jnp.exp(
        p["decay_w0"] + jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]))
    hd = lambda t: t.astype(jnp.float32).reshape(b, h, dh)
    new_wkv, y = _rwkv_step(state["wkv"], (hd(r), hd(k), hd(v), hd(w)),
                            p["bonus_u"])
    y = rms_norm(y.reshape(b, d).astype(x_t.dtype), p["ln_x"])
    y = (y.astype(jnp.float32) * jax.nn.silu(g.astype(jnp.float32))
         ).astype(x_t.dtype)
    out = ops.linear(y, p["w_o"])
    return out, {**state, "wkv": new_wkv, "tm_x": xf}


def rwkv_channel_mix_decode(p, x_t: jax.Array, state, cfg
                            ) -> Tuple[jax.Array, Any]:
    xf = x_t.astype(jnp.float32)
    xs = state["cm_x"]
    xk = _lerp(xf, xs, p["mu_ck"]).astype(x_t.dtype)
    xr = _lerp(xf, xs, p["mu_cr"]).astype(x_t.dtype)
    k = jnp.square(jax.nn.relu(ops.linear(xk, p["w_ck"])
                               .astype(jnp.float32))).astype(x_t.dtype)
    out = jax.nn.sigmoid(ops.linear(xr, p["w_cr"]).astype(jnp.float32)
                         ).astype(x_t.dtype) * ops.linear(k, p["w_cv"])
    return out, {**state, "cm_x": xf}

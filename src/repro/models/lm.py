"""Unified model builder for all assigned architecture families.

A model is a sequence of *periods* (period = lcm of the attention/MoE
interleave patterns; 1 for homogeneous stacks, 8 for Jamba).  Per-position
param subtrees are stacked across periods with a leading "layers" axis and
the stack runs under ``lax.scan`` — 95-layer models lower to compact HLO.

Families:
  dense/moe/vlm — decoder-only LM (vlm/audio prepend stub frontend embeds)
  ssm           — RWKV-6 (time-mix + channel-mix per layer)
  hybrid        — Jamba (mamba x7 : attn x1, MoE every other layer)
  encdec        — bidirectional encoder + causal decoder w/ cross attention
"""
from __future__ import annotations

import dataclasses
from math import gcd
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.sparse_kv import SparseKVCache, abstract_cache, freeze_prefix
from repro.kernels import ops
from . import module as mod
from .module import ParamSpec
from .layers import (rms_norm, norm_spec, embed_specs, embed_apply,
                     unembed_apply, mlp_specs, mlp_apply)
from .attention import (attn_specs, attn_apply, attn_decode, DenseKVCache,
                        cross_attn_decode, pooled_attn_panel,
                        pooled_attn_prefill_chunk)
from .moe import moe_specs, moe_apply
from .ssm import (mamba_specs, mamba_apply, mamba_decode, mamba_init_state,
                  rwkv_specs, rwkv_time_mix, rwkv_channel_mix,
                  rwkv_init_state, rwkv_time_mix_decode,
                  rwkv_channel_mix_decode)


def _lcm(a: int, b: int) -> int:
    return a * b // gcd(a, b)


def period_len(cfg) -> int:
    p = 1
    if cfg.family == "hybrid":
        p = _lcm(p, cfg.attn_every)
    if cfg.n_experts:
        p = _lcm(p, cfg.moe_every)
    return p


def layer_kind(cfg, i: int) -> Tuple[str, str]:
    if cfg.family == "ssm":
        return ("rwkv", "cmix")
    mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
    ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
    return (mixer, ffn)


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def _block_specs(cfg, kind: Tuple[str, str], cross: bool = False
                 ) -> Dict[str, Any]:
    mixer, ffn = kind
    if mixer == "rwkv":
        return {"ln1": norm_spec(cfg), "tmix": rwkv_specs(cfg),
                "ln2": norm_spec(cfg)}
    s: Dict[str, Any] = {"ln1": norm_spec(cfg)}
    s["mixer"] = attn_specs(cfg) if mixer == "attn" else mamba_specs(cfg)
    if cross:
        s["ln_cross"] = norm_spec(cfg)
        s["cross"] = attn_specs(cfg, cross=True)
    s["ln2"] = norm_spec(cfg)
    s["ffn"] = moe_specs(cfg) if ffn == "moe" else mlp_specs(cfg)
    return s


def _stack_specs(tree: Any, n: int) -> Any:
    def one(p: str, s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + tuple(s.shape), s.dtype,
                         ("layers",) + tuple(s.axes or (None,) * len(s.shape)),
                         init=s.init, scale=s.scale)
    return mod._map_with_path(one, tree)


def model_specs(cfg) -> Dict[str, Any]:
    p = period_len(cfg)
    n_periods = cfg.n_layers // p
    if cfg.n_layers % p != 0:
        raise ValueError(f"n_layers {cfg.n_layers} not a multiple of period {p}")
    kinds = [layer_kind(cfg, j) for j in range(p)]
    cross = cfg.family == "encdec"
    period = {f"l{j}": _block_specs(cfg, kinds[j], cross=cross)
              for j in range(p)}
    specs: Dict[str, Any] = {
        "embed": embed_specs(cfg),
        "blocks": _stack_specs(period, n_periods),
        "final_norm": norm_spec(cfg),
    }
    if cfg.family == "encdec":
        enc_period = {"l0": _block_specs(cfg, ("attn", "mlp"))}
        specs["encoder"] = _stack_specs(enc_period, cfg.enc_layers)
        specs["enc_norm"] = norm_spec(cfg)
    return specs


def abstract_params(cfg):
    return mod.abstract(model_specs(cfg))


def init_params(cfg, key):
    return mod.initialize(model_specs(cfg), key)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _sublayer(x, p, kind, cfg, ctx, positions, memory, attn_impl,
              collect_kv: Optional[list] = None):
    mixer, ffn = kind
    if mixer == "rwkv":
        h = rwkv_time_mix(p["tmix"], rms_norm(x, p["ln1"]), cfg, ctx)
        x = ctx.constrain(x + h, ("batch", "seq", "embed"))
        h = rwkv_channel_mix(p["tmix"], rms_norm(x, p["ln2"]), cfg)
        return ctx.constrain(x + h, ("batch", "seq", "embed"))

    h = rms_norm(x, p["ln1"])
    if mixer == "attn":
        h = attn_apply(p["mixer"], h, cfg, ctx, positions,
                       causal=(memory is None) or None, attn_impl=attn_impl)
    else:
        h = mamba_apply(p["mixer"], h, cfg, ctx)
    x = ctx.constrain(x + h, ("batch", "seq", "embed"))
    if "cross" in p and memory is not None:
        h = attn_apply(p["cross"], rms_norm(x, p["ln_cross"]), cfg, ctx,
                       positions, memory=memory)
        x = ctx.constrain(x + h, ("batch", "seq", "embed"))
    h2 = rms_norm(x, p["ln2"])
    if ffn == "moe":
        h2 = moe_apply(p["ffn"], h2, cfg, ctx)
    else:
        h2 = mlp_apply(p["ffn"], h2, ctx)
    return ctx.constrain(x + h2, ("batch", "seq", "embed"))


def _stack_forward(blocks, x, cfg, ctx, positions, kinds, memory=None,
                   attn_impl="masked", causal=True):
    def body(xc, pp):
        for j, kind in enumerate(kinds):
            k = kind if causal else ("attn", "mlp")
            xc = _sublayer(xc, pp[f"l{j}"], k, cfg, ctx, positions,
                           memory, attn_impl)
        return xc, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = lax.scan(body, x, blocks)
    return x


def forward_train(params, batch: Dict[str, jax.Array], cfg, ctx,
                  attn_impl: str = "masked") -> jax.Array:
    """Returns final hidden states [B, S, d] (logits are computed chunked in
    the loss to keep the [B,S,V] tensor off the residency list)."""
    if cfg.family == "encdec":
        return _encdec_forward(params, batch, cfg, ctx, attn_impl)

    tokens = batch["tokens"]
    x = embed_apply(params["embed"], tokens, cfg)
    if cfg.frontend and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype)
        # frontend prefill only (pooled serving rejects frontend families)
        x = jnp.concatenate([fe, x], axis=1)  # jitlint: disable=hot-path-op
    x = ctx.constrain(x, ("batch", "seq", "embed"))
    s = x.shape[1]
    positions = jnp.arange(s)
    p = period_len(cfg)
    kinds = [layer_kind(cfg, j) for j in range(p)]
    x = _stack_forward(params["blocks"], x, cfg, ctx, positions, kinds,
                       attn_impl=attn_impl)
    return rms_norm(x, params["final_norm"])


def _encdec_forward(params, batch, cfg, ctx, attn_impl):
    src = batch["src_embeds"].astype(cfg.cdtype)
    src = ctx.constrain(src, ("batch", "seq", "embed"))
    positions_src = jnp.arange(src.shape[1])
    enc = _stack_forward(params["encoder"], src, cfg, ctx, positions_src,
                         [("attn", "mlp")], causal=False)
    enc = rms_norm(enc, params["enc_norm"])

    x = embed_apply(params["embed"], batch["tokens"], cfg)
    x = ctx.constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1])
    x = _stack_forward(params["blocks"], x, cfg, ctx, positions,
                       [("attn", "mlp")], memory=enc, attn_impl=attn_impl)
    return rms_norm(x, params["final_norm"])


def logits_fn(params, hidden: jax.Array, cfg, ctx) -> jax.Array:
    logits = unembed_apply(params["embed"], hidden, cfg)
    return ctx.constrain(logits, ("batch", None, "vocab"))


# ---------------------------------------------------------------------------
# prefill: full forward + per-layer state collection (for the serving engine)
# ---------------------------------------------------------------------------

def _sublayer_prefill(x, p, kind, cfg, ctx, positions, memory):
    mixer, ffn = kind
    if mixer == "rwkv":
        xin1 = rms_norm(x, p["ln1"])
        h, st = rwkv_time_mix(p["tmix"], xin1, cfg, ctx, return_state=True)
        x = x + h
        xin2 = rms_norm(x, p["ln2"])
        h = rwkv_channel_mix(p["tmix"], xin2, cfg)
        st = {**st, "cm_x": xin2.astype(jnp.float32)[:, -1]}
        return x + h, {"state": st}

    h = rms_norm(x, p["ln1"])
    if mixer == "attn":
        h, (k, v) = attn_apply(p["mixer"], h, cfg, ctx, positions,
                               return_kv=True)
        collected = {"k": k, "v": v}
    else:
        h, st = mamba_apply(p["mixer"], h, cfg, ctx, return_state=True)
        collected = {"state": st}
    x = x + h
    if "cross" in p and memory is not None:
        h = attn_apply(p["cross"], rms_norm(x, p["ln_cross"]), cfg, ctx,
                       positions, memory=memory)
        x = x + h
    h2 = rms_norm(x, p["ln2"])
    h2 = moe_apply(p["ffn"], h2, cfg, ctx) if ffn == "moe" \
        else mlp_apply(p["ffn"], h2, ctx)
    return x + h2, collected


def forward_prefill(params, batch, cfg, ctx) -> Tuple[jax.Array, Dict]:
    """Full forward returning (final hidden, per-layer collected states).

    Collected states are stacked over periods: {"l{j}": {...(P, ...)}}.
    For encdec, also returns the per-layer cross K/V of the encoder memory.
    """
    memory = None
    if cfg.family == "encdec":
        src = batch["src_embeds"].astype(cfg.cdtype)
        pos_s = jnp.arange(src.shape[1])
        enc = _stack_forward(params["encoder"], src, cfg, ctx, pos_s,
                             [("attn", "mlp")], causal=False)
        memory = rms_norm(enc, params["enc_norm"])

    tokens = batch["tokens"]
    x = embed_apply(params["embed"], tokens, cfg)
    if cfg.frontend and "frontend_embeds" in batch:
        x = jnp.concatenate(  # jitlint: disable=hot-path-op
            [batch["frontend_embeds"].astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    p = period_len(cfg)
    kinds = [layer_kind(cfg, j) for j in range(p)]

    def body(xc, pp):
        out = {}
        cross_kv = {}
        for j, kind in enumerate(kinds):
            pj = pp[f"l{j}"]
            xc, out[f"l{j}"] = _sublayer_prefill(
                xc, pj, kind, cfg, ctx, positions, memory)
            if "cross" in pj and memory is not None:
                from .attention import _project_kv
                ck, cv = _project_kv(pj["cross"], memory, cfg)
                cross_kv[f"l{j}"] = {"k": ck.transpose(0, 2, 1, 3),
                                     "v": cv.transpose(0, 2, 1, 3)}
        return xc, (out, cross_kv)

    x, (collected, cross) = lax.scan(body, x, params["blocks"])
    hidden = rms_norm(x, params["final_norm"])
    return hidden, {"layers": collected, "cross": cross,
                    "len": x.shape[1]}


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, prefix: int, mode: str = "sparse",
               abstract: bool = False) -> Dict[str, Any]:
    """Cache pytree for one period position x n_periods (stacked leading dim).

    mode "sparse": the paper's compressed frozen prefix + dense tail.
    mode "dense":  baseline preallocated cache of size prefix + tail.
    """
    p = period_len(cfg)
    n_periods = cfg.n_layers // p
    kinds = [layer_kind(cfg, j) for j in range(p)]
    hkv, hd = cfg.n_kv, cfg.hd
    dt = cfg.cdtype

    def attn_cache():
        if mode == "sparse":
            c = abstract_cache(batch, hkv, prefix, hd,
                               1.0 - cfg.kv_k_sparsity,
                               1.0 - cfg.kv_v_sparsity,
                               tail_size=cfg.kv_tail, dtype=dt)
            return c
        s_max = prefix + cfg.kv_tail
        k = jax.ShapeDtypeStruct((batch, hkv, s_max, hd), dt)
        return DenseKVCache(k, k, jax.ShapeDtypeStruct((), jnp.int32))

    def leaf_cache(kind):
        mixer, _ = kind
        if mixer == "attn":
            return {"kv": attn_cache()}
        if mixer == "mamba":
            st = mamba_init_state(cfg, batch)
            return {"state": jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), st)}
        st = rwkv_init_state(cfg, batch)
        return {"state": jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), st)}

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((n_periods,) + tuple(s.shape),
                                           s.dtype), tree)

    cache = {"pos": jax.ShapeDtypeStruct((), jnp.int32),
             "layers": {f"l{j}": stack(leaf_cache(kinds[j]))
                        for j in range(p)}}
    if cfg.family == "encdec":
        # static cross K/V from the encoder (prefill-computed; ideal
        # candidates for the paper's frozen compressed format)
        kv = jax.ShapeDtypeStruct((n_periods, batch, hkv, prefix, hd), dt)
        cache["cross"] = {"k": kv, "v": kv}
    if abstract:
        return cache
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype)
        if not isinstance(s, jax.Array) else s, cache)


# ---------------------------------------------------------------------------
# one-token decode step
# ---------------------------------------------------------------------------

def _sublayer_decode(x_t, p, cache_j, kind, cfg, ctx, position,
                     cross_kv=None):
    mixer, ffn = kind
    new_cache = dict(cache_j)
    if mixer == "rwkv":
        h, st = rwkv_time_mix_decode(p["tmix"], rms_norm(x_t, p["ln1"]),
                                     cache_j["state"], cfg)
        x_t = x_t + h
        h, st = rwkv_channel_mix_decode(p["tmix"], rms_norm(x_t, p["ln2"]),
                                        st, cfg)
        new_cache["state"] = st
        return x_t + h, new_cache

    h = rms_norm(x_t, p["ln1"])
    if mixer == "attn":
        h, kv = attn_decode(p["mixer"], h, cache_j["kv"], cfg, ctx, position)
        new_cache["kv"] = kv
    else:
        h, st = mamba_decode(p["mixer"], h, cache_j["state"], cfg)
        new_cache["state"] = st
    x_t = x_t + h
    if "cross" in p and cross_kv is not None:
        h = cross_attn_decode(p["cross"], rms_norm(x_t, p["ln_cross"]),
                              cross_kv[0], cross_kv[1], cfg)
        x_t = x_t + h
    h2 = rms_norm(x_t, p["ln2"])
    if ffn == "moe":
        h2 = moe_apply(p["ffn"], h2[:, None, :], cfg, ctx)[:, 0]
    else:
        h2 = mlp_apply(p["ffn"], h2)
    return x_t + h2, new_cache


def _attn_kinds(cfg) -> List[Tuple[str, str]]:
    if cfg.family == "encdec" or cfg.frontend:
        raise ValueError(
            "pooled serving has no cross-attention / frontend-embedding path")
    pl = period_len(cfg)
    kinds = [layer_kind(cfg, j) for j in range(pl)]
    if not all(k[0] == "attn" for k in kinds):
        raise ValueError(
            "pooled serving supports attention stacks (dense/moe families)")
    return kinds


def _pooled_ffn(pj, kind, h2, cfg, ctx):
    """The shared MLP/MoE half of a pooled panel block, flattened to rows.

    The flatten is a bit-exactness requirement, not a style choice: XLA
    fuses the SwiGLU epilogue differently at ``[B, 1, d]`` than at
    ``[B, d]`` (the silu·up product rounds through different fusions), so
    running the ``Q == 1`` panel at its natural rank would perturb bf16
    decode logits vs the pre-unification decode step.  Row-flattening
    makes the panel width invisible to the FFN — ``Q == 1`` compiles the
    exact 2-D program the old ``forward_decode_pooled`` ran.

    Deliberately NO sharding constraint on the silu·up hidden (``ctx`` is
    the MoE router's API argument only): pinning the ffn dim to the model
    axis would partial-sum + all-reduce the ``w_down`` contraction, and
    that reassociation breaks the sharded-vs-unsharded token-identity bar
    mesh serving asserts.  The rows stay data-sharded through the
    residual stream; with serving weights replicated, duplicating the FFN
    across the tensor axis is the explicit cost of exact parity (the
    TP-weights ROADMAP follow-up owns removing it).
    """
    lead = h2.shape[:-1]
    rows = h2.reshape(-1, h2.shape[-1])
    if kind[1] == "moe":
        out = moe_apply(pj["ffn"], rows[:, None, :], cfg, ctx)[:, 0]
    else:
        out = mlp_apply(pj["ffn"], rows)
    return out.reshape(*lead, out.shape[-1])


def forward_panel_pooled(params, state, tokens: jax.Array,
                         slot_mask: jax.Array, cfg, ctx, bs: int
                         ) -> Tuple[jax.Array, Any]:
    """THE per-token serving forward: score a ``[B, Qn]`` token panel per
    slot in ONE pass over the pooled serving cache.

    One function, three serving roles — the old ``forward_decode_pooled``
    and ``forward_verify_pooled`` scan bodies collapsed into this single
    panel path with a static ``Qn``:

    * ``Qn == 1`` — a plain decode tick (``tokens [B, 1]`` is each slot's
      last committed token); the ops layer squeezes the panel onto the
      exact single-query fused dispatch, so greedy decode stays
      bit-identical to the pre-unification engine;
    * ``Qn == K+1`` — a speculative verify step (``tokens[:, 1:]`` the
      padded draft window);
    * spec-off engines simply never build a ``Qn > 1`` trace.

    Panel position ``j`` decodes at absolute position ``pos + j`` with
    intra-window causal attention, so ``logits[:, j]`` is exactly what
    ``j`` sequential decode ticks past ``tokens[:, 0]`` would have
    produced for that continuation.  All ``Qn`` fresh K/V are appended
    and ``pos``/``tail_len`` advance by ``Qn`` per live slot — a caller
    that keeps fewer (speculative rejection) rolls the suffix back by a
    pure masked length decrement.  Masked slots are bit-identical
    passthrough, and every shape is static: one trace per
    (pool geometry, Qn), whatever the accept lengths turn out to be.

    Returns (logits [B, Qn, V] f32, new state); unknown ``state`` keys
    (e.g. the sampler lanes) pass through untouched.
    """
    qn = tokens.shape[1]
    x = embed_apply(params["embed"], tokens, cfg)            # [B, Qn, d]
    x = ctx.constrain(x, ("batch", "seq", "embed"))
    kinds = _attn_kinds(cfg)
    positions = state["pos"][:, None] + jnp.arange(qn)[None, :]
    prefix_blocks = state["prefix_blocks"]
    tail_len = state["tail_len"]
    # paged pool: the block table is pool-level state threaded to every
    # layer's attention (the arena leaves themselves ride the scan)
    table = state.get("table")

    def body(xc, xs):
        pp, cc = xs
        new_cc = {}
        for j, kind in enumerate(kinds):
            pj, cj = pp[f"l{j}"], cc[f"l{j}"]
            h = rms_norm(xc, pj["ln1"])
            h, new_kv = pooled_attn_panel(
                pj["mixer"], h, cj["kv"], cfg, ctx, positions,
                prefix_blocks, tail_len, slot_mask, bs, table=table)
            xc = xc + h
            xc = xc + _pooled_ffn(pj, kind, rms_norm(xc, pj["ln2"]),
                                  cfg, ctx)
            new_cc[f"l{j}"] = {"kv": new_kv}
        return xc, new_cc

    x, new_layers = lax.scan(body, x, (params["blocks"], state["layers"]))
    x = rms_norm(x, params["final_norm"])
    logits = unembed_apply(params["embed"], x, cfg)
    logits = ctx.constrain(logits, ("batch", None, "vocab"))
    grow = qn * slot_mask.astype(jnp.int32)
    new_state = {**state, "layers": new_layers,
                 "pos": positions[:, 0] + grow,
                 "tail_len": tail_len + grow}
    return logits, new_state


_ARENA_KEYS = ("k_bitmap", "k_values", "v_bitmap", "v_values")


def forward_prefill_chunk(params, state, tokens: jax.Array, slot: jax.Array,
                          cfg, ctx, bs: int,
                          new_ids: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, Any]:
    """Prefill one prompt chunk for a single slot of the pooled cache.

    tokens [1, C]; slot scalar int32.  The chunk attends to the slot's
    already-frozen prefix, then its full (bs)-token blocks are pruned +
    packed straight into the slot's prefix storage at the pool's static
    capacity; a trailing remainder (< bs tokens — last chunk only) lands in
    the dense tail.  One ``jax.jit`` trace per distinct chunk length; the
    slot index and start position are traced values, so admitting a request
    into *any* slot at *any* offset reuses the same compiled step.
    Returns (last-token logits [1, V], new state) — the engine samples the
    request's first token from these logits under the slot's lane; unknown
    ``state`` keys pass through untouched.

    Paged pool (``state`` carries a block table): the slot attends to its
    prefix THROUGH its table row (blocks a cache hit pointed at were
    frozen by other requests), and the chunk's ``C // bs`` new blocks are
    frozen into FRESH arena pages ``new_ids`` (int32 ``[C // bs]``,
    host-allocated) appended to the table row — never into shared storage,
    which is the copy-on-write guarantee.
    """
    c = tokens.shape[1]
    nb_new, rem = c // bs, c % bs
    kinds = _attn_kinds(cfg)
    paged = "table" in state
    x = embed_apply(params["embed"], tokens, cfg)            # [1, C, d]
    start = jnp.take(state["pos"], slot)
    pb0 = jnp.take(state["prefix_blocks"], slot)
    positions = start + jnp.arange(c)
    ctx_len = pb0 * bs
    if paged:
        if new_ids is None and nb_new != 0:
            raise ValueError(
                "paged prefill needs fresh arena ids for its full blocks")
        # arena leaves are pool-global — only the per-slot tails slice
        slot_layers = {
            name: {"kv": {
                k: (a if k in _ARENA_KEYS
                    else lax.dynamic_slice_in_dim(a, slot, 1, axis=1))
                for k, a in leaf["kv"].items()}}
            for name, leaf in state["layers"].items()}
        sb = state["table"].shape[1]
        table_row = lax.dynamic_slice(
            state["table"], (slot, jnp.int32(0)), (1, sb))[0]
    else:
        slot_layers = jax.tree_util.tree_map(
            lambda a: lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            state["layers"])
        table_row = None

    def body(xc, xs):
        pp, cc = xs
        chunk_kv = {}
        for j, kind in enumerate(kinds):
            pj, cj = pp[f"l{j}"], cc[f"l{j}"]
            h = rms_norm(xc, pj["ln1"])
            h, k_c, v_c = pooled_attn_prefill_chunk(
                pj["mixer"], h, cj["kv"], cfg, ctx, positions, ctx_len, bs,
                table_row=table_row)
            xc = xc + h
            h2 = rms_norm(xc, pj["ln2"])
            if kind[1] == "moe":
                h2 = moe_apply(pj["ffn"], h2, cfg, ctx)
            else:
                h2 = mlp_apply(pj["ffn"], h2, ctx)
            xc = xc + h2
            chunk_kv[f"l{j}"] = {"k": k_c, "v": v_c}
        return xc, chunk_kv

    x, chunk_kv = lax.scan(body, x, (params["blocks"], slot_layers))
    hidden = rms_norm(x, params["final_norm"])
    logits = logits_fn(params, hidden[:, -1:], cfg, ctx)[:, 0]

    from repro.core.sparse_kv import freeze_chunk_blocks
    if paged and nb_new:
        new_ids = jnp.asarray(new_ids, jnp.int32)            # [nb_new]
    new_layers = {}
    for name, leaf in state["layers"].items():
        kv = dict(leaf["kv"])
        ck, cv = chunk_kv[name]["k"], chunk_kv[name]["v"]    # [P,1,Hkv,C,hd]
        p_, _, hkv, _, hd = ck.shape
        if nb_new:
            cap_k = kv["k_values"].shape[-1]
            cap_v = kv["v_values"].shape[-1]
            k_bm, k_vl, v_bm, v_vl = freeze_chunk_blocks(
                ck[:, 0, :, :nb_new * bs], cv[:, 0, :, :nb_new * bs],
                cfg.kv_k_sparsity, cfg.kv_v_sparsity, bs, cap_k, cap_v)
            for key, upd in (("k_bitmap", k_bm), ("k_values", k_vl),
                             ("v_bitmap", v_bm), ("v_values", v_vl)):
                if paged:
                    # [P, Hkv, nb, X] -> [P, nb, Hkv, X] rows into the
                    # fresh arena pages (never shared storage: CoW)
                    kv[key] = kv[key].at[:, new_ids].set(
                        upd.transpose(0, 2, 1, 3).astype(kv[key].dtype))
                else:
                    kv[key] = lax.dynamic_update_slice(
                        kv[key], upd[:, None].astype(kv[key].dtype),
                        (0, slot, 0, pb0, 0))
        if rem:
            for key, src in (("k_tail", ck), ("v_tail", cv)):
                kv[key] = lax.dynamic_update_slice(
                    kv[key], src[:, :, :, nb_new * bs:].astype(
                        kv[key].dtype),
                    (0, slot, 0, 0, 0))
        new_layers[name] = {"kv": kv}

    new_state = {**state, "layers": new_layers,
                 "pos": state["pos"].at[slot].set(start + c),
                 "prefix_blocks":
                     state["prefix_blocks"].at[slot].set(pb0 + nb_new),
                 "tail_len": state["tail_len"].at[slot].set(rem)}
    if paged and nb_new:
        new_state["table"] = lax.dynamic_update_slice(
            state["table"], new_ids[None], (slot, pb0))
        new_state["refcount"] = state["refcount"].at[new_ids].add(1)
    return logits, new_state


def forward_decode(params, cache, tokens: jax.Array, cfg, ctx
                   ) -> Tuple[jax.Array, Any]:
    """tokens [B, 1] -> (logits [B, V] f32, updated cache)."""
    b = tokens.shape[0]
    x_t = embed_apply(params["embed"], tokens[:, 0], cfg)
    x_t = ctx.constrain(x_t, ("batch", "embed"))
    position = cache["pos"]
    pl = period_len(cfg)
    kinds = [layer_kind(cfg, j) for j in range(pl)]
    has_cross = cfg.family == "encdec"

    def body(xc, xs):
        pp, cc, cross = xs
        new_cc = {}
        for j, kind in enumerate(kinds):
            ck = (cross["k"], cross["v"]) if has_cross else None
            xc, new_cc[f"l{j}"] = _sublayer_decode(
                xc, pp[f"l{j}"], cc[f"l{j}"], kind, cfg, ctx, position, ck)
        return xc, new_cc

    n_periods = cfg.n_layers // pl
    xs = (params["blocks"], cache["layers"],
          cache["cross"] if has_cross else
          {"k": jnp.zeros((n_periods, 0)), "v": jnp.zeros((n_periods, 0))})
    x_t, new_layers = lax.scan(body, x_t, xs)
    x_t = rms_norm(x_t, params["final_norm"])
    logits = unembed_apply(params["embed"], x_t, cfg)
    logits = ctx.constrain(logits, ("batch", "vocab"))
    new_cache = dict(cache)
    new_cache["layers"] = new_layers
    new_cache["pos"] = position + 1
    return logits, new_cache

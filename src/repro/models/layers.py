"""Shared model components: RMSNorm, RoPE, embeddings, SwiGLU MLP.

All matmuls route through ``repro.kernels.ops.linear`` so the paper's
sparse-format weights drop in transparently after ``convert_to_sparse``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .module import ParamSpec


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def rope_angles(positions: jax.Array, hd: int, theta: float):
    """positions [...,] -> (cos, sin) of shape [..., hd//2] (f32)."""
    freq = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x [..., S, H, D] with cos/sin [..., S, D//2] (broadcast over H)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    # static half-rotate (shape never varies per token)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],  # jitlint: disable=hot-path-op
                           axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embed_specs(cfg) -> Dict[str, ParamSpec]:
    d = cfg.pdtype
    specs = {"tok": ParamSpec((cfg.vocab, cfg.d_model), d,
                              ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab), d,
                                     ("embed", "vocab"))
    return specs


def embed_apply(p, tokens: jax.Array, cfg) -> jax.Array:
    return jnp.take(p["tok"], tokens, axis=0).astype(cfg.cdtype)


def unembed_apply(p, x: jax.Array, cfg) -> jax.Array:
    w = p["tok"].T.astype(cfg.cdtype) if cfg.tie_embeddings else p["lm_head"]
    # ops.linear dispatches on the leaf type (dense / sparse-bf16 / int8 /
    # packed4); never swallow kernel errors behind a silent dense fallback
    return ops.linear(x, w, out_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg, d_in: Optional[int] = None,
              d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d_in = d_in or cfg.d_model
    d_ff = d_ff or cfg.d_ff
    dt = cfg.pdtype
    return {
        "w_gate": ParamSpec((d_in, d_ff), dt, ("embed", "ffn")),
        "w_up": ParamSpec((d_in, d_ff), dt, ("embed", "ffn")),
        "w_down": ParamSpec((d_ff, d_in), dt, ("ffn", "embed")),
    }


def mlp_apply(p, x: jax.Array, ctx=None) -> jax.Array:
    h = jax.nn.silu(ops.linear(x, p["w_gate"])) * ops.linear(x, p["w_up"])
    if ctx is not None:
        h = ctx.constrain(h, ("batch", "seq", "ffn"))
    return ops.linear(h, p["w_down"])


def norm_spec(cfg, d: Optional[int] = None) -> ParamSpec:
    return ParamSpec((d or cfg.d_model,), jnp.float32, ("embed",),
                     init="ones")

"""GQA attention (train full-sequence, decode with dense or sparse-KV cache).

Head counts are padded to ``cfg.tp_pad`` (extra heads have zero-init wq/wo
rows so they are mathematically inert) so the head axis always shards over
the model axis; kv heads replicate when ``n_kv`` doesn't divide TP
(DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.core.sparse_kv import SparseKVCache, append_token
from .module import ParamSpec
from .layers import rms_norm, rope_angles, apply_rope
from .flash import blocked_attention, full_attention


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseKVCache:
    """Baseline decode cache: preallocated [B, Hkv, S_max, D] + length."""
    k: jax.Array
    v: jax.Array
    length: jax.Array           # int32 scalar

    def tree_flatten(self):
        return (self.k, self.v, self.length), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_dense_cache(batch, hkv, s_max, d, dtype=jnp.bfloat16):
    z = jax.ShapeDtypeStruct if dtype is None else None
    k = jnp.zeros((batch, hkv, s_max, d), dtype)
    return DenseKVCache(k, k, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_specs(cfg, cross: bool = False) -> Dict[str, ParamSpec]:
    hq, hkv, hd, d = cfg.padded_heads, cfg.n_kv, cfg.hd, cfg.d_model
    dt = cfg.pdtype
    specs = {
        "wq": ParamSpec((d, hq * hd), dt, ("embed", "heads")),
        "wk": ParamSpec((d, hkv * hd), dt, ("embed", "kv_heads")),
        "wv": ParamSpec((d, hkv * hd), dt, ("embed", "kv_heads")),
        "wo": ParamSpec((hq * hd, d), dt, ("heads", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), jnp.float32, (None,), init="ones")
        specs["k_norm"] = ParamSpec((hd,), jnp.float32, (None,), init="ones")
    return specs


def _project_q(p, x, cfg):
    b = x.shape[:-1]
    q = ops.linear(x, p["wq"]).reshape(*b, cfg.padded_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    return q


def _project_kv(p, x, cfg):
    b = x.shape[:-1]
    k = ops.linear(x, p["wk"]).reshape(*b, cfg.n_kv, cfg.hd)
    v = ops.linear(x, p["wv"]).reshape(*b, cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v


def _repeat_kv(k: jax.Array, g: int) -> jax.Array:
    return jnp.repeat(k, g, axis=1)


# ---------------------------------------------------------------------------
# full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def attn_apply(p, x: jax.Array, cfg, ctx, positions: jax.Array,
               memory: Optional[jax.Array] = None,
               causal: Optional[bool] = None,
               attn_impl: str = "masked",
               return_kv: bool = False):
    """x [B, S, d]; memory (enc-dec cross attention source) [B, Sm, d]."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    q = _project_q(p, x, cfg)                                # [B,S,Hq,hd]
    src = memory if memory is not None else x
    k, v = _project_kv(p, src, cfg)                          # [B,Sm,Hkv,hd]

    if causal is None:
        causal = memory is None
    if memory is None:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    q = q.transpose(0, 2, 1, 3)                              # [B,Hq,S,hd]
    k = _repeat_kv(k.transpose(0, 2, 1, 3), hq // hkv)
    v = _repeat_kv(v.transpose(0, 2, 1, 3), hq // hkv)
    sm = 1.0 / hd ** 0.5
    # short seqs: one einsum (scores fit per-device; also keeps the HLO flat
    # so compiled-probe cost analysis is exact).  Longer: blocked flash.
    thr = getattr(cfg, "full_attn_max", 4096)
    if s <= thr and k.shape[2] <= thr:
        o = full_attention(q, k, v, sm, causal=causal)
    else:
        o = blocked_attention(q, k, v, sm, causal=causal, impl=attn_impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = ops.linear(o, p["wo"])
    if return_kv:
        g = hq // hkv
        kv = (k[:, ::g], v[:, ::g])          # un-repeated [B, Hkv, S, hd]
        return out, kv
    return out


# ---------------------------------------------------------------------------
# decode (one token, cached KV)
# ---------------------------------------------------------------------------

def attn_decode(p, x_t: jax.Array, cache, cfg, ctx,
                position: jax.Array) -> Tuple[jax.Array, Any]:
    """x_t [B, d] (single new token). cache: DenseKVCache | SparseKVCache."""
    b, _ = x_t.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    g = hq // hkv
    q = _project_q(p, x_t, cfg)                              # [B,Hq,hd]
    k_new, v_new = _project_kv(p, x_t, cfg)                  # [B,Hkv,hd]
    cos, sin = rope_angles(position, hd, cfg.rope_theta)     # scalar pos
    q = apply_rope(q[:, None], cos[None, None], sin[None, None])[:, 0]
    k_new = apply_rope(k_new[:, None], cos[None, None], sin[None, None])[:, 0]
    sm = 1.0 / hd ** 0.5

    if isinstance(cache, SparseKVCache):
        cache = append_token(cache, k_new, v_new)
        if (getattr(cfg, "cp_decode", False) and ctx is not None
                and ctx.mesh is not None and cache.k_sp.bitmap.ndim == 5):
            from repro.distributed.cp_attention import \
                sparse_decode_attention_cp
            o = sparse_decode_attention_cp(q, cache, hkv, sm, ctx)
        else:
            o = ops.sparse_decode_attention(
                q, cache.k_sp, cache.v_sp, hkv, sm,
                cache.k_tail, cache.v_tail, cache.tail_len)
    else:
        idx = cache.length
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new[:, :, None, :].astype(cache.k.dtype), idx, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new[:, :, None, :].astype(cache.v.dtype), idx, axis=2)
        cache = DenseKVCache(k, v, idx + 1)
        valid = jnp.arange(k.shape[2])[None, :] < (idx + 1)
        valid = jnp.broadcast_to(valid, (b, k.shape[2]))
        if ctx is not None:
            k = ctx.constrain(k, ("batch", "kv_heads", "ctx", None))
            v = ctx.constrain(v, ("batch", "kv_heads", "ctx", None))
        o = full_attention(q[:, :, None, :], _repeat_kv(k, g),
                           _repeat_kv(v, g), sm, causal=False,
                           kv_valid=valid)[:, :, 0, :]

    out = ops.linear(o.reshape(b, hq * hd).astype(x_t.dtype), p["wo"])
    return out, cache


def cross_attn_decode(p, x_t: jax.Array, k: jax.Array, v: jax.Array,
                      cfg) -> jax.Array:
    """Decode-time cross attention against precomputed (possibly sparse)
    encoder K/V [B, Hkv, Sm, hd] — no mask, no cache update."""
    b, _ = x_t.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    q = _project_q(p, x_t, cfg)
    sm = 1.0 / hd ** 0.5
    g = hq // hkv
    o = full_attention(q[:, :, None, :], _repeat_kv(k, g), _repeat_kv(v, g),
                       sm, causal=False)[:, :, 0, :]
    return ops.linear(o.reshape(b, hq * hd).astype(x_t.dtype), p["wo"])

"""GQA attention (train full-sequence, decode with dense or sparse-KV cache).

Head counts are padded to ``cfg.tp_pad`` (extra heads have zero-init wq/wo
rows so they are mathematically inert) so the head axis always shards over
the model axis; kv heads replicate when ``n_kv`` doesn't divide TP
(DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.core.sparse_format import unpack
from repro.core.sparse_kv import (SparseKVCache, append_tail_panel,
                                  append_token, pooled_view)
from .module import ParamSpec
from .layers import rms_norm, rope_angles, apply_rope
from .flash import blocked_attention, full_attention


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseKVCache:
    """Baseline decode cache: preallocated [B, Hkv, S_max, D] + length."""
    k: jax.Array
    v: jax.Array
    length: jax.Array           # int32 scalar

    def tree_flatten(self):
        return (self.k, self.v, self.length), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_dense_cache(batch, hkv, s_max, d, dtype=jnp.bfloat16):
    z = jax.ShapeDtypeStruct if dtype is None else None
    k = jnp.zeros((batch, hkv, s_max, d), dtype)
    return DenseKVCache(k, k, jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_specs(cfg, cross: bool = False) -> Dict[str, ParamSpec]:
    hq, hkv, hd, d = cfg.padded_heads, cfg.n_kv, cfg.hd, cfg.d_model
    dt = cfg.pdtype
    specs = {
        "wq": ParamSpec((d, hq * hd), dt, ("embed", "heads")),
        "wk": ParamSpec((d, hkv * hd), dt, ("embed", "kv_heads")),
        "wv": ParamSpec((d, hkv * hd), dt, ("embed", "kv_heads")),
        "wo": ParamSpec((hq * hd, d), dt, ("heads", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), jnp.float32, (None,), init="ones")
        specs["k_norm"] = ParamSpec((hd,), jnp.float32, (None,), init="ones")
    return specs


def _project_q(p, x, cfg):
    b = x.shape[:-1]
    q = ops.linear(x, p["wq"]).reshape(*b, cfg.padded_heads, cfg.hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
    return q


def _project_kv(p, x, cfg):
    b = x.shape[:-1]
    k = ops.linear(x, p["wk"]).reshape(*b, cfg.n_kv, cfg.hd)
    v = ops.linear(x, p["wv"]).reshape(*b, cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"])
    return k, v


def _repeat_kv(k: jax.Array, g: int) -> jax.Array:
    # train/prefill only; the decode kernels expand groups in-register
    return jnp.repeat(k, g, axis=1)  # jitlint: disable=hot-path-op


# ---------------------------------------------------------------------------
# full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def attn_apply(p, x: jax.Array, cfg, ctx, positions: jax.Array,
               memory: Optional[jax.Array] = None,
               causal: Optional[bool] = None,
               attn_impl: str = "masked",
               return_kv: bool = False):
    """x [B, S, d]; memory (enc-dec cross attention source) [B, Sm, d]."""
    b, s, _ = x.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    q = _project_q(p, x, cfg)                                # [B,S,Hq,hd]
    src = memory if memory is not None else x
    k, v = _project_kv(p, src, cfg)                          # [B,Sm,Hkv,hd]

    if causal is None:
        causal = memory is None
    if memory is None:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    q = q.transpose(0, 2, 1, 3)                              # [B,Hq,S,hd]
    k = _repeat_kv(k.transpose(0, 2, 1, 3), hq // hkv)
    v = _repeat_kv(v.transpose(0, 2, 1, 3), hq // hkv)
    sm = 1.0 / hd ** 0.5
    # short seqs: one einsum (scores fit per-device; also keeps the HLO flat
    # so compiled-probe cost analysis is exact).  Longer: blocked flash.
    thr = getattr(cfg, "full_attn_max", 4096)
    if s <= thr and k.shape[2] <= thr:
        o = full_attention(q, k, v, sm, causal=causal)
    else:
        o = blocked_attention(q, k, v, sm, causal=causal, impl=attn_impl)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    out = ops.linear(o, p["wo"])
    if return_kv:
        g = hq // hkv
        kv = (k[:, ::g], v[:, ::g])          # un-repeated [B, Hkv, S, hd]
        return out, kv
    return out


# ---------------------------------------------------------------------------
# decode (one token, cached KV)
# ---------------------------------------------------------------------------

def attn_decode(p, x_t: jax.Array, cache, cfg, ctx,
                position: jax.Array) -> Tuple[jax.Array, Any]:
    """x_t [B, d] (single new token). cache: DenseKVCache | SparseKVCache."""
    b, _ = x_t.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    g = hq // hkv
    q = _project_q(p, x_t, cfg)                              # [B,Hq,hd]
    k_new, v_new = _project_kv(p, x_t, cfg)                  # [B,Hkv,hd]
    cos, sin = rope_angles(position, hd, cfg.rope_theta)     # scalar pos
    q = apply_rope(q[:, None], cos[None, None], sin[None, None])[:, 0]
    k_new = apply_rope(k_new[:, None], cos[None, None], sin[None, None])[:, 0]
    sm = 1.0 / hd ** 0.5

    if isinstance(cache, SparseKVCache):
        cache = append_token(cache, k_new, v_new)
        if (getattr(cfg, "cp_decode", False) and ctx is not None
                and ctx.mesh is not None and cache.k_sp.bitmap.ndim == 5):
            # context-parallel: the only surviving partial+merge consumer
            from repro.distributed.cp_attention import \
                sparse_decode_attention_cp
            o = sparse_decode_attention_cp(q, cache, hkv, sm, ctx)
        else:
            # fused prefix+tail flash-decode: one kernel yields the final
            # attention output (no XLA-side tail merge)
            o = ops.sparse_decode_attention(
                q, cache.k_sp, cache.v_sp, hkv, sm,
                cache.k_tail, cache.v_tail, cache.tail_len)
    else:
        idx = cache.length
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new[:, :, None, :].astype(cache.k.dtype), idx, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new[:, :, None, :].astype(cache.v.dtype), idx, axis=2)
        cache = DenseKVCache(k, v, idx + 1)
        valid = jnp.arange(k.shape[2])[None, :] < (idx + 1)
        valid = jnp.broadcast_to(valid, (b, k.shape[2]))
        if ctx is not None:
            k = ctx.constrain(k, ("batch", "kv_heads", "ctx", None))
            v = ctx.constrain(v, ("batch", "kv_heads", "ctx", None))
        o = full_attention(q[:, :, None, :], _repeat_kv(k, g),
                           _repeat_kv(v, g), sm, causal=False,
                           kv_valid=valid)[:, :, 0, :]

    out = ops.linear(o.reshape(b, hq * hd).astype(x_t.dtype), p["wo"])
    return out, cache


def pooled_attn_panel(p, x: jax.Array, kv: Dict[str, jax.Array], cfg,
                      ctx, positions: jax.Array, prefix_blocks: jax.Array,
                      tail_len: jax.Array, slot_mask: jax.Array, bs: int,
                      table: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """THE pooled serving attention: one ``[B, Qn]`` query panel per layer.

    One function serves every per-token serving step — plain decode is the
    ``Qn == 1`` panel, speculative verify the ``Qn == K+1`` panel; the old
    ``pooled_attn_decode`` / ``pooled_attn_verify`` pair collapsed into
    this single body.  ``x [B, Qn, d]`` is each slot's panel (last
    committed token + up to ``Qn-1`` drafts), ``positions [B, Qn]`` its
    absolute positions; every slot carries its own position, prefix length
    and tail fill (``prefix_blocks``/``tail_len`` int32 ``[B]``) — the
    per-slot variable-length semantics continuous batching needs.  All
    shapes are static, so each panel width traces exactly once.

    All ``Qn`` fresh K/V land in the slot's dense tail at
    ``tail_len..tail_len+Qn-1`` (a rollback is a pure length decrement),
    and the panel is scored by the fused prefix+tail flash-decode kernel
    with a ``Qn*G``-row query block: panel query ``j`` sees the full
    frozen prefix, the pre-existing tail, and panel tokens ``<= j`` —
    intra-window causal.  At ``Qn == 1`` the ops layer squeezes the panel
    onto the exact single-query dispatch, so a decode tick is
    bit-identical to the pre-unification ``pooled_attn_decode`` path.
    Inactive slots (``slot_mask`` False) write nothing and pass their
    cache through bit-identical.

    ``table`` (int32 ``[B, Sb]``, paged pool only) switches the frozen
    prefix to the pool-global arena layout: ``kv``'s compressed leaves are
    then ``[n_phys, Hkv, X]`` shared storage and each slot's blocks are
    reached through its table row — same math, one indirection on the
    fetch.  The dense tail stays per-slot either way.
    """
    b, qn, _ = x.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    q = _project_q(p, x, cfg)                                 # [B,Qn,Hq,hd]
    k_new, v_new = _project_kv(p, x, cfg)                     # [B,Qn,Hkv,hd]
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)     # [B,Qn,hd//2]
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)
    sm = 1.0 / hd ** 0.5

    n_valid = jnp.where(slot_mask, qn, 0)
    k_tail = append_tail_panel(kv["k_tail"], k_new.transpose(0, 2, 1, 3),
                               tail_len, n_valid)
    v_tail = append_tail_panel(kv["v_tail"], v_new.transpose(0, 2, 1, 3),
                               tail_len, n_valid)
    # panel query 0 sees its own token; each later query j sees j more
    t_att = tail_len + slot_mask.astype(jnp.int32)
    if table is not None:
        o = ops.sparse_decode_attention_paged(
            q, kv["k_bitmap"], kv["k_values"], kv["v_bitmap"],
            kv["v_values"], table, hkv, sm, bs, k_tail, v_tail, t_att,
            prefix_len=prefix_blocks * bs)
    else:
        k_sp = pooled_view(kv["k_bitmap"], kv["k_values"], bs, hd)
        v_sp = pooled_view(kv["v_bitmap"], kv["v_values"], bs, hd)
        o = ops.sparse_decode_attention(q, k_sp, v_sp, hkv, sm,
                                        k_tail, v_tail, t_att,
                                        prefix_len=prefix_blocks * bs)
    out = ops.linear(o.reshape(b, qn, hq * hd).astype(x.dtype), p["wo"])
    return out, {**kv, "k_tail": k_tail, "v_tail": v_tail}


def pooled_attn_prefill_chunk(p, x: jax.Array, kv: Dict[str, jax.Array],
                              cfg, ctx, positions: jax.Array,
                              ctx_len: jax.Array, bs: int,
                              table_row: Optional[jax.Array] = None
                              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill attention for ONE slot of the pooled cache.

    Queries attend causally within the chunk plus fully over the slot's
    already-frozen compressed prefix (decompressed here; the chunk path is
    off the per-token hot loop).  ``x [1, C, d]``; ``kv``: slot-sliced
    pooled leaves (``[1, Hkv, Sb, X]``); ``positions [C]`` absolute;
    ``ctx_len`` scalar int32 — valid prefix tokens.  Returns
    ``(out [1, C, d], k_chunk, v_chunk [1, Hkv, C, hd] post-RoPE)`` so the
    caller can freeze the chunk into the pool.

    ``table_row`` (int32 ``[Sb]``, paged pool only): ``kv``'s compressed
    leaves are the shared ``[n_phys, Hkv, X]`` arena and the slot's frozen
    prefix is gathered through its block-table row before decompression —
    a prefix-cache hit means these are blocks some OTHER request froze.
    """
    b, c, _ = x.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    g = hq // hkv
    q = _project_q(p, x, cfg)                                # [1,C,Hq,hd]
    k, v = _project_kv(p, x, cfg)                            # [1,C,Hkv,hd]
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)    # [C, hd//2]
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])
    q = q.transpose(0, 2, 1, 3)                              # [1,Hq,C,hd]
    k = k.transpose(0, 2, 1, 3)                              # [1,Hkv,C,hd]
    v = v.transpose(0, 2, 1, 3)

    if table_row is not None:
        # gather the slot's logical blocks out of the shared arena, then
        # decompress exactly as the flat path would.  Explicit clip mode:
        # table entries are clipped in range at write time, and the jaxpr
        # audit (repro.analysis) rejects PROMISE_IN_BOUNDS arena access
        arena = lambda a: jnp.take(a, table_row, axis=0, mode="clip")
        view = lambda bm, vl: pooled_view(
            arena(bm).transpose(1, 0, 2)[None],
            arena(vl).transpose(1, 0, 2)[None], bs, hd)
        k_ctx = unpack(view(kv["k_bitmap"], kv["k_values"]))
        v_ctx = unpack(view(kv["v_bitmap"], kv["v_values"]))
    else:
        k_ctx = unpack(pooled_view(kv["k_bitmap"], kv["k_values"], bs, hd))
        v_ctx = unpack(pooled_view(kv["v_bitmap"], kv["v_values"], bs, hd))
    s_ctx = k_ctx.shape[2]
    # prefill-chunk path: concat over the static chunk width, not the
    # per-token decode loop  # jitlint: disable=hot-path-op
    kv_valid = jnp.concatenate(  # jitlint: disable=hot-path-op
        [jnp.arange(s_ctx) < ctx_len, jnp.ones((c,), bool)])[None, :]
    kk = _repeat_kv(jnp.concatenate([k_ctx.astype(k.dtype), k], axis=2), g)  # jitlint: disable=hot-path-op
    vv = _repeat_kv(jnp.concatenate([v_ctx.astype(v.dtype), v], axis=2), g)  # jitlint: disable=hot-path-op
    sm = 1.0 / hd ** 0.5
    o = full_attention(q, kk, vv, sm, causal=True, kv_valid=kv_valid)
    o = o.transpose(0, 2, 1, 3).reshape(b, c, hq * hd)
    return ops.linear(o, p["wo"]), k, v


def cross_attn_decode(p, x_t: jax.Array, k: jax.Array, v: jax.Array,
                      cfg) -> jax.Array:
    """Decode-time cross attention against precomputed (possibly sparse)
    encoder K/V [B, Hkv, Sm, hd] — no mask, no cache update."""
    b, _ = x_t.shape
    hq, hkv, hd = cfg.padded_heads, cfg.n_kv, cfg.hd
    q = _project_q(p, x_t, cfg)
    sm = 1.0 / hd ** 0.5
    g = hq // hkv
    o = full_attention(q[:, :, None, :], _repeat_kv(k, g), _repeat_kv(v, g),
                       sm, causal=False)[:, :, 0, :]
    return ops.linear(o.reshape(b, hq * hd).astype(x_t.dtype), p["wo"])

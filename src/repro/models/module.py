"""Minimal functional parameter system (no flax in this environment).

Models are described as trees of :class:`ParamSpec` (shape, dtype, logical
axes, initializer).  One spec tree serves three masters:

* ``initialize``     — real arrays for smoke tests / small training runs;
* ``abstract``       — ``ShapeDtypeStruct`` leaves for the multi-pod dry-run
                       (lower + compile with zero allocation);
* ``partition_specs``— logical-axis names -> ``PartitionSpec`` via a rules
                       table (see repro.distributed.sharding).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: Tuple[Optional[str], ...] = ()
    init: str = "fan_in"          # fan_in | normal | zeros | ones | embed | small
    scale: float = 1.0

    def __post_init__(self):
        if self.axes:
            if len(self.axes) != len(self.shape):
                raise ValueError(
                    f"axes {self.axes} do not match shape {self.shape}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map_with_path(fn: Callable[[str, ParamSpec], Any], tree: Any) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)[0]
    treedef = jax.tree_util.tree_structure(tree, is_leaf=is_spec)
    out = []
    for path, leaf in flat:
        p = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append(fn(p, leaf) if is_spec(leaf) else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract(tree: Any) -> Any:
    return _map_with_path(
        lambda p, s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def _init_leaf(path: str, spec: ParamSpec, root_key: jax.Array) -> jax.Array:
    seed = int.from_bytes(hashlib.md5(path.encode()).digest()[:4], "little")
    key = jax.random.fold_in(root_key, seed)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * 0.02 * spec.scale).astype(spec.dtype)
    if spec.init == "small":
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * 1e-2 * spec.scale).astype(spec.dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * spec.scale).astype(spec.dtype)
    # fan_in: variance-scaling on the second-to-last dim (matmul RHS [K, N])
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / max(fan_in, 1) ** 0.5
    return (jax.random.normal(key, spec.shape, jnp.float32)
            * std).astype(spec.dtype)


def initialize(tree: Any, key: jax.Array) -> Any:
    return _map_with_path(lambda p, s: _init_leaf(p, s, key), tree)


def partition_specs(tree: Any, rules: Dict[str, Any]) -> Any:
    """Logical axes -> PartitionSpec; first use of a mesh axis wins per leaf."""
    def one(path: str, spec: ParamSpec) -> PartitionSpec:
        used = set()
        out = []
        for ax in (spec.axes or (None,) * len(spec.shape)):
            mesh_ax = rules.get(ax) if ax is not None else None
            if mesh_ax is None:
                out.append(None)
                continue
            flat_ax = tuple(mesh_ax) if isinstance(mesh_ax, (tuple, list)) \
                else (mesh_ax,)
            keep = tuple(a for a in flat_ax if a not in used)
            used.update(keep)
            if not keep:
                out.append(None)
            else:
                out.append(keep if len(keep) > 1 else keep[0])
        return PartitionSpec(*out)
    return _map_with_path(one, tree)


def param_count(tree: Any) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    total = 0
    for l in leaves:
        if is_spec(l):
            n = 1
            for d in l.shape:
                n *= d
            total += n
        elif hasattr(l, "size"):
            total += l.size
    return total

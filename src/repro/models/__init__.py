"""Model zoo: functional param-spec models for all assigned architectures."""
from . import module
from .module import ParamSpec, abstract, initialize, partition_specs
from .lm import (model_specs, abstract_params, init_params, forward_train,
                 forward_decode, init_cache, logits_fn, period_len,
                 layer_kind)

"""Request scheduler for the continuous-batching engine.

Pure host-side bookkeeping — no jax in here.  The scheduler owns the
request lifecycle (queued -> prefilling -> decoding -> finished), maps live
requests onto cache-pool slots, splits prompts into block-aligned prefill
chunks, and recycles slots on completion.  The engine asks it three
questions per tick: *which request gets a prefill chunk*, *which slots
decode*, and *who is finished*.

Every request carries its own :class:`~repro.serving.sampling.SamplingParams`
— the scheduler enforces the host-side half of that contract (eos / stop
sequences / max_new_tokens => ``finish_reason``); the device-side half
(temperature / top-k / top-p / seeded RNG) lives in the engine's sampling
lanes.

Admission control: a request is only admitted when a slot is free AND its
worst-case context (prompt + max_new_tokens) fits the pool's per-slot
token capacity — the refreeze scatter is unguarded on device, so the
scheduler is the component that makes overflow impossible.

Fault tolerance (PR 8) adds three lifecycle exits that are *not* normal
completion, all host-side:

* **shed** — ``max_queue`` bounds the admission queue; a submit past the
  bound is rejected immediately with ``finish_reason="shed"`` (reject-new
  before degrading live traffic — the request never holds a slot or page).
* **timeout** — per-request deadlines (``SamplingParams.deadline_s`` /
  ``ttft_deadline_s``) are enforced by :meth:`expire` at tick boundaries;
  an expired request finishes with ``finish_reason="timeout"``.  A stop
  committed by :meth:`record_tokens` always beats a *later* deadline
  check — deadlines only fire on still-unfinished requests.
* **cancelled** — :meth:`cancel` removes a request wherever it lives
  (queued / prefilling / decoding) with ``finish_reason="cancelled"``.

Deferred admissions (paged-pool reservation failure) requeue with
exponential backoff: :meth:`defer_admission` stamps the queue head's
``next_admit``, and :meth:`admit` refuses to admit it early.  Backoff is
head-of-line only, so FIFO order is preserved.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from .sampling import RequestMetrics, RequestOutput, SamplingParams


@dataclasses.dataclass
class Request:
    """One generation request: immutable contract + scheduler-owned state."""
    rid: int
    prompt: List[int]
    params: SamplingParams
    # -- lifecycle state (scheduler-owned) --
    slot: int = -1
    prefill_done: int = 0            # prompt tokens already chunk-prefilled
    generated: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[Optional[float]] = dataclasses.field(default_factory=list)
    # None | "stop" | "length" | "shed" | "timeout" | "cancelled"
    finish_reason: Optional[str] = None
    arrival_time: float = 0.0
    admitted_time: Optional[float] = None    # queue -> pool slot
    first_token_time: Optional[float] = None
    finished_time: Optional[float] = None
    decode_ticks: int = 0            # engine decode steps consumed
    next_admit: float = 0.0          # earliest admit time (backoff requeue)
    backoff_s: float = 0.0           # current backoff interval

    @property
    def finished(self) -> bool:
        return self.finish_reason is not None

    @property
    def decoding(self) -> bool:
        return (self.slot >= 0 and not self.finished
                and self.prefill_done >= len(self.prompt))

    def output(self) -> RequestOutput:
        """Immutable snapshot of the current generation state."""
        return RequestOutput(
            request_id=self.rid,
            prompt_token_ids=tuple(self.prompt),
            token_ids=tuple(self.generated),
            finish_reason=self.finish_reason,
            metrics=RequestMetrics(self.arrival_time, self.first_token_time,
                                   self.finished_time,
                                   decode_ticks=self.decode_ticks,
                                   num_generated=len(self.generated),
                                   admitted_time=self.admitted_time),
            logprobs=tuple(self.logprobs))


def block_hashes(tokens: Sequence[int], bs: int) -> List[int]:
    """Chained content hashes of ``tokens``' full ``bs``-token blocks.

    ``h[i] = hash((h[i-1], block_i))`` — each hash commits to the ENTIRE
    token prefix up to its block's end, so a flat ``hash -> block id`` dict
    behaves exactly like a prefix trie: two prompts share hash ``i`` iff
    their first ``(i + 1) * bs`` tokens are identical.  The trailing
    partial block (if any) is not hashed — only frozen, block-aligned
    content is shareable.
    """
    out: List[int] = []
    parent = bs                      # domain-separate from user token values
    for i in range(len(tokens) // bs):
        parent = hash((parent, tuple(tokens[i * bs:(i + 1) * bs])))
        out.append(parent)
    return out


class PrefixTrie:
    """Host-side prefix index: chained block hash -> physical block id.

    Because the hashes chain (see :func:`block_hashes`), a flat dict IS a
    trie — :meth:`match` walks a prompt's hash list until the first miss,
    which is the longest shared block-aligned prefix already frozen in the
    arena.  The trie never owns blocks: the :class:`BlockAllocator` does
    refcounting/eviction and calls :meth:`drop` (via its ``on_evict``
    callback) when a cached block's storage is reclaimed.
    """

    def __init__(self) -> None:
        self._map: Dict[int, int] = {}

    def match(self, hashes: Sequence[int]) -> List[int]:
        """Physical ids of the longest indexed prefix of ``hashes``."""
        ids: List[int] = []
        for h in hashes:
            bid = self._map.get(h)
            if bid is None:
                break
            ids.append(bid)
        return ids

    def insert(self, h: int, bid: int) -> None:
        self._map.setdefault(h, bid)     # first writer wins

    def drop(self, h: int) -> None:
        self._map.pop(h, None)

    def reload(self, items) -> None:
        """Replace the whole index (warm-restart restore).  In place, so
        bound callbacks (the allocator's ``on_evict``) keep pointing at
        the live object."""
        self._map = dict(items)

    def items(self):
        return self._map.items()

    def __len__(self) -> int:
        return len(self._map)


def _matches_stop(generated: List[int],
                  stop_ids: Sequence[Sequence[int]]) -> bool:
    """True if the generated tail equals any stop sequence."""
    return any(len(generated) >= len(s)
               and generated[len(generated) - len(s):] == list(s)
               for s in stop_ids)


class Scheduler:
    """Maps requests onto ``slots`` pool slots with chunked prefill.

    ``chunk`` is the max prompt tokens prefill processes per engine tick
    (rounded down to a block multiple for every chunk but the last, so the
    pool's frozen prefix stays block-aligned).  ``capacity_tokens`` is the
    pool's per-slot limit used for admission.  ``max_queue`` bounds the
    admission queue (0 = unbounded): a submit past the bound is shed.
    ``backoff_base`` / ``backoff_cap`` shape the exponential requeue delay
    applied by :meth:`defer_admission`.
    """

    def __init__(self, slots: int, capacity_tokens: int, bs: int,
                 chunk: Optional[int] = None,
                 clock=time.monotonic, max_queue: int = 0,
                 backoff_base: float = 0.005, backoff_cap: float = 0.25):
        if chunk is not None and chunk < bs:
            raise ValueError(f"prefill chunk {chunk} < block size {bs}")
        self.slots = slots
        self.capacity_tokens = capacity_tokens
        self.bs = bs
        self.chunk = (chunk // bs * bs) if chunk else None
        self.clock = clock
        self.max_queue = max_queue
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.finished: Dict[int, Request] = {}        # rid -> request
        self._next_rid = 0
        # sheds happen HERE (the queue bound is scheduler state), so the
        # scheduler owns the authoritative count; layers above mirror it
        # instead of incrementing their own, which keeps shed accounting
        # single-sourced no matter how many frontends submit
        self.shed_count = 0

    # -- submission ---------------------------------------------------------
    def submit(self, prompt: List[int],
               params: Optional[SamplingParams] = None) -> int:
        """Queue a request; returns its id.  Raises if it can never fit.

        With ``max_queue`` set and the queue full, the request is **shed**:
        it goes straight to ``finished`` with ``finish_reason="shed"``,
        holding no slot, no pages, and no queue position — load shedding
        rejects new work before it can degrade live traffic.  Callers
        distinguish the outcome by the returned request's finish reason,
        not by an exception (shedding is a normal overload response).
        """
        params = params if params is not None else SamplingParams()
        if not prompt:
            raise ValueError("empty prompt")
        need = len(prompt) + params.max_new_tokens
        if need > self.capacity_tokens:
            raise ValueError(
                f"request needs {need} tokens; pool slots hold "
                f"{self.capacity_tokens}")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        req = Request(rid, list(prompt), params, arrival_time=now)
        if self.max_queue and len(self.queue) >= self.max_queue:
            # shed at submit time: admitted_time stays None (the request
            # was never admitted — queue-time metrics must not invent a
            # zero-length admission) and the scheduler's own counter is
            # the one counter path
            req.finish_reason = "shed"
            req.finished_time = now
            self.finished[rid] = req
            self.shed_count += 1
        else:
            self.queue.append(req)
        return rid

    # -- per-tick queries ---------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def admit(self, now: Optional[float] = None) -> Optional[Request]:
        """Move the oldest queued request into a free slot (if any).

        A head backing off after :meth:`defer_admission` is not admitted
        before its ``next_admit`` time — and, to keep FIFO order, nothing
        behind it is either.
        """
        now = self.clock() if now is None else now
        if not self.queue:
            return None
        if self.queue[0].next_admit > now:
            return None
        free = self.free_slots()
        if not free:
            return None
        req = self.queue.popleft()
        req.slot = free[0]
        req.admitted_time = now
        self.active[req.slot] = req
        return req

    def defer_admission(self, now: Optional[float] = None) -> float:
        """Back off the queue head after a failed admission attempt (paged
        page-reservation shortfall).  Doubles the head's backoff interval
        (from ``backoff_base`` up to ``backoff_cap``) and stamps its
        ``next_admit``; returns the interval.  Head-of-line only — FIFO
        order is preserved, later requests simply wait behind the head.
        """
        now = self.clock() if now is None else now
        req = self.queue[0]
        req.backoff_s = min(self.backoff_cap,
                            max(self.backoff_base, req.backoff_s * 2))
        req.next_admit = now + req.backoff_s
        return req.backoff_s

    # -- lifecycle exits ----------------------------------------------------
    def _finish_abnormal(self, req: Request, reason: str,
                         now: float) -> None:
        req.finish_reason = reason
        req.finished_time = now
        self.finished[req.rid] = req

    def cancel(self, rid: int, now: Optional[float] = None
               ) -> Optional[Request]:
        """Cancel a request wherever it lives; returns it if state changed.

        Queued: removed from the queue.  Active (prefilling or decoding):
        removed from ``active`` — the caller owns releasing its slot
        (``req.slot >= 0`` distinguishes this case).  Already finished
        (or unknown rid): no-op, returns ``None`` — cancellation racing
        normal completion loses quietly.
        """
        now = self.clock() if now is None else now
        for req in self.queue:
            if req.rid == rid:
                self.queue.remove(req)
                self._finish_abnormal(req, "cancelled", now)
                return req
        for slot, req in list(self.active.items()):
            if req.rid == rid:
                del self.active[slot]
                self._finish_abnormal(req, "cancelled", now)
                return req
        return None

    def expire(self, now: Optional[float] = None) -> List[Request]:
        """Finish every request whose deadline has passed with
        ``finish_reason="timeout"``; returns them (callers release the
        slots of those with ``req.slot >= 0``).

        Two deadlines per request, both measured from arrival:
        ``params.ttft_deadline_s`` fires only while no token has been
        produced; ``params.deadline_s`` bounds total wall clock.  Queued
        requests expire too (a request that waited out its whole deadline
        in the queue never deserves a slot).  Runs at tick *start*, so a
        stop committed last tick already finished the request — committed
        output always beats a later deadline check.
        """
        now = self.clock() if now is None else now
        expired: List[Request] = []
        for slot, req in list(self.active.items()):
            if self._deadline_passed(req, now):
                del self.active[slot]
                self._finish_abnormal(req, "timeout", now)
                expired.append(req)
        for req in list(self.queue):
            if self._deadline_passed(req, now):
                self.queue.remove(req)
                self._finish_abnormal(req, "timeout", now)
                expired.append(req)
        return expired

    @staticmethod
    def _deadline_passed(req: Request, now: float) -> bool:
        p = req.params
        waited = now - req.arrival_time
        if p.deadline_s is not None and waited >= p.deadline_s:
            return True
        return (p.ttft_deadline_s is not None
                and req.first_token_time is None
                and waited >= p.ttft_deadline_s)

    def next_prefill(self) -> Optional[Request]:
        """The request owed a prefill chunk this tick (oldest first)."""
        for req in sorted(self.active.values(), key=lambda r: r.rid):
            if req.prefill_done < len(req.prompt):
                return req
        return None

    def prefill_chunk(self, req: Request) -> List[int]:
        """Slice the next chunk off ``req``'s prompt and mark it done.

        Every chunk except the last is a multiple of ``bs`` (the frozen
        prefix grows whole blocks); the final chunk carries the remainder
        into the dense tail.
        """
        left = len(req.prompt) - req.prefill_done
        take = left if self.chunk is None else min(self.chunk, left)
        if take < left:                   # not final: keep block-aligned
            take = take // self.bs * self.bs
        chunk = req.prompt[req.prefill_done:req.prefill_done + take]
        req.prefill_done += take
        return chunk

    def decoding_slots(self) -> List[int]:
        return [s for s, r in self.active.items() if r.decoding]

    # -- completion ---------------------------------------------------------
    def record_token(self, slot: int, token: int,
                     logprob: Optional[float] = None) -> Optional[str]:
        """Single-token convenience wrapper over :meth:`record_tokens`."""
        return self.record_tokens(
            slot, [token], None if logprob is None else [logprob])

    def record_tokens(self, slot: int, tokens: Sequence[int],
                      logprobs: Optional[Sequence[Optional[float]]] = None,
                      decode_tick: bool = True) -> Optional[str]:
        """Commit the window of tokens one engine tick produced for a slot
        (one token on the plain path; up to K+1 under speculation).

        The stop scan runs *inside* the window: each token is appended and
        checked in order, and the first eos / stop-sequence / budget hit
        truncates the commit — tokens past it are discarded, exactly as if
        the non-speculative engine had stopped there (speculatively
        verified tokens crossing a stop must never leak into the output).
        A stop hit on the budget's last token wins over "length".

        Returns the finish reason (``"stop"`` | ``"length"`` | None);
        finishing releases the slot for re-admission.  ``decode_tick=False``
        (prefill's first token) leaves the tick counter untouched so
        ``accepted_per_tick`` measures decode work only.  ``logprobs`` are
        the device sampler's chosen-token log-probabilities (surfaced on
        ``RequestOutput.logprobs``); host-only callers may omit them.
        """
        req = self.active[slot]
        now = self.clock()
        if req.first_token_time is None:
            req.first_token_time = now
        if decode_tick:
            req.decode_ticks += 1
        p = req.params
        reason = None
        for i, token in enumerate(tokens):
            token = int(token)
            req.generated.append(token)
            req.logprobs.append(None if logprobs is None else logprobs[i])
            if ((p.eos_id is not None and token == p.eos_id)
                    or _matches_stop(req.generated, p.stop_ids)):
                reason = "stop"
            elif len(req.generated) >= p.max_new_tokens:
                reason = "length"
            if reason is not None:
                break                      # truncate: drop the window's rest
        if reason is not None:
            req.finish_reason = reason
            req.finished_time = now
            del self.active[slot]
            self.finished[req.rid] = req
        return reason

    def done(self) -> bool:
        return not self.queue and not self.active

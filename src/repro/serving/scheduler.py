"""Request scheduler for the continuous-batching engine.

Pure host-side bookkeeping — no jax in here.  The scheduler owns the
request lifecycle (queued -> prefilling -> decoding -> finished), maps live
requests onto cache-pool slots, splits prompts into block-aligned prefill
chunks, and recycles slots on completion.  The engine asks it three
questions per tick: *which request gets a prefill chunk*, *which slots
decode*, and *who is finished*.

Every request carries its own :class:`~repro.serving.sampling.SamplingParams`
— the scheduler enforces the host-side half of that contract (eos / stop
sequences / max_new_tokens => ``finish_reason``); the device-side half
(temperature / top-k / top-p / seeded RNG) lives in the engine's sampling
lanes.

Admission control: a request is only admitted when a slot is free AND its
worst-case context (prompt + max_new_tokens) fits the pool's per-slot
token capacity — the refreeze scatter is unguarded on device, so the
scheduler is the component that makes overflow impossible.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from .sampling import RequestMetrics, RequestOutput, SamplingParams


@dataclasses.dataclass
class Request:
    """One generation request: immutable contract + scheduler-owned state."""
    rid: int
    prompt: List[int]
    params: SamplingParams
    # -- lifecycle state (scheduler-owned) --
    slot: int = -1
    prefill_done: int = 0            # prompt tokens already chunk-prefilled
    generated: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[Optional[float]] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None        # None | "stop" | "length"
    arrival_time: float = 0.0
    first_token_time: Optional[float] = None
    finished_time: Optional[float] = None
    decode_ticks: int = 0            # engine decode steps consumed

    @property
    def finished(self) -> bool:
        return self.finish_reason is not None

    @property
    def decoding(self) -> bool:
        return (self.slot >= 0 and not self.finished
                and self.prefill_done >= len(self.prompt))

    def output(self) -> RequestOutput:
        """Immutable snapshot of the current generation state."""
        return RequestOutput(
            request_id=self.rid,
            prompt_token_ids=tuple(self.prompt),
            token_ids=tuple(self.generated),
            finish_reason=self.finish_reason,
            metrics=RequestMetrics(self.arrival_time, self.first_token_time,
                                   self.finished_time,
                                   decode_ticks=self.decode_ticks,
                                   num_generated=len(self.generated)),
            logprobs=tuple(self.logprobs))


def block_hashes(tokens: Sequence[int], bs: int) -> List[int]:
    """Chained content hashes of ``tokens``' full ``bs``-token blocks.

    ``h[i] = hash((h[i-1], block_i))`` — each hash commits to the ENTIRE
    token prefix up to its block's end, so a flat ``hash -> block id`` dict
    behaves exactly like a prefix trie: two prompts share hash ``i`` iff
    their first ``(i + 1) * bs`` tokens are identical.  The trailing
    partial block (if any) is not hashed — only frozen, block-aligned
    content is shareable.
    """
    out: List[int] = []
    parent = bs                      # domain-separate from user token values
    for i in range(len(tokens) // bs):
        parent = hash((parent, tuple(tokens[i * bs:(i + 1) * bs])))
        out.append(parent)
    return out


class PrefixTrie:
    """Host-side prefix index: chained block hash -> physical block id.

    Because the hashes chain (see :func:`block_hashes`), a flat dict IS a
    trie — :meth:`match` walks a prompt's hash list until the first miss,
    which is the longest shared block-aligned prefix already frozen in the
    arena.  The trie never owns blocks: the :class:`BlockAllocator` does
    refcounting/eviction and calls :meth:`drop` (via its ``on_evict``
    callback) when a cached block's storage is reclaimed.
    """

    def __init__(self) -> None:
        self._map: Dict[int, int] = {}

    def match(self, hashes: Sequence[int]) -> List[int]:
        """Physical ids of the longest indexed prefix of ``hashes``."""
        ids: List[int] = []
        for h in hashes:
            bid = self._map.get(h)
            if bid is None:
                break
            ids.append(bid)
        return ids

    def insert(self, h: int, bid: int) -> None:
        self._map.setdefault(h, bid)     # first writer wins

    def drop(self, h: int) -> None:
        self._map.pop(h, None)

    def __len__(self) -> int:
        return len(self._map)


def _matches_stop(generated: List[int],
                  stop_ids: Sequence[Sequence[int]]) -> bool:
    """True if the generated tail equals any stop sequence."""
    return any(len(generated) >= len(s)
               and generated[len(generated) - len(s):] == list(s)
               for s in stop_ids)


class Scheduler:
    """Maps requests onto ``slots`` pool slots with chunked prefill.

    ``chunk`` is the max prompt tokens prefill processes per engine tick
    (rounded down to a block multiple for every chunk but the last, so the
    pool's frozen prefix stays block-aligned).  ``capacity_tokens`` is the
    pool's per-slot limit used for admission.
    """

    def __init__(self, slots: int, capacity_tokens: int, bs: int,
                 chunk: Optional[int] = None,
                 clock=time.monotonic):
        assert chunk is None or chunk >= bs, (chunk, bs)
        self.slots = slots
        self.capacity_tokens = capacity_tokens
        self.bs = bs
        self.chunk = (chunk // bs * bs) if chunk else None
        self.clock = clock
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.finished: Dict[int, Request] = {}        # rid -> request
        self._next_rid = 0

    # -- submission ---------------------------------------------------------
    def submit(self, prompt: List[int],
               params: Optional[SamplingParams] = None) -> int:
        """Queue a request; returns its id.  Raises if it can never fit."""
        params = params if params is not None else SamplingParams()
        if not prompt:
            raise ValueError("empty prompt")
        need = len(prompt) + params.max_new_tokens
        if need > self.capacity_tokens:
            raise ValueError(
                f"request needs {need} tokens; pool slots hold "
                f"{self.capacity_tokens}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), params,
                                  arrival_time=self.clock()))
        return rid

    # -- per-tick queries ---------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def admit(self) -> Optional[Request]:
        """Move the oldest queued request into a free slot (if any)."""
        if not self.queue:
            return None
        free = self.free_slots()
        if not free:
            return None
        req = self.queue.popleft()
        req.slot = free[0]
        self.active[req.slot] = req
        return req

    def next_prefill(self) -> Optional[Request]:
        """The request owed a prefill chunk this tick (oldest first)."""
        for req in sorted(self.active.values(), key=lambda r: r.rid):
            if req.prefill_done < len(req.prompt):
                return req
        return None

    def prefill_chunk(self, req: Request) -> List[int]:
        """Slice the next chunk off ``req``'s prompt and mark it done.

        Every chunk except the last is a multiple of ``bs`` (the frozen
        prefix grows whole blocks); the final chunk carries the remainder
        into the dense tail.
        """
        left = len(req.prompt) - req.prefill_done
        take = left if self.chunk is None else min(self.chunk, left)
        if take < left:                   # not final: keep block-aligned
            take = take // self.bs * self.bs
        chunk = req.prompt[req.prefill_done:req.prefill_done + take]
        req.prefill_done += take
        return chunk

    def decoding_slots(self) -> List[int]:
        return [s for s, r in self.active.items() if r.decoding]

    # -- completion ---------------------------------------------------------
    def record_token(self, slot: int, token: int,
                     logprob: Optional[float] = None) -> Optional[str]:
        """Single-token convenience wrapper over :meth:`record_tokens`."""
        return self.record_tokens(
            slot, [token], None if logprob is None else [logprob])

    def record_tokens(self, slot: int, tokens: Sequence[int],
                      logprobs: Optional[Sequence[Optional[float]]] = None,
                      decode_tick: bool = True) -> Optional[str]:
        """Commit the window of tokens one engine tick produced for a slot
        (one token on the plain path; up to K+1 under speculation).

        The stop scan runs *inside* the window: each token is appended and
        checked in order, and the first eos / stop-sequence / budget hit
        truncates the commit — tokens past it are discarded, exactly as if
        the non-speculative engine had stopped there (speculatively
        verified tokens crossing a stop must never leak into the output).
        A stop hit on the budget's last token wins over "length".

        Returns the finish reason (``"stop"`` | ``"length"`` | None);
        finishing releases the slot for re-admission.  ``decode_tick=False``
        (prefill's first token) leaves the tick counter untouched so
        ``accepted_per_tick`` measures decode work only.  ``logprobs`` are
        the device sampler's chosen-token log-probabilities (surfaced on
        ``RequestOutput.logprobs``); host-only callers may omit them.
        """
        req = self.active[slot]
        now = self.clock()
        if req.first_token_time is None:
            req.first_token_time = now
        if decode_tick:
            req.decode_ticks += 1
        p = req.params
        reason = None
        for i, token in enumerate(tokens):
            token = int(token)
            req.generated.append(token)
            req.logprobs.append(None if logprobs is None else logprobs[i])
            if ((p.eos_id is not None and token == p.eos_id)
                    or _matches_stop(req.generated, p.stop_ids)):
                reason = "stop"
            elif len(req.generated) >= p.max_new_tokens:
                reason = "length"
            if reason is not None:
                break                      # truncate: drop the window's rest
        if reason is not None:
            req.finish_reason = reason
            req.finished_time = now
            del self.active[slot]
            self.finished[req.rid] = req
        return reason

    def done(self) -> bool:
        return not self.queue and not self.active

"""Request scheduler for the continuous-batching engine.

Pure host-side bookkeeping — no jax in here.  The scheduler owns the
request lifecycle (queued -> prefilling -> decoding -> finished), maps live
requests onto cache-pool slots, splits prompts into block-aligned prefill
chunks, and recycles slots on EOS / length exhaustion.  The engine asks it
three questions per tick: *which request gets a prefill chunk*, *which
slots decode*, and *who is finished*.

Admission control: a request is only admitted when a slot is free AND its
worst-case context (prompt + max_new_tokens) fits the pool's per-slot
token capacity — the refreeze scatter is unguarded on device, so the
scheduler is the component that makes overflow impossible.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class Request:
    """One generation request."""
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    # -- lifecycle state (scheduler-owned) --
    slot: int = -1
    prefill_done: int = 0            # prompt tokens already chunk-prefilled
    generated: List[int] = dataclasses.field(default_factory=list)
    finished: bool = False

    @property
    def decoding(self) -> bool:
        return (self.slot >= 0 and not self.finished
                and self.prefill_done >= len(self.prompt))


class Scheduler:
    """Maps requests onto ``slots`` pool slots with chunked prefill.

    ``chunk`` is the max prompt tokens prefill processes per engine tick
    (rounded down to a block multiple for every chunk but the last, so the
    pool's frozen prefix stays block-aligned).  ``capacity_tokens`` is the
    pool's per-slot limit used for admission.
    """

    def __init__(self, slots: int, capacity_tokens: int, bs: int,
                 chunk: Optional[int] = None):
        assert chunk is None or chunk >= bs, (chunk, bs)
        self.slots = slots
        self.capacity_tokens = capacity_tokens
        self.bs = bs
        self.chunk = (chunk // bs * bs) if chunk else None
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}          # slot -> request
        self.finished: Dict[int, Request] = {}        # rid -> request
        self._next_rid = 0

    # -- submission ---------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        """Queue a request; returns its id.  Raises if it can never fit."""
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        need = len(prompt) + max_new_tokens
        if need > self.capacity_tokens:
            raise ValueError(
                f"request needs {need} tokens; pool slots hold "
                f"{self.capacity_tokens}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), max_new_tokens, eos_id))
        return rid

    # -- per-tick queries ---------------------------------------------------
    def free_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self.active]

    def admit(self) -> Optional[Request]:
        """Move the oldest queued request into a free slot (if any)."""
        if not self.queue:
            return None
        free = self.free_slots()
        if not free:
            return None
        req = self.queue.popleft()
        req.slot = free[0]
        self.active[req.slot] = req
        return req

    def next_prefill(self) -> Optional[Request]:
        """The request owed a prefill chunk this tick (oldest first)."""
        for req in sorted(self.active.values(), key=lambda r: r.rid):
            if req.prefill_done < len(req.prompt):
                return req
        return None

    def prefill_chunk(self, req: Request) -> List[int]:
        """Slice the next chunk off ``req``'s prompt and mark it done.

        Every chunk except the last is a multiple of ``bs`` (the frozen
        prefix grows whole blocks); the final chunk carries the remainder
        into the dense tail.
        """
        left = len(req.prompt) - req.prefill_done
        take = left if self.chunk is None else min(self.chunk, left)
        if take < left:                   # not final: keep block-aligned
            take = take // self.bs * self.bs
        chunk = req.prompt[req.prefill_done:req.prefill_done + take]
        req.prefill_done += take
        return chunk

    def decoding_slots(self) -> List[int]:
        return [s for s, r in self.active.items() if r.decoding]

    # -- completion ---------------------------------------------------------
    def record_token(self, slot: int, token: int) -> bool:
        """Append a generated token; returns True if the request finished
        (EOS or max_new_tokens) and its slot should be released."""
        req = self.active[slot]
        req.generated.append(token)
        if ((req.eos_id is not None and token == req.eos_id)
                or len(req.generated) >= req.max_new_tokens):
            req.finished = True
            del self.active[slot]
            self.finished[req.rid] = req
            return True
        return False

    def done(self) -> bool:
        return not self.queue and not self.active

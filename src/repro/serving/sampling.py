"""Request-level sampling: ``SamplingParams`` in, ``RequestOutput`` out,
and the jit-stable on-device sampler between them.

The serving engines treat decoding as *sampling lanes*: every cache-pool
slot carries its own ``temperature`` / ``top_k`` / ``top_p`` scalar and a
``[2]`` uint32 RNG key inside the engine's device state, so requests with
heterogeneous :class:`SamplingParams` coexist in one batched decode step.
Everything in here is shape-static — lanes are ``[slots]`` vectors that are
*written*, never re-shaped, so admitting a request with new params is an
``at[slot].set`` and the jitted step never retraces.

Key discipline (what makes seeded sampling reproducible): a request's lane
key is ``PRNGKey(params.seed)``, split **on device** once per sampled
token — at the final prefill chunk (first token) and at every decode tick
after that.  The key never mixes in the slot index or co-tenant state, so
the same request produces the same tokens no matter which slot it lands in
or who it shares the batch with.

``temperature == 0`` lanes bypass the categorical entirely and reduce to
exactly ``jnp.argmax(logits, -1).astype(int32)`` — bit-identical to the
greedy-only engine this API replaces.

Two per-tick cost notes: top-k/top-p masking is sort-free on the hot path
(a ``lax.top_k`` bucket of :data:`TOPP_BUCKET` entries replaces the full
``[slots, V]`` sort; an in-trace ``lax.cond`` keeps the exact full-sort
branch for lanes with unbounded support), and the sampler also returns a
``[slots]`` chosen-token logprob lane so ``RequestOutput.logprobs`` costs
no extra device round trip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# request-level API objects
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding contract.

    temperature: 0 = greedy (exact argmax); > 0 scales logits before the
      categorical draw.
    top_k: keep only the k highest logits (0 = disabled).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
      distribution whose mass reaches ``top_p`` (1.0 = disabled).
    seed: per-request RNG seed; same seed => same tokens, regardless of
      slot placement or batch co-tenants.
    max_new_tokens: generation budget (includes the first token sampled
      from the prompt's last logits).
    eos_id: single stop token (finish_reason "stop").
    stop_ids: stop *sequences* — each entry is a token-id tuple (a bare int
      means a 1-token sequence); generation finishes when the generated
      tail matches one.  Stop tokens are included in the output.
    deadline_s: total wall-clock budget from arrival (None = unbounded).
      Enforced host-side at tick boundaries; an expired request finishes
      with ``finish_reason="timeout"``.  A stop committed before the
      deadline check always wins (output already produced is never
      retroactively timed out).
    ttft_deadline_s: first-token budget from arrival (None = unbounded) —
      fires only while the request has produced no token, so a request
      that started streaming is governed by ``deadline_s`` alone.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    stop_ids: Tuple[Tuple[int, ...], ...] = ()
    deadline_s: Optional[float] = None
    ttft_deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0: {self.deadline_s}")
        if self.ttft_deadline_s is not None and self.ttft_deadline_s <= 0:
            raise ValueError(
                f"ttft_deadline_s must be > 0: {self.ttft_deadline_s}")
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0: {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1: {self.max_new_tokens}")
        norm = tuple(
            (int(s),) if isinstance(s, int) else tuple(int(t) for t in s)
            for s in self.stop_ids)
        if any(not s for s in norm):
            raise ValueError("empty stop sequence")
        object.__setattr__(self, "stop_ids", norm)

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    """Wall-clock timing + decode accounting of one request
    (``time.monotonic`` seconds).

    ``decode_ticks`` counts engine decode steps, ``num_generated`` the
    tokens actually committed — under speculative decoding one verify tick
    commits a whole accepted window, so throughput must be derived from
    tokens committed, never from ticks (the old one-token-per-tick
    assumption undercounts spec runs by the acceptance factor).

    ``admitted_time`` is when the scheduler moved the request from the
    queue into a pool slot — every timestamp here is observable at the
    engine's tick-boundary sync point, so the TTFT splits cleanly into
    ``queue_time`` (submit → slot) and ``prefill_time`` (slot → first
    token) with no extra device traffic.  Requests that die in the queue
    (shed, queued-timeout) leave it ``None``.
    """
    arrival_time: float
    first_token_time: Optional[float]
    finished_time: Optional[float]
    decode_ticks: int = 0
    num_generated: int = 0
    admitted_time: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (queue wait + prefill)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def queue_time(self) -> Optional[float]:
        """Submit → slot admission."""
        if self.admitted_time is None:
            return None
        return self.admitted_time - self.arrival_time

    @property
    def prefill_time(self) -> Optional[float]:
        """Slot admission → first token (chunked prefill wall time)."""
        if self.admitted_time is None or self.first_token_time is None:
            return None
        return self.first_token_time - self.admitted_time

    @property
    def decode_time(self) -> Optional[float]:
        """First token → finish."""
        if self.first_token_time is None or self.finished_time is None:
            return None
        return self.finished_time - self.first_token_time

    @property
    def tpot(self) -> Optional[float]:
        """Per-output-token latency after the first token (the SLO
        counterpart of :attr:`decode_tok_s`)."""
        if (self.finished_time is None or self.first_token_time is None
                or self.num_generated <= 1):
            return None
        return ((self.finished_time - self.first_token_time)
                / (self.num_generated - 1))

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.finished_time is None:
            return None
        return self.finished_time - self.arrival_time

    @property
    def accepted_per_tick(self) -> Optional[float]:
        """Mean tokens committed per decode tick (the first token comes
        from prefill, not a decode tick).  1.0 on the non-speculative
        path; up to K+1 under draft–verify speculation."""
        if self.decode_ticks <= 0:
            return None
        return (self.num_generated - 1) / self.decode_ticks

    @property
    def decode_tok_s(self) -> Optional[float]:
        """True decode throughput: tokens *committed* after the first over
        the decode wall-clock window."""
        if (self.finished_time is None or self.first_token_time is None
                or self.num_generated <= 1):
            return None
        dt = self.finished_time - self.first_token_time
        if dt <= 0:
            return None
        return (self.num_generated - 1) / dt


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """Snapshot of one request's generation state.

    Streaming yields one per emitted token (``finish_reason is None`` while
    running); ``ContinuousEngine.run`` returns the final one per request.

    ``logprobs[i]`` is the chosen-token log-probability of ``token_ids[i]``
    under the model's *unmodified* distribution (``log_softmax(logits)`` —
    before temperature / top-k / top-p shaping), carried out of the jitted
    sampler as one extra ``[slots]`` lane per tick.  Entries are ``None``
    only when the producer recorded tokens without logprobs (host-only
    scheduler tests).
    """
    request_id: int
    prompt_token_ids: Tuple[int, ...]
    token_ids: Tuple[int, ...]
    # None while running; "stop" | "length" on normal completion;
    # "shed" | "timeout" | "cancelled" on the fault-tolerant exits
    finish_reason: Optional[str]
    metrics: RequestMetrics
    logprobs: Tuple[Optional[float], ...] = ()

    @property
    def finished(self) -> bool:
        return self.finish_reason is not None


# ---------------------------------------------------------------------------
# sampling lanes (device state)
# ---------------------------------------------------------------------------

def init_lanes(slots: int) -> Dict[str, jax.Array]:
    """Zeroed lane state: every slot starts greedy with a null key."""
    return {
        "temperature": jnp.zeros((slots,), jnp.float32),
        "top_k": jnp.zeros((slots,), jnp.int32),
        "top_p": jnp.ones((slots,), jnp.float32),
        "rng": jnp.zeros((slots, 2), jnp.uint32),
    }


def lane_axes() -> Dict[str, tuple]:
    """Logical-axes pytree matching :func:`init_lanes` — the lanes' own
    sharding description (slots over the data axes; the RNG key's trailing
    pair stays together).  Consumed by ``distributed/serving_sharding``."""
    return {
        "temperature": ("slots",),
        "top_k": ("slots",),
        "top_p": ("slots",),
        "rng": ("slots", None),
    }


def request_key(params: SamplingParams) -> jax.Array:
    """The per-request RNG lane seed — deliberately slot-independent."""
    return jax.random.PRNGKey(params.seed)


def broadcast_lanes(params: SamplingParams, batch: int
                    ) -> Dict[str, jax.Array]:
    """Uniform lanes for a static batch (the legacy one-shot engine): every
    row shares ``params``, including the key — rows are independent
    requests that happen to be decoded lockstep."""
    key = request_key(params)
    return {
        "temperature": jnp.full((batch,), params.temperature, jnp.float32),
        "top_k": jnp.full((batch,), params.top_k, jnp.int32),
        "top_p": jnp.full((batch,), params.top_p, jnp.float32),
        "rng": jnp.tile(key[None, :], (batch, 1)),
    }


def set_lane(state: Dict[str, Any], slot: jax.Array, temperature: jax.Array,
             top_k: jax.Array, top_p: jax.Array, key: jax.Array
             ) -> Dict[str, Any]:
    """Write one slot's lane at admission (pure; the engine jits it once —
    slot and every param are traced scalars, so any request reuses it)."""
    sm = state["sample"]
    return {**state, "sample": {
        "temperature": sm["temperature"].at[slot].set(temperature),
        "top_k": sm["top_k"].at[slot].set(top_k),
        "top_p": sm["top_p"].at[slot].set(top_p),
        "rng": sm["rng"].at[slot].set(key),
    }}


# ---------------------------------------------------------------------------
# the sampler
# ---------------------------------------------------------------------------

# Static bucket for the sort-free top-p path: lanes whose support is
# bounded by ``top_k <= TOPP_BUCKET`` never touch a full [B, V] sort.
TOPP_BUCKET = 128


def _mask_logits_sorted(scaled: jax.Array, top_k: jax.Array,
                        top_p: jax.Array) -> jax.Array:
    """Exact full-sort masker (the pre-bucketing reference semantics).

    ``scaled`` [B, V] is already temperature-scaled.  Kept as the exact
    fallback branch of :func:`_mask_logits` and as the oracle the bucketed
    path is tested against (identical samples at equal seed).
    """
    v = scaled.shape[-1]
    # documented exact-sort fallback (oracle for the bucketed path)
    sorted_desc = -jnp.sort(-scaled, axis=-1)  # jitlint: disable=hot-path-op

    k = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v)
    kth = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    kept = jnp.where(scaled < kth, -jnp.inf, scaled)

    # nucleus over the already top-k-masked distribution: keep the sorted
    # prefix whose mass *before* each token is < top_p (the first token is
    # always kept), then translate back via a value cutoff.  The
    # normalizer is the same O(V) logsumexp over ``kept`` the bucketed
    # masker uses, so the two branches' per-position probabilities agree
    # to the last ulp wherever the kept support coincides.
    sorted_kept = jnp.where(sorted_desc < kth, -jnp.inf, sorted_desc)
    denom = jax.scipy.special.logsumexp(kept, axis=-1, keepdims=True)
    probs = jnp.exp(sorted_kept - denom)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    in_nucleus = cum_before < top_p[:, None]
    cutoff = jnp.min(jnp.where(in_nucleus, sorted_desc, jnp.inf),
                     axis=-1, keepdims=True)
    return jnp.where(kept < cutoff, -jnp.inf, kept)


def _mask_logits_bucketed(scaled: jax.Array, top_k: jax.Array,
                          top_p: jax.Array, kb: int) -> jax.Array:
    """Two-pass threshold top-k/top-p without the full [B, V] sort.

    Pass 1: ``lax.top_k`` pulls the (already sorted) ``kb``-entry bucket —
    with every lane's ``top_k`` in [1, kb], the kept support lives entirely
    inside it, so the k-th value threshold and the nucleus cutoff read off
    the bucket.  Pass 2: the nucleus mass is normalized against the *exact*
    kept distribution via an O(V) logsumexp (no sort), then translated back
    to a value cutoff applied to the full row.  Lanes with ``top_k == 0``
    reach this branch only when ``top_p == 1`` (no masking at all).
    """
    top_vals, _ = jax.lax.top_k(scaled, kb)                      # [B, kb]
    k = jnp.clip(top_k, 1, kb)
    kth = jnp.take_along_axis(top_vals, (k - 1)[:, None], axis=-1)
    kth = jnp.where((top_k > 0)[:, None], kth, -jnp.inf)
    kept = jnp.where(scaled < kth, -jnp.inf, scaled)

    denom = jax.scipy.special.logsumexp(kept, axis=-1, keepdims=True)
    bucket_kept = jnp.where(top_vals < kth, -jnp.inf, top_vals)
    probs = jnp.exp(bucket_kept - denom)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    in_nucleus = cum_before < top_p[:, None]
    cutoff = jnp.min(jnp.where(in_nucleus, top_vals, jnp.inf),
                     axis=-1, keepdims=True)
    cutoff = jnp.where((top_p >= 1.0)[:, None], -jnp.inf, cutoff)
    return jnp.where(kept < cutoff, -jnp.inf, kept)


def _mask_logits(logits: jax.Array, temperature: jax.Array,
                 top_k: jax.Array, top_p: jax.Array,
                 live: Optional[jax.Array] = None) -> jax.Array:
    """Temperature -> top-k -> top-p, all vectorized over the lane axis.

    Returns masked/scaled logits [B, V] ready for a categorical draw; at
    least one token always survives.  top_k == 0 and top_p == 1 are exact
    no-ops (modulo temperature scaling).

    The hot path is sort-free: lanes bounded by ``top_k <= TOPP_BUCKET``
    resolve both thresholds from a ``lax.top_k`` bucket; a single runtime
    ``lax.cond`` falls back to the exact full-sort masker only when some
    lane needs unbounded support (``top_k == 0`` with ``top_p < 1``, or
    ``top_k > TOPP_BUCKET``).  Both branches live in one trace, so
    heterogeneous lanes never retrace.

    ``live`` (bool [B], optional) restricts that fallback decision to
    lanes whose draw is actually consumed: released slots keep their stale
    lane params until the next admission, and a parked exact-support lane
    must not drag every live lane through the full sort.  Dead lanes still
    get a (bucket-masked) draw — it is discarded by the caller.

    Determinism scope: both branches score kept tokens with identical
    values and share the same logsumexp normalizer, but the exact branch
    accumulates the nucleus cumsum over the full [B, V] row while the
    bucketed branch accumulates over the [B, kb] bucket — so a lane whose
    scaled logit sits within a float ulp of its nucleus cutoff could in
    principle mask differently depending on which branch the *batch*
    takes (i.e. on whether some co-tenant needs unbounded support).  For
    continuous logits this boundary set has measure zero; the seed-only
    determinism contract holds per decode branch.
    """
    v = logits.shape[-1]
    # temperature == 0 lanes take the argmax path in sample_step; the clamp
    # only keeps this branch finite for them.
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    kb = min(v, TOPP_BUCKET)
    if kb == v:          # tiny vocab: the bucket IS the full sort
        return _mask_logits_sorted(scaled, top_k, top_p)
    needs_exact = (top_k > kb) | ((top_k == 0) & (top_p < 1.0))
    if live is not None:
        needs_exact = needs_exact & live
    return jax.lax.cond(
        jnp.any(needs_exact),
        lambda s: _mask_logits_sorted(s, top_k, top_p),
        lambda s: _mask_logits_bucketed(s, top_k, top_p, kb),
        scaled)


def sample_step(logits: jax.Array, lanes: Dict[str, jax.Array],
                advance: jax.Array
                ) -> Tuple[jax.Array, jax.Array, Dict[str, jax.Array]]:
    """Draw one token per lane; split each advancing lane's key on device.

    logits [B, V] (any float dtype); lanes as in :func:`init_lanes`;
    advance bool [B] — lanes whose RNG consumes a split this step (the
    engine passes its live-slot mask, so parked slots keep their key and a
    request's token stream depends only on its own tick count).

    Returns (tokens int32 [B], logprobs f32 [B], new lanes).
    ``temperature == 0`` lanes are exactly ``argmax(logits)``.  The
    logprob lane is the chosen token's ``log_softmax(logits)`` under the
    model's unmodified distribution (before temperature / top-k / top-p
    shaping) — the serving engines surface it on
    :attr:`RequestOutput.logprobs`.
    """
    # deliberate widening: sampling math runs at f32 (the bf16 tp>1
    # greedy-drift caveat in BENCH_mesh.json is why this stays explicit)
    logits = logits.astype(jnp.float32)  # jitlint: disable=dtype-promote
    temp = lanes["temperature"]
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    split = jax.vmap(lambda k: jax.random.split(k, 2))(lanes["rng"])
    carry, sub = split[:, 0], split[:, 1]
    masked = _mask_logits(logits, temp, lanes["top_k"], lanes["top_p"],
                          live=advance)
    sampled = jax.vmap(jax.random.categorical)(sub, masked).astype(jnp.int32)

    tok = jnp.where(temp > 0.0, sampled, greedy_tok)
    logp = jax.nn.log_softmax(logits, axis=-1)
    chosen_logp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
    new_rng = jnp.where(advance[:, None], carry, lanes["rng"])
    return tok, chosen_logp, {**lanes, "rng": new_rng}


# ---------------------------------------------------------------------------
# speculative acceptance (the verify half of draft–verify decoding)
# ---------------------------------------------------------------------------

def accept_step(logits: jax.Array, tokens: jax.Array, draft_len: jax.Array,
                lanes: Dict[str, jax.Array], live: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array,
                           Dict[str, jax.Array]]:
    """Per-lane acceptance over a verified draft window.

    logits [B, Qn, V] — the verify forward's panel logits (``logits[:, j]``
    conditions on the panel prefix through position ``j``); tokens
    [B, Qn] — the input panel (last committed token + padded drafts);
    draft_len int32 [B] — valid drafts per slot (0..Qn-1); lanes as in
    :func:`init_lanes`; live bool [B].

    Greedy lanes accept a draft iff it equals the argmax of the logits it
    was drafted to follow — the committed stream is *provably* the token
    stream the non-speculative engine would emit (each committed position
    is the argmax conditioned on the identical accepted prefix).  Sampled
    lanes run standard rejection sampling against the lane's own
    masked/temperature-scaled distribution: the drafter is deterministic
    (a point mass at the draft), so draft ``d`` is accepted with
    probability ``p(d)`` and a rejection re-samples from ``p`` with ``d``
    excluded (the renormalized residual) — the output *distribution* is
    exactly the non-speculative sampler's, token by token.

    Returns ``(out_tok int32 [B, Qn], out_logp f32 [B, Qn], n_commit
    int32 [B], new lanes)``: slot ``b`` commits ``out_tok[b, :n_commit[b]]``
    (``n_commit = accepted + 1`` — the window always ends with the
    correction/bonus token, whose K/V is *not* yet appended; masked slots
    commit 0).  ``out_logp`` is the chosen-token log-probability under the
    model's unmodified distribution, like :func:`sample_step`'s.  Every
    accept length 0..Qn-1 flows through the same masked selects — zero
    retraces.
    """
    b, qn, v = logits.shape
    # deliberate widening: accept math runs at f32 like sample_step's
    logits = logits.astype(jnp.float32)  # jitlint: disable=dtype-promote
    temp = lanes["temperature"]
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # [B, Qn]

    # one split chain per tick: carry + Qn categorical keys + Qn-1 uniforms
    split = jax.vmap(lambda k: jax.random.split(k, 2 * qn))(lanes["rng"])
    carry, k_cat, k_u = split[:, 0], split[:, 1:1 + qn], split[:, 1 + qn:]

    masked = jax.vmap(
        lambda lg: _mask_logits(lg, temp, lanes["top_k"], lanes["top_p"],
                                live=live),
        in_axes=1, out_axes=1)(logits)                           # [B, Qn, V]
    probs = jax.nn.softmax(masked, axis=-1)

    # draft d_{j+1} is judged by position j's distribution
    draft_next = tokens[:, 1:]                                   # [B, Qn-1]
    p_draft = jnp.take_along_axis(probs[:, :-1], draft_next[..., None],
                                  axis=-1)[..., 0]               # [B, Qn-1]
    u = jax.vmap(jax.vmap(jax.random.uniform))(k_u)              # [B, Qn-1]
    greedy_acc = greedy_tok[:, :-1] == draft_next
    samp_acc = u < p_draft
    acc = jnp.where((temp > 0.0)[:, None], samp_acc, greedy_acc)
    acc &= jnp.arange(qn - 1)[None, :] < draft_len[:, None]
    accepted = jnp.cumprod(acc.astype(jnp.int32), axis=1).sum(axis=1)  # [B]

    # correction (rejection: residual excludes the failed draft) / bonus
    # (all drafts accepted: plain draw) candidate at every position — only
    # the one at position ``accepted`` is ever committed.  One categorical
    # serves both cases: positions with a valid draft (j < draft_len)
    # exclude it (the renormalized residual), later positions draw from
    # the lane's distribution unmodified.
    dpad = jnp.pad(draft_next, ((0, 0), (0, 1)), constant_values=-1)
    jidx = jnp.arange(qn)[None, :]
    excl = ((jnp.arange(v)[None, None, :] == dpad[..., None])
            & (jidx < draft_len[:, None])[..., None])
    cand = jax.vmap(jax.vmap(jax.random.categorical))(
        k_cat, jnp.where(excl, -jnp.inf, masked)).astype(jnp.int32)
    corr = jnp.where((temp > 0.0)[:, None], cand, greedy_tok)

    out_tok = jnp.where(jidx < accepted[:, None],
                        dpad.astype(jnp.int32), corr)
    logp = jax.nn.log_softmax(logits, axis=-1)
    out_logp = jnp.take_along_axis(logp, out_tok[..., None], axis=-1)[..., 0]
    n_commit = jnp.where(live, accepted + 1, 0).astype(jnp.int32)
    new_rng = jnp.where(live[:, None], carry, lanes["rng"])
    return out_tok, out_logp, n_commit, {**lanes, "rng": new_rng}

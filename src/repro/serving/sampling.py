"""Request-level sampling: ``SamplingParams`` in, ``RequestOutput`` out,
and the jit-stable on-device sampler between them.

The serving engines treat decoding as *sampling lanes*: every cache-pool
slot carries its own ``temperature`` / ``top_k`` / ``top_p`` scalar and a
``[2]`` uint32 RNG key inside the engine's device state, so requests with
heterogeneous :class:`SamplingParams` coexist in one batched decode step.
Everything in here is shape-static — lanes are ``[slots]`` vectors that are
*written*, never re-shaped, so admitting a request with new params is an
``at[slot].set`` and the jitted step never retraces.

Key discipline (what makes seeded sampling reproducible): a request's lane
key is ``PRNGKey(params.seed)``, split **on device** once per sampled
token — at the final prefill chunk (first token) and at every decode tick
after that.  The key never mixes in the slot index or co-tenant state, so
the same request produces the same tokens no matter which slot it lands in
or who it shares the batch with.

``temperature == 0`` lanes bypass the categorical entirely and reduce to
exactly ``jnp.argmax(logits, -1).astype(int32)`` — bit-identical to the
greedy-only engine this API replaces.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# request-level API objects
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding contract.

    temperature: 0 = greedy (exact argmax); > 0 scales logits before the
      categorical draw.
    top_k: keep only the k highest logits (0 = disabled).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
      distribution whose mass reaches ``top_p`` (1.0 = disabled).
    seed: per-request RNG seed; same seed => same tokens, regardless of
      slot placement or batch co-tenants.
    max_new_tokens: generation budget (includes the first token sampled
      from the prompt's last logits).
    eos_id: single stop token (finish_reason "stop").
    stop_ids: stop *sequences* — each entry is a token-id tuple (a bare int
      means a 1-token sequence); generation finishes when the generated
      tail matches one.  Stop tokens are included in the output.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    stop_ids: Tuple[Tuple[int, ...], ...] = ()

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0: {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0: {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1]: {self.top_p}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1: {self.max_new_tokens}")
        norm = tuple(
            (int(s),) if isinstance(s, int) else tuple(int(t) for t in s)
            for s in self.stop_ids)
        if any(not s for s in norm):
            raise ValueError("empty stop sequence")
        object.__setattr__(self, "stop_ids", norm)

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    """Wall-clock timing of one request (``time.monotonic`` seconds)."""
    arrival_time: float
    first_token_time: Optional[float]
    finished_time: Optional[float]

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (queue wait + prefill)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.finished_time is None:
            return None
        return self.finished_time - self.arrival_time


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """Snapshot of one request's generation state.

    Streaming yields one per emitted token (``finish_reason is None`` while
    running); ``ContinuousEngine.run`` returns the final one per request.
    """
    request_id: int
    prompt_token_ids: Tuple[int, ...]
    token_ids: Tuple[int, ...]
    finish_reason: Optional[str]          # None | "stop" | "length"
    metrics: RequestMetrics

    @property
    def finished(self) -> bool:
        return self.finish_reason is not None


# ---------------------------------------------------------------------------
# sampling lanes (device state)
# ---------------------------------------------------------------------------

def init_lanes(slots: int) -> Dict[str, jax.Array]:
    """Zeroed lane state: every slot starts greedy with a null key."""
    return {
        "temperature": jnp.zeros((slots,), jnp.float32),
        "top_k": jnp.zeros((slots,), jnp.int32),
        "top_p": jnp.ones((slots,), jnp.float32),
        "rng": jnp.zeros((slots, 2), jnp.uint32),
    }


def request_key(params: SamplingParams) -> jax.Array:
    """The per-request RNG lane seed — deliberately slot-independent."""
    return jax.random.PRNGKey(params.seed)


def broadcast_lanes(params: SamplingParams, batch: int
                    ) -> Dict[str, jax.Array]:
    """Uniform lanes for a static batch (the legacy one-shot engine): every
    row shares ``params``, including the key — rows are independent
    requests that happen to be decoded lockstep."""
    key = request_key(params)
    return {
        "temperature": jnp.full((batch,), params.temperature, jnp.float32),
        "top_k": jnp.full((batch,), params.top_k, jnp.int32),
        "top_p": jnp.full((batch,), params.top_p, jnp.float32),
        "rng": jnp.tile(key[None, :], (batch, 1)),
    }


def set_lane(state: Dict[str, Any], slot: jax.Array, temperature: jax.Array,
             top_k: jax.Array, top_p: jax.Array, key: jax.Array
             ) -> Dict[str, Any]:
    """Write one slot's lane at admission (pure; the engine jits it once —
    slot and every param are traced scalars, so any request reuses it)."""
    sm = state["sample"]
    return {**state, "sample": {
        "temperature": sm["temperature"].at[slot].set(temperature),
        "top_k": sm["top_k"].at[slot].set(top_k),
        "top_p": sm["top_p"].at[slot].set(top_p),
        "rng": sm["rng"].at[slot].set(key),
    }}


# ---------------------------------------------------------------------------
# the sampler
# ---------------------------------------------------------------------------

def _mask_logits(logits: jax.Array, temperature: jax.Array,
                 top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Temperature -> top-k -> top-p, all vectorized over the lane axis.

    Returns masked/scaled logits [B, V] ready for a categorical draw; at
    least one token always survives.  top_k == 0 and top_p == 1 are exact
    no-ops (modulo temperature scaling).
    """
    v = logits.shape[-1]
    # temperature == 0 lanes take the argmax path in sample_step; the clamp
    # only keeps this branch finite for them.
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    sorted_desc = -jnp.sort(-scaled, axis=-1)                    # [B, V]

    k = jnp.clip(jnp.where(top_k > 0, top_k, v), 1, v)
    kth = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    kept = jnp.where(scaled < kth, -jnp.inf, scaled)

    # nucleus over the already top-k-masked distribution: keep the sorted
    # prefix whose mass *before* each token is < top_p (the first token is
    # always kept), then translate back via a value cutoff.
    sorted_kept = jnp.where(sorted_desc < kth, -jnp.inf, sorted_desc)
    probs = jax.nn.softmax(sorted_kept, axis=-1)
    cum_before = jnp.cumsum(probs, axis=-1) - probs
    in_nucleus = cum_before < top_p[:, None]
    cutoff = jnp.min(jnp.where(in_nucleus, sorted_desc, jnp.inf),
                     axis=-1, keepdims=True)
    return jnp.where(kept < cutoff, -jnp.inf, kept)


def sample_step(logits: jax.Array, lanes: Dict[str, jax.Array],
                advance: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Draw one token per lane; split each advancing lane's key on device.

    logits [B, V] (any float dtype); lanes as in :func:`init_lanes`;
    advance bool [B] — lanes whose RNG consumes a split this step (the
    engine passes its live-slot mask, so parked slots keep their key and a
    request's token stream depends only on its own tick count).

    Returns (tokens int32 [B], new lanes).  ``temperature == 0`` lanes are
    exactly ``argmax(logits)``.
    """
    logits = logits.astype(jnp.float32)
    temp = lanes["temperature"]
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    split = jax.vmap(lambda k: jax.random.split(k, 2))(lanes["rng"])
    carry, sub = split[:, 0], split[:, 1]
    masked = _mask_logits(logits, temp, lanes["top_k"], lanes["top_p"])
    sampled = jax.vmap(jax.random.categorical)(sub, masked).astype(jnp.int32)

    tok = jnp.where(temp > 0.0, sampled, greedy_tok)
    new_rng = jnp.where(advance[:, None], carry, lanes["rng"])
    return tok, {**lanes, "rng": new_rng}

"""Serving engines over the paper's §6.2 compressed-KV design.

Two engines share the kernels but differ in how they treat traffic:

* :class:`Engine` — the legacy **one-shot** engine: one static batch,
  prefill -> freeze -> decode.  Refreezing grows the cache shapes, so each
  refreeze re-traces the jitted decode.  Kept as the numerical baseline
  and for single-batch benchmarking.

* :class:`ContinuousEngine` — the **continuous-batching** engine: requests
  stream through a :class:`~repro.serving.cache_pool.CachePool` of
  fixed-geometry slots under a :class:`~repro.serving.scheduler.Scheduler`.
  Chunked prefill interleaves with decode ticks, slots recycle on EOS, and
  every jitted step — decode over ``(params, pool_state, tokens,
  slot_mask)``, per-chunk-length prefill, refreeze, release — compiles
  exactly once.  This is the paper's "cache frozen in model state" design
  made multi-tenant: refreeze folds tails into the prefix *in place* at
  static shapes instead of reallocating.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_kv import SparseKVCache, freeze_prefix
from repro.distributed import NULL_CTX
from repro.models import lm
from repro.models.attention import DenseKVCache

from .cache_pool import CachePool
from .scheduler import Scheduler


def retrace_count(jitted) -> int:
    """Number of traces a ``jax.jit``-wrapped callable has accumulated.

    The continuous engine's invariant is that this stays flat after warmup
    (one trace per shape family); tests assert it directly.
    """
    return int(jitted._cache_size())


class Engine:
    def __init__(self, params, cfg, ctx=NULL_CTX, kv_mode: str = "sparse"):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.kv_mode = kv_mode
        self._decode = jax.jit(
            lambda p, c, t: lm.forward_decode(p, c, t, cfg, ctx))
        self._prefill = jax.jit(
            lambda p, b: lm.forward_prefill(p, b, cfg, ctx))

    # ------------------------------------------------------------------
    def prefill(self, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        hidden, collected = self._prefill(self.params, batch)
        p = lm.period_len(cfg)
        kinds = [lm.layer_kind(cfg, j) for j in range(p)]
        layers: Dict[str, Any] = {}
        for j, kind in enumerate(kinds):
            got = collected["layers"][f"l{j}"]
            if kind[0] == "attn":
                layers[f"l{j}"] = {"kv": self._build_kv(got["k"], got["v"])}
            else:
                layers[f"l{j}"] = {"state": got["state"]}
        cache = {"pos": jnp.asarray(collected["len"], jnp.int32),
                 "layers": layers}
        if cfg.family == "encdec":
            cross = collected["cross"]["l0"]
            cache["cross"] = {"k": cross["k"], "v": cross["v"]}
        logits = lm.logits_fn(self.params, hidden[:, -1:], cfg, self.ctx)
        return cache, logits[:, 0]

    def _build_kv(self, k_stack, v_stack):
        """k/v [P, B, Hkv, S, hd] -> per-period cache, host-packed.

        Pass 1 finds the max per-block nnz across layers (global magnitude
        pruning gives ragged block occupancy); pass 2 packs every layer at
        that common capacity so the stacked cache has static shapes — the
        stacked analogue of the paper's fixed offline capacity."""
        cfg = self.cfg
        n_periods = k_stack.shape[0]
        per = []
        cap_k = cap_v = None
        if self.kv_mode == "sparse" and n_periods > 1:
            probes = [freeze_prefix(
                k_stack[i], v_stack[i], cfg.kv_k_sparsity,
                cfg.kv_v_sparsity, tail_size=cfg.kv_tail,
                bs=min(128, k_stack.shape[3])) for i in range(n_periods)]
            cap_k = max(p.k_sp.capacity for p in probes)
            cap_v = max(p.v_sp.capacity for p in probes)
        for i in range(n_periods):
            k, v = k_stack[i], v_stack[i]
            s = k.shape[2]
            if self.kv_mode == "sparse":
                bs = min(128, s)
                per.append(freeze_prefix(
                    k, v, cfg.kv_k_sparsity, cfg.kv_v_sparsity,
                    tail_size=cfg.kv_tail, bs=bs,
                    capacity_k=cap_k, capacity_v=cap_v))
            else:
                pad = cfg.kv_tail
                kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
                per.append(DenseKVCache(kp, vp,
                                        jnp.asarray(s, jnp.int32)))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, jax.Array], steps: int,
                 greedy: bool = True, rng: Optional[jax.Array] = None):
        cache, logits = self.prefill(batch)
        b = batch["tokens"].shape[0]
        toks = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(steps):
            toks.append(tok)
            if self.kv_mode == "sparse":
                cache = self._maybe_refreeze(cache)
            logits, cache = self._decode(self.params, cache, tok[:, None])
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        toks.append(tok)
        return jnp.stack(toks, axis=1), cache

    # ------------------------------------------------------------------
    def _maybe_refreeze(self, cache):
        """Fold full tails back into the compressed prefix (paper §6.2's
        amortized step).  Host-side, between jitted decode steps; note the
        prefix growth changes cache shapes -> one re-trace per refreeze."""
        from repro.core.sparse_kv import refreeze
        cfg = self.cfg
        layers = dict(cache["layers"])
        changed = False
        for name, leaf in layers.items():
            if "kv" not in leaf:
                continue
            kv = leaf["kv"]
            t = kv.k_tail.shape[3]          # stacked [P, B, Hkv, T, D]
            if int(kv.tail_len[0]) < t:
                continue
            n_periods = kv.k_tail.shape[0]
            per = [refreeze(jax.tree_util.tree_map(lambda a: a[i], kv),
                            cfg.kv_k_sparsity, cfg.kv_v_sparsity)
                   for i in range(n_periods)]
            cap_k = max(p.k_sp.capacity for p in per)
            cap_v = max(p.v_sp.capacity for p in per)
            if any(p.k_sp.capacity != cap_k or p.v_sp.capacity != cap_v
                   for p in per):
                # re-pack at a common capacity so the stack is rectangular
                per = [self._repack(p, cap_k, cap_v) for p in per]
            layers[name] = {**leaf, "kv": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per)}
            changed = True
        if not changed:
            return cache
        return {**cache, "layers": layers}

    def _repack(self, kvc, cap_k, cap_v):
        """Re-store one period's cache at the stack-wide common capacity.

        Uses :func:`repack_capacity`, which keeps bitmap and values
        consistent in both directions (the old grow-only pad left the
        bitmap claiming truncated values when capacities shrank)."""
        from repro.core.sparse_format import repack_capacity
        return SparseKVCache(repack_capacity(kvc.k_sp, cap_k),
                             repack_capacity(kvc.v_sp, cap_v),
                             kvc.k_tail, kvc.v_tail, kvc.tail_len)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

class ContinuousEngine:
    """Continuous-batching serving engine on the pooled sparse-KV cache.

    One engine tick (:meth:`step`):

    1. **refreeze** — any decoding slot whose tail ring is full gets its
       tail pruned + folded into its compressed prefix, in place;
    2. **admission / chunked prefill** — the oldest request owed prompt
       work gets one chunk processed against its slot's frozen prefix;
       finishing the prompt yields the request's first token;
    3. **decode** — every decoding slot advances one token in a single
       batched step jitted over ``(params, pool_state, tokens, slot_mask)``.

    All device work reuses four compiled functions (decode / refreeze /
    release, plus one prefill per distinct chunk length); admissions,
    evictions and refreezes never retrace — see :func:`retrace_count`.
    Host<->device traffic per tick is one token vector; slot lengths are
    mirrored host-side.
    """

    def __init__(self, params, cfg, ctx=NULL_CTX, slots: int = 4,
                 max_tokens: int = 0, bs: int = 0,
                 prefill_chunk: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        max_tokens = max_tokens or 4 * cfg.kv_tail
        if not bs:
            # largest tail divisor <= min(128, prefill_chunk): chunks stay
            # block-aligned and the tail folds in whole blocks
            limit = min(128, prefill_chunk or 128, cfg.kv_tail)
            bs = next(d for d in range(limit, 0, -1)
                      if cfg.kv_tail % d == 0)
        self.pool = CachePool.build(cfg, slots, max_tokens, bs=bs)
        self.state = self.pool.init_state()
        self.scheduler = Scheduler(slots, self.pool.capacity_tokens,
                                   self.pool.bs, chunk=prefill_chunk)
        bs_ = self.pool.bs

        # greedy argmax stays on device: only [slots]-sized int32 token
        # vectors cross the host boundary each tick, never [slots, vocab]
        # logits
        def _decode(p, st, t, m):
            logits, st = lm.forward_decode_pooled(p, st, t, m, cfg, ctx, bs_)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), st

        def _prefill(p, st, t, s):
            logits, st = lm.forward_prefill_chunk(p, st, t, s, cfg, ctx, bs_)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), st

        self._decode = jax.jit(_decode)
        self._prefill_chunk = jax.jit(_prefill)
        self._refreeze = jax.jit(self.pool.refreeze)
        self._release = jax.jit(self.pool.release)
        # host mirrors (avoid a device sync per tick)
        self._tail_len = np.zeros(slots, np.int64)
        self._last_tok: Dict[int, int] = {}           # slot -> last token

    # -- public API ---------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None) -> int:
        """Queue a request (any iterable of token ids).  Returns its id."""
        return self.scheduler.submit([int(t) for t in np.asarray(prompt)],
                                     max_new_tokens, eos_id)

    def run(self) -> Dict[int, List[int]]:
        """Tick until every submitted request finished; returns
        ``{request id: generated tokens}`` (greedy decoding)."""
        while not self.scheduler.done():
            self.step()
        return {rid: req.generated
                for rid, req in self.scheduler.finished.items()}

    def generate_batch(self, prompts: jax.Array, steps: int) -> jax.Array:
        """Convenience mirror of the legacy ``Engine.generate``: submit all
        rows of ``prompts [B, S]``, return ``[B, steps + 1]`` greedy tokens
        (the first comes from the prompt's last logits, like the legacy
        engine's prefill token)."""
        rids = [self.submit(row, steps + 1) for row in np.asarray(prompts)]
        out = self.run()
        return jnp.asarray([out[r] for r in rids], jnp.int32)

    def trace_counts(self) -> Dict[str, int]:
        return {"decode": retrace_count(self._decode),
                "prefill_chunk": retrace_count(self._prefill_chunk),
                "refreeze": retrace_count(self._refreeze),
                "release": retrace_count(self._release)}

    # -- one tick -----------------------------------------------------------
    def step(self) -> None:
        sch = self.scheduler
        # admission: fill every free slot from the queue
        while sch.queue and sch.free_slots():
            sch.admit()

        # refreeze before decode appends: any decoding slot with a full tail
        if any(self._tail_len[s] >= self.pool.tail
               for s in sch.decoding_slots()):
            self.state = self._refreeze(self.state)
            for s in range(self.pool.slots):
                if self._tail_len[s] >= self.pool.tail:
                    self._tail_len[s] = 0

        # one prefill chunk for the oldest request still owed prompt work
        req = sch.next_prefill()
        if req is not None:
            chunk = sch.prefill_chunk(req)
            toks = jnp.asarray(np.asarray(chunk, np.int32)[None, :])
            tok, self.state = self._prefill_chunk(
                self.params, self.state, toks, jnp.int32(req.slot))
            # device-side tail_len after a chunk = chunk_len % bs, and all
            # chunks before the last are block-aligned
            self._tail_len[req.slot] = req.prefill_done % self.pool.bs
            if req.prefill_done >= len(req.prompt):
                self._emit(req.slot, int(np.asarray(tok)[0]))

        # decode tick for every slot with a live request past prefill
        slots = sch.decoding_slots()
        if not slots:
            return
        b = self.pool.slots
        tokens = np.zeros((b, 1), np.int32)
        mask = np.zeros((b,), bool)
        for s in slots:
            tokens[s, 0] = self._last_tok[s]
            mask[s] = True
        tok, self.state = self._decode(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(mask))
        picked = np.asarray(tok)
        for s in slots:
            self._tail_len[s] += 1
            self._emit(s, int(picked[s]))

    def _emit(self, slot: int, tok: int) -> None:
        """Record a generated token; recycle the slot if that finished it."""
        if self.scheduler.record_token(slot, tok):
            self.state = self._release(self.state, jnp.int32(slot))
            self._tail_len[slot] = 0
            self._last_tok.pop(slot, None)
        else:
            self._last_tok[slot] = tok

"""Serving engines over the paper's §6.2 compressed-KV design.

Two engines share the kernels but differ in how they treat traffic:

* :class:`Engine` — the legacy **one-shot** engine: one static batch,
  prefill -> freeze -> decode.  Refreezing grows the cache shapes, so each
  refreeze re-traces the jitted decode.  Kept as the numerical baseline
  and for single-batch benchmarking.

* :class:`ContinuousEngine` — the **continuous-batching** engine: requests
  stream through a :class:`~repro.serving.cache_pool.CachePool` of
  fixed-geometry slots under a :class:`~repro.serving.scheduler.Scheduler`.
  Chunked prefill interleaves with decode ticks, slots recycle on EOS, and
  every jitted step — decode over ``(params, pool_state, tokens,
  slot_mask)``, per-chunk-length prefill, refreeze, release, lane set —
  compiles exactly once.  This is the paper's "cache frozen in model
  state" design made multi-tenant: refreeze folds tails into the prefix
  *in place* at static shapes instead of reallocating.

Both engines speak the request-level API of :mod:`repro.serving.sampling`:
callers pass :class:`SamplingParams` and get tokens / RequestOutputs back.
The model's decode steps return **logits**; token selection is the
sampler's job (per-slot on-device lanes in the continuous engine, one
broadcast lane in the legacy engine) — argmax is just the
``temperature=0`` lane of that sampler.
"""
from __future__ import annotations

from typing import (Any, Callable, Dict, Iterator, List, Optional, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_kv import SparseKVCache, freeze_prefix
from repro.distributed import NULL_CTX, serving_sharding
from repro.models import lm
from repro.models.attention import DenseKVCache

from . import sampling
from .cache_pool import BlockAllocator, CachePool
from .cache_pool import checkified_raw as cache_pool_checkified_raw
from .faults import (CANCEL_PREFILL, CANCEL_SPEC, DOUBLE_RELEASE,
                     DRAFTER_ERROR, PAGE_EXHAUSTION, FaultPlan)
from .sampling import RequestOutput, SamplingParams
from .scheduler import PrefixTrie, Scheduler, block_hashes
from .spec import AdaptiveDraft, SpecConfig


def retrace_count(jitted) -> int:
    """Number of traces a ``jax.jit``-wrapped callable has accumulated.

    The continuous engine's invariant is that this stays flat after warmup
    (one trace per shape family); tests assert it directly.
    """
    return int(jitted._cache_size())


def stable_trace_counts(counts: Dict[str, int],
                        ignore: tuple = ("prefill_chunk",)) -> Dict[str, int]:
    """The subset of :meth:`ContinuousEngine.trace_counts` that must stay
    FLAT after warmup.

    ``prefill_chunk`` legitimately accumulates one trace per distinct
    chunk length (a new prompt length is a new shape family, not a
    retrace), so zero-retrace assertions compare the rest.  One shared
    utility — the engine benchmarks and the serving/spec/sharding test
    suites all filter through here instead of re-implementing the drop.
    """
    return {k: v for k, v in counts.items() if k not in ignore}


class Engine:
    def __init__(self, params, cfg, ctx=NULL_CTX, kv_mode: str = "sparse"):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.kv_mode = kv_mode
        self._decode = jax.jit(
            lambda p, c, t: lm.forward_decode(p, c, t, cfg, ctx))
        self._prefill = jax.jit(
            lambda p, b: lm.forward_prefill(p, b, cfg, ctx))
        self._sample = jax.jit(sampling.sample_step)

    # ------------------------------------------------------------------
    def prefill(self, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        hidden, collected = self._prefill(self.params, batch)
        p = lm.period_len(cfg)
        kinds = [lm.layer_kind(cfg, j) for j in range(p)]
        layers: Dict[str, Any] = {}
        for j, kind in enumerate(kinds):
            got = collected["layers"][f"l{j}"]
            if kind[0] == "attn":
                layers[f"l{j}"] = {"kv": self._build_kv(got["k"], got["v"])}
            else:
                layers[f"l{j}"] = {"state": got["state"]}
        cache = {"pos": jnp.asarray(collected["len"], jnp.int32),
                 "layers": layers}
        if cfg.family == "encdec":
            cross = collected["cross"]["l0"]
            cache["cross"] = {"k": cross["k"], "v": cross["v"]}
        logits = lm.logits_fn(self.params, hidden[:, -1:], cfg, self.ctx)
        return cache, logits[:, 0]

    def _build_kv(self, k_stack, v_stack):
        """k/v [P, B, Hkv, S, hd] -> per-period cache, host-packed.

        Pass 1 finds the max per-block nnz across layers (global magnitude
        pruning gives ragged block occupancy); pass 2 packs every layer at
        that common capacity so the stacked cache has static shapes — the
        stacked analogue of the paper's fixed offline capacity."""
        cfg = self.cfg
        n_periods = k_stack.shape[0]
        per = []
        cap_k = cap_v = None
        if self.kv_mode == "sparse" and n_periods > 1:
            probes = [freeze_prefix(
                k_stack[i], v_stack[i], cfg.kv_k_sparsity,
                cfg.kv_v_sparsity, tail_size=cfg.kv_tail,
                bs=min(128, k_stack.shape[3])) for i in range(n_periods)]
            cap_k = max(p.k_sp.capacity for p in probes)
            cap_v = max(p.v_sp.capacity for p in probes)
        for i in range(n_periods):
            k, v = k_stack[i], v_stack[i]
            s = k.shape[2]
            if self.kv_mode == "sparse":
                bs = min(128, s)
                per.append(freeze_prefix(
                    k, v, cfg.kv_k_sparsity, cfg.kv_v_sparsity,
                    tail_size=cfg.kv_tail, bs=bs,
                    capacity_k=cap_k, capacity_v=cap_v))
            else:
                pad = cfg.kv_tail
                kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
                per.append(DenseKVCache(kp, vp,
                                        jnp.asarray(s, jnp.int32)))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, jax.Array],
                 params: Optional[SamplingParams] = None):
        """Decode ``params.max_new_tokens`` tokens for the whole batch.

        Every row shares ``params`` (a static batch is one lockstep wave,
        not a request stream — per-request params, eos/stop handling and
        streaming live on :class:`ContinuousEngine`; eos/stop params are
        rejected here rather than silently decoded past).  The decode step
        returns logits; token selection happens in the shared jitted
        sampler, so ``temperature=0`` is exactly the old greedy path.
        Returns ``([B, max_new_tokens] int32 tokens, final cache)`` — the
        first token is sampled from the prompt's last logits.
        """
        params = params if params is not None else SamplingParams()
        if params.eos_id is not None or params.stop_ids:
            raise ValueError(
                "the one-shot Engine decodes fixed-length lockstep batches "
                "and cannot honor eos_id/stop_ids; submit to "
                "ContinuousEngine for per-request stop handling")
        cache, logits = self.prefill(batch)
        b = batch["tokens"].shape[0]
        lanes = sampling.broadcast_lanes(params, b)
        live = jnp.ones((b,), bool)
        toks = []
        tok, _, lanes = self._sample(logits, lanes, live)
        for i in range(params.max_new_tokens - 1):
            toks.append(tok)
            if self.kv_mode == "sparse":
                cache = self._maybe_refreeze(cache)
            logits, cache = self._decode(self.params, cache, tok[:, None])
            tok, _, lanes = self._sample(logits, lanes, live)
        toks.append(tok)
        return jnp.stack(toks, axis=1), cache

    # ------------------------------------------------------------------
    def _maybe_refreeze(self, cache):
        """Fold full tails back into the compressed prefix (paper §6.2's
        amortized step).  Host-side, between jitted decode steps; note the
        prefix growth changes cache shapes -> one re-trace per refreeze."""
        from repro.core.sparse_kv import refreeze
        cfg = self.cfg
        layers = dict(cache["layers"])
        changed = False
        for name, leaf in layers.items():
            if "kv" not in leaf:
                continue
            kv = leaf["kv"]
            t = kv.k_tail.shape[3]          # stacked [P, B, Hkv, T, D]
            if int(kv.tail_len[0]) < t:
                continue
            n_periods = kv.k_tail.shape[0]
            per = [refreeze(jax.tree_util.tree_map(lambda a: a[i], kv),
                            cfg.kv_k_sparsity, cfg.kv_v_sparsity)
                   for i in range(n_periods)]
            cap_k = max(p.k_sp.capacity for p in per)
            cap_v = max(p.v_sp.capacity for p in per)
            if any(p.k_sp.capacity != cap_k or p.v_sp.capacity != cap_v
                   for p in per):
                # re-pack at a common capacity so the stack is rectangular
                per = [self._repack(p, cap_k, cap_v) for p in per]
            layers[name] = {**leaf, "kv": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per)}
            changed = True
        if not changed:
            return cache
        return {**cache, "layers": layers}

    def _repack(self, kvc, cap_k, cap_v):
        """Re-store one period's cache at the stack-wide common capacity.

        Uses :func:`repack_capacity`, which keeps bitmap and values
        consistent in both directions (the old grow-only pad left the
        bitmap claiming truncated values when capacities shrank)."""
        from repro.core.sparse_format import repack_capacity
        return SparseKVCache(repack_capacity(kvc.k_sp, cap_k),
                             repack_capacity(kvc.v_sp, cap_v),
                             kvc.k_tail, kvc.v_tail, kvc.tail_len)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

class ContinuousEngine:
    """Continuous-batching serving engine on the pooled sparse-KV cache.

    One engine tick (:meth:`step`):

    1. **refreeze** — any decoding slot whose tail ring is full gets its
       tail pruned + folded into its compressed prefix, in place;
    2. **admission / chunked prefill** — admitted requests get their
       sampling lane (temperature / top-k / top-p / seeded RNG key)
       written into device state; the oldest request owed prompt work gets
       one chunk processed against its slot's frozen prefix, and finishing
       the prompt samples the request's first token;
    3. **decode** — every decoding slot advances one token in a single
       batched step jitted over ``(params, pool_state, tokens, slot_mask)``
       — the model returns per-slot logits and the on-device sampler draws
       each slot's token under its own lane, splitting the ``[slots, 2]``
       RNG lane in place.

    Decode, speculative verify and spec-off ticks are all the SAME
    canonical **panel forward** (:func:`repro.models.lm.forward_panel_pooled`
    at static width ``Q``): decode is the ``Q == 1`` panel (squeezed onto
    the single-query fused dispatch for bit-identical greedy output),
    verify the ``Q == K+1`` panel — one scan body, one per-layer fused
    attention kernel, one shape family per panel width.

    With ``mesh=`` the WHOLE serving state is mesh-sharded — slots over
    the data axes, KV heads over the model axis
    (``repro.distributed.serving_sharding``) — and every jitted step is
    pinned with ``in_shardings``/``out_shardings`` so state never moves
    between ticks.  The scheduler is untouched (slot placement is a device
    concern, not a request concern); non-dividing dims fall back to
    replication, and a 1-device mesh is exactly the unsharded engine.

    All device work reuses five compiled functions (decode / refreeze /
    release / set_lane, plus one prefill per distinct chunk length);
    admissions, evictions, refreezes and *heterogeneous sampling params*
    never retrace — see :func:`retrace_count`.  Host<->device traffic per
    tick is one token vector plus one chosen-token logprob vector (surfaced
    on :attr:`RequestOutput.logprobs`); slot lengths are mirrored
    host-side.  Per layer, the decode tick's attention is ONE fused
    prefix+tail flash-decode kernel — the XLA-side tail attention + lse
    merge the two-pass design paid per token is gone.

    With ``spec=SpecConfig(k>0)`` the decode tick becomes a **draft–verify
    step**: a model-free n-gram drafter proposes up to ``k`` continuation
    tokens per slot from the request's own history, and one jitted verify
    forward scores all ``k+1`` positions against the pooled cache (a query
    panel through the same fused kernel), accepts per lane (greedy: exact
    match — token-identical to this engine with spec off; sampled:
    rejection sampling — distribution unchanged) and rolls rejected drafts
    back by a pure length decrement.  The verify step compiles once per
    (pool geometry, k); accept lengths 0..k never retrace.
    ``spec_hist[a]`` counts ticks that committed ``a`` accepted drafts.

    With ``paged=True`` the pool stores compressed blocks ONCE in a shared
    physical arena of ``phys_blocks`` pages indexed through per-slot block
    tables.  Admission content-addresses each prompt's block-aligned
    prefix against a host prefix index (:class:`~.scheduler.PrefixTrie` of
    chained block hashes): a hit points the new slot's table row at the
    already-frozen pages (refcount++) and SKIPS their prefill entirely —
    N requests sharing a system prompt pay its prefill and its arena bytes
    once.  Frozen pages are immutable; prefill/refreeze always append
    fresh pages past the shared prefix (copy-on-write at the divergence
    block), and releases decref — a refcount-0 page parks in the
    allocator's LRU, revivable by a future hit until evicted for reuse.
    Admission reserves each request's worst-case page demand up front, so
    device-side allocation can never fail mid-flight.  The table and
    refcount are data: decode still never retraces.

    **Fault-tolerant lifecycle** (all host-side control flow — the jitted
    transitions and their compile manifest are untouched): per-request
    deadlines (``SamplingParams.deadline_s`` / ``ttft_deadline_s``)
    enforced at tick start, :meth:`cancel` for any live request, bounded
    admission with load shedding (``max_queue``; rejected requests finish
    ``"shed"`` at submit time), exponential-backoff requeue when paged
    admission can't reserve pages, and an optional degraded mode
    (``degrade_queue``) that drops speculative drafting to zero under
    queue pressure.  ``fault_counters`` tallies every abnormal event.  A
    seeded :class:`~repro.serving.faults.FaultPlan` (``faults=``) injects
    failures at the named host sites for the fault-injection harness.
    :meth:`save_snapshot` / :meth:`load_snapshot` persist the paged
    arena + prefix index for crash-safe warm restarts.

    **Overlapped (double-buffered) ticks** (``overlap=True``): the tick
    loop is pipelined — tick *t+1*'s device work is dispatched BEFORE
    tick *t*'s tokens are synced, so host scheduler work (admission,
    stop scanning, callbacks, releases) hides behind the in-flight
    device step instead of serializing with it.  On the plain decode
    path the input token chains **on device**: :attr:`_decode_chain`
    consumes the previous tick's un-synced token vector (a jax async
    value) and host-overrides only the lanes where the chain breaks (a
    slot fresh out of prefill, re-admitted, or the first tick after an
    idle pipeline).  Under speculation the in-flight verify window is
    committed after the next tick's admission/prefill dispatch but
    before drafting (the n-gram drafter needs the committed history and
    the paged refreeze scatter needs exact tail mirrors).  Either way
    there is exactly ONE sync site — :meth:`_sync_inflight`, the
    registry-designated ``jax.block_until_ready`` — and commit re-checks
    ``(slot, rid)`` liveness, so a request that expired, was cancelled,
    or finished while its window was in flight never has speculatively
    dispatched tokens committed.  Greedy *and* seeded-sampled output is
    token-identical to ``overlap=False`` (the oracle): each request's
    RNG stream is a pure function of its sampled-token count, and
    discarded speculative draws happen strictly after the request's
    last committed draw.  :meth:`quiesce` drains the pipeline (snapshot
    paths call it implicitly).
    """

    def __init__(self, params, cfg, ctx=NULL_CTX, slots: int = 4,
                 max_tokens: int = 0, bs: int = 0,
                 prefill_chunk: Optional[int] = None,
                 spec: Optional[SpecConfig] = None,
                 capacity_slack: float = 1.25,
                 mesh=None, paged: bool = False, phys_blocks: int = 0,
                 checkify: Optional[bool] = None,
                 max_queue: int = 0, degrade_queue: int = 0,
                 faults: Optional[FaultPlan] = None, clock=None,
                 obs=None, overlap: bool = False):
        if mesh is not None:
            # mesh-sharded serving: slots over the data axes, KV heads over
            # the model axis.  The ctx also constrains activations inside
            # the forwards so the residual stream follows the state.
            if ctx is not NULL_CTX:
                raise ValueError(
                    "pass either ctx= or mesh=, not both: mesh= derives "
                    "its own serving ShardCtx (slots over data, KV heads "
                    "over model)")
            ctx = serving_sharding.serving_ctx(mesh, cfg)
        self.cfg = cfg
        self.ctx = ctx
        self.mesh = mesh
        max_tokens = max_tokens or 4 * cfg.kv_tail
        if not bs:
            # largest tail divisor <= min(128, prefill_chunk): chunks stay
            # block-aligned and the tail folds in whole blocks
            limit = min(128, prefill_chunk or 128, cfg.kv_tail)
            bs = next(d for d in range(limit, 0, -1)
                      if cfg.kv_tail % d == 0)
        self.pool = CachePool.build(cfg, slots, max_tokens, bs=bs,
                                    capacity_slack=capacity_slack,
                                    paged=paged, n_phys=phys_blocks,
                                    checkify=checkify)
        if mesh is not None and self.pool.checkify:
            raise ValueError("checkify mode is unsharded-only: the "
                             "functionalized error output has no mesh "
                             "placement")
        # pool storage + per-slot sampling lanes travel as one state pytree
        # through every jitted transition (the pool ops pass unknown keys
        # through untouched)
        self.state = {**self.pool.init_state(),
                      "sample": sampling.init_lanes(slots)}
        sch_kw = {} if clock is None else {"clock": clock}
        self.scheduler = Scheduler(slots, self.pool.capacity_tokens,
                                   self.pool.bs, chunk=prefill_chunk,
                                   max_queue=max_queue, **sch_kw)
        bs_ = self.pool.bs

        # mesh placement: every jitted step below is pinned with explicit
        # in_shardings/out_shardings so (a) the state NEVER leaves its
        # placement between ticks and (b) host-fed operands (token panels,
        # masks, lane params) land directly on their shards.  Weights are
        # replicated (serving decode streams the cache, not the weights);
        # all placements degrade to replication when a dim doesn't divide
        # its mesh axis, so a 1-device mesh IS the unsharded engine.
        self.state_axes = {**self.pool.state_axes(),
                           "sample": sampling.lane_axes()}
        if mesh is not None:
            st_sh = serving_sharding.state_shardings(ctx, self.state,
                                                     self.state_axes)
            tok_sh = serving_sharding.token_sharding(ctx, slots)
            vec_sh = serving_sharding.vec_sharding(ctx, slots)
            rep = serving_sharding.replicated(ctx)
            par_sh = jax.tree_util.tree_map(lambda _: rep, params)
            params = jax.device_put(params, par_sh)
            self.state = jax.device_put(self.state, st_sh)

            def _jit(fn, in_s, out_s):
                return jax.jit(fn, in_shardings=in_s, out_shardings=out_s)
        elif self.pool.checkify:
            # sanitized mode: the pool transitions plant checkify.check
            # invariants, which a plain jit cannot trace — functionalize
            # each step and throw the accumulated error at the host
            # boundary.  trace_counts() keeps working through the
            # forwarded _cache_size.
            st_sh = tok_sh = vec_sh = rep = par_sh = None

            def _jit(fn, in_s, out_s):
                checked = jax.jit(cache_pool_checkified_raw(fn))

                def run(*args):
                    err, out = checked(*args)
                    err.throw()
                    return out
                run._cache_size = checked._cache_size
                return run
        else:
            st_sh = tok_sh = vec_sh = rep = par_sh = None

            def _jit(fn, in_s, out_s):
                return jax.jit(fn)
        self.params = params

        # sampling stays on device: only [slots]-sized token + logprob
        # vectors cross the host boundary each tick, never [slots, vocab]
        # logits.  A decode tick is the Q == 1 instance of the SAME panel
        # forward the speculative verify step uses (lm.forward_panel_pooled
        # — the per-layer attention is one fused prefix+tail kernel), so
        # decode and verify share one scan body and differ only in their
        # static panel width.
        def _decode(p, st, t, m):
            logits, st = lm.forward_panel_pooled(p, st, t, m, cfg, ctx, bs_)
            tok, logp, lanes = sampling.sample_step(
                logits[:, 0], st["sample"], m)
            return tok, logp, {**st, "sample": lanes}

        def _prefill(p, st, t, s, final, ids=None):
            logits, st = lm.forward_prefill_chunk(p, st, t, s, cfg, ctx, bs_,
                                                  new_ids=ids)
            lanes = st["sample"]
            lane = {k: jax.lax.dynamic_slice_in_dim(v, s, 1, axis=0)
                    for k, v in lanes.items()}
            # the key advances only when the chunk is final (= a token is
            # actually sampled), keeping the request's RNG stream a pure
            # function of its sampled-token count
            tok, logp, lane = sampling.sample_step(
                logits, lane, jnp.reshape(final, (1,)))
            lanes = {**lanes, "rng": jax.lax.dynamic_update_slice_in_dim(
                lanes["rng"], lane["rng"], s, axis=0)}
            return tok, logp, {**st, "sample": lanes}

        self._decode = _jit(_decode, (par_sh, st_sh, tok_sh, vec_sh),
                            (vec_sh, vec_sh, st_sh))
        if paged:
            self._prefill_chunk = _jit(
                _prefill, (par_sh, st_sh, rep, rep, rep, rep),
                (rep, rep, st_sh))
            self._refreeze = _jit(
                lambda st, ids: self.pool.refreeze(st, new_ids=ids),
                (st_sh, rep), st_sh)
            self._assign = _jit(
                lambda st, s, ids, n: self.pool.assign_blocks(st, s, ids, n),
                (st_sh, rep, rep, rep), st_sh)
        else:
            self._prefill_chunk = _jit(
                _prefill, (par_sh, st_sh, rep, rep, rep), (rep, rep, st_sh))
            self._refreeze = _jit(self.pool.refreeze, (st_sh,), st_sh)
            self._assign = None
        self._release = _jit(self.pool.release, (st_sh, rep), st_sh)
        # a fresh function object, NOT sampling.set_lane itself: pjit's
        # fastpath cache is keyed on the function, so jitting the shared
        # module function would let other engines' pool geometries count
        # against this engine's trace_counts()
        self._set_lane = _jit(
            lambda st, slot, t, k, p, key:
                sampling.set_lane(st, slot, t, k, p, key),
            (st_sh, rep, rep, rep, rep, rep), st_sh)

        # speculative decoding: one jitted draft–verify step scores all
        # K+1 panel positions in a single forward over the pooled cache,
        # accepts per lane on device, and rolls the rejected suffix back
        # by a pure length decrement — zero retraces across accept lengths
        # 0..K.  When disabled the non-spec path above is preserved
        # bit-for-bit (the verify step is never built, never traced).
        self._spec = spec if spec is not None and spec.active else None
        self._verify = None
        self._adaptive = None
        if self._spec is not None:
            self.drafter = self._spec.build_drafter()
            qn = self._spec.k + 1
            self.spec_hist = np.zeros(qn, np.int64)   # committed-1 per tick
            if self._spec.adaptive:
                # host-side per-slot draft-length controller: each slot's
                # recent acceptance rate scales its next draft window
                # (data only — the [slots, K+1] panel shape never changes)
                self._adaptive = AdaptiveDraft(self._spec)

            def _verify(p, st, toks, m, dl):
                logits, st = lm.forward_panel_pooled(p, st, toks, m, cfg,
                                                     ctx, bs_)
                tok, logp, nc, lanes = sampling.accept_step(
                    logits, toks, dl, st["sample"], m)
                # appended qn per live slot; keep 1 + accepted = nc
                roll = qn * m.astype(jnp.int32) - nc
                st = self.pool.rollback({**st, "sample": lanes}, roll)
                return tok, logp, nc, st

            self._verify = _jit(_verify,
                                (par_sh, st_sh, tok_sh, vec_sh, vec_sh),
                                (tok_sh, tok_sh, vec_sh, st_sh))

        # overlapped pipeline: the chained decode entry consumes the
        # PREVIOUS tick's un-synced token vector as a device operand and
        # host-overrides only the broken-chain lanes — the input panel of
        # tick t+1 never round-trips through the host, so the scheduler
        # tick runs while the device computes.  Same forward, same sampler,
        # one extra shape family; built only when overlap is on so the
        # serial engine's trace_counts() are untouched.
        self.overlap = bool(overlap)
        self._inflight: Optional[Dict[str, Any]] = None
        if self.overlap:
            def _decode_chain(p, st, prev, ov, ovm, m):
                t = jnp.where(ovm, ov, prev)[:, None]
                logits, st = lm.forward_panel_pooled(p, st, t, m, cfg, ctx,
                                                     bs_)
                tok, logp, lanes = sampling.sample_step(
                    logits[:, 0], st["sample"], m)
                return tok, logp, {**st, "sample": lanes}

            self._decode_chain = _jit(
                _decode_chain,
                (par_sh, st_sh, vec_sh, vec_sh, vec_sh, vec_sh),
                (vec_sh, vec_sh, st_sh))
            # steady-state device-operand caches: on an uninterrupted
            # chain the override vectors are all-zero and the decode mask
            # repeats, so reuse ONE transferred array per shape instead of
            # a fresh device_put every tick.  All entries are built with
            # jnp.asarray(np.ndarray) so every dispatch hands
            # _decode_chain the same operand provenance (the jit cache
            # keys committed device_puts apart from jit outputs — mixing
            # them would double-compile).
            self._ov_zero: Optional[Tuple[jax.Array, jax.Array]] = None
            self._mask_cache: Dict[Tuple[int, ...], jax.Array] = {}
        else:
            self._decode_chain = None

        # host mirrors (avoid a device sync per tick)
        self._tail_len = np.zeros(slots, np.int64)
        self._last_tok: Dict[int, int] = {}           # slot -> last token
        self._callbacks: Dict[int, Callable[[RequestOutput], None]] = {}
        self._pending_release: List[int] = []         # flushed once per tick

        # fault-tolerant lifecycle: deadline/cancel/shed accounting, the
        # seeded fault plan (None in production), and the degraded-mode
        # queue threshold (queue >= degrade_queue drops spec drafting to 0
        # so verify ticks commit exactly one token — pressure relief
        # without a shape change).  _slot_live mirrors which slots hold
        # admitted device state so a double release is detected host-side
        # as a warning, never acted on twice.
        self._faults = faults
        self._degrade_queue = degrade_queue
        self._tick_no = 0
        self._in_tick = False
        self._slot_live = np.zeros(slots, bool)
        self.fault_counters: Dict[str, int] = {
            "shed": 0, "timeout": 0, "cancelled": 0, "double_release": 0,
            "drafter_error": 0, "deferred": 0, "degraded_ticks": 0,
            "injected_page_exhaustion": 0}

        # observability (repro.obs.Observability or None): a host-only
        # telemetry sink fed exclusively at the tick-boundary sync point
        # and on the host-side submit/cancel paths.  Every call site is
        # guarded on `self._obs is not None`, no jitted function knows it
        # exists, and it receives plain ints/floats/lists — the obs-on
        # engine is token-identical and retrace-identical to obs-off
        # (tests/test_obs.py pins all three properties).
        self._obs = obs
        self._tick_committed = 0          # tokens committed this tick
        if obs is not None and faults is not None:
            faults.on_fire = (
                lambda site, tick: obs.fault(site, tick,
                                             self.scheduler.clock()))

        # paged pool: host-side id lifecycle + prefix index.  Sharing needs
        # deterministic block content, which needs deterministic chunk
        # boundaries — the trie only indexes blocks frozen by full-width
        # chunks, so it is active iff prefill is chunked.
        self._trie = PrefixTrie() if paged else None
        self._alloc = (BlockAllocator(self.pool.n_phys,
                                      on_evict=self._trie.drop)
                       if paged else None)
        self._blocks: Dict[int, List[int]] = {}       # slot -> table row ids
        self._reserved: Dict[int, int] = {}           # slot -> pages owed

    # -- public API ---------------------------------------------------------
    def submit(self, prompt, params: Optional[SamplingParams] = None,
               on_token: Optional[Callable[[RequestOutput], None]] = None
               ) -> int:
        """Queue a request (any iterable of token ids) under its own
        :class:`SamplingParams`.  Returns the request id.

        ``on_token`` is called with a :class:`RequestOutput` snapshot after
        every token window this request commits — one token per tick on
        the non-speculative path, up to ``spec.k + 1`` tokens per verify
        tick under speculation (the last snapshot has ``finished``).

        Under load shedding (``max_queue`` set, queue full) the request is
        rejected immediately: ``on_token`` fires exactly once with a final
        ``finish_reason="shed"`` snapshot and nothing is registered — the
        shed costs no slot, no pages, and no tick work.
        """
        toks = [int(t) for t in np.asarray(prompt)]
        rid = self.scheduler.submit(toks, params)
        if self._obs is not None:
            # queue_depth is passed from the post-submit queue so the gauge
            # is consistent even when the request was shed at submit time
            # (sheds never enter the queue) — the asyncio frontend submits
            # between ticks, where obs.tick cannot refresh it
            self._obs.request_submitted(rid, len(toks),
                                        self.scheduler.clock(),
                                        queue_depth=len(self.scheduler.queue))
        req = self.scheduler.finished.get(rid)
        if req is not None and req.finish_reason == "shed":
            # one counter path: the scheduler sheds, the scheduler counts
            # (Scheduler.shed_count); the engine mirror re-syncs instead of
            # incrementing so a shed can never be double-counted no matter
            # which layer observed it first
            self.fault_counters["shed"] = self.scheduler.shed_count
            out = req.output()
            if self._obs is not None:
                self._obs.request_finished(out, self.scheduler.clock())
            if on_token is not None:
                on_token(out)
            return rid
        if on_token is not None:
            self._callbacks[rid] = on_token
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it lives (queued / prefilling /
        decoding).  Returns whether anything was cancelled — a rid that
        already finished (or was never submitted) is a quiet ``False``,
        so cancellation racing normal completion is safe to lose.

        An active request's slot is released through the same batched
        release path normal completion uses (paged blocks decref'd, LRU
        retains revivable prefixes); co-tenant slots are untouched — their
        token streams are bit-identical to a run where the cancelled
        request never existed past its release tick.  The final
        ``finish_reason="cancelled"`` snapshot fires the request's
        ``on_token`` callback once.
        """
        return self._cancel_inner(rid) is not None

    def _cancel_inner(self, rid: int) -> Optional[RequestOutput]:
        req = self.scheduler.cancel(rid)
        if req is None:
            return None
        self.fault_counters["cancelled"] += 1
        if req.slot >= 0:
            self._abort_slot(req.slot)
        out = req.output()
        if self._obs is not None:
            self._obs.request_finished(out, self.scheduler.clock())
        cb = self._callbacks.pop(rid, None)
        if cb is not None:
            cb(out)
        return out

    def _abort_slot(self, slot: int) -> None:
        """Tear down an active slot outside the normal finish path (cancel
        or deadline expiry): queue its batched release and reset the host
        mirrors.  Outside a tick the release flushes immediately (a caller
        cancelling between ticks must not leave pages pinned)."""
        self._pending_release.append(slot)
        self._tail_len[slot] = 0
        self._last_tok.pop(slot, None)
        if self._adaptive is not None:
            self._adaptive.reset(slot)
        if not self._in_tick:
            self._flush_releases()

    def _expire_deadlines(self, now: float,
                          events: List[RequestOutput]) -> None:
        """Finish every request past its deadline (``finish_reason=
        "timeout"``), releasing the slots of active ones.  Runs at tick
        start — BEFORE this tick's decode — so a stop committed last tick
        has already won; a deadline can never retract emitted output."""
        for req in self.scheduler.expire(now):
            self.fault_counters["timeout"] += 1
            if req.slot >= 0:
                self._abort_slot(req.slot)
            out = req.output()
            if self._obs is not None:
                self._obs.request_finished(out, now)
            events.append(out)
            cb = self._callbacks.pop(req.rid, None)
            if cb is not None:
                cb(out)

    def run(self) -> Dict[int, RequestOutput]:
        """Tick until every submitted request finished; returns
        ``{request id: RequestOutput}``."""
        while not self.scheduler.done():
            self.step()
        self.quiesce()
        return {rid: req.output()
                for rid, req in self.scheduler.finished.items()}

    def stream(self) -> Iterator[RequestOutput]:
        """Tick until the queue drains, yielding a :class:`RequestOutput`
        snapshot per committed token window (interleaved across live
        requests, in emission order) — per token without speculation, per
        accepted window with it.  Submitting more work mid-iteration
        extends the stream."""
        while not self.scheduler.done():
            yield from self.step()
        yield from self.quiesce()

    def quiesce(self) -> List[RequestOutput]:
        """Drain the overlapped pipeline: commit (or, for requests that
        died in flight, discard) the in-flight tick's window and flush any
        pending releases.  A no-op on the serial engine or when nothing is
        in flight.  Snapshot paths and the asyncio frontend's shutdown
        call this so the arena is never serialized under an un-synced
        dispatch; returns the snapshots it committed."""
        events: List[RequestOutput] = []
        self._sync_inflight(events)
        self._flush_releases()
        return events

    def generate_batch(self, prompts: jax.Array,
                       params: Optional[SamplingParams] = None) -> jax.Array:
        """Convenience mirror of the legacy ``Engine.generate``: submit all
        rows of ``prompts [B, S]`` under one ``params``, return
        ``[B, max_new_tokens]`` tokens (the first comes from the prompt's
        last logits, like the legacy engine's prefill token)."""
        params = params if params is not None else SamplingParams()
        rids = [self.submit(row, params) for row in np.asarray(prompts)]
        out = self.run()
        return jnp.asarray([out[r].token_ids for r in rids], jnp.int32)

    def trace_counts(self) -> Dict[str, int]:
        counts = {"decode": retrace_count(self._decode),
                  "prefill_chunk": retrace_count(self._prefill_chunk),
                  "refreeze": retrace_count(self._refreeze),
                  "release": retrace_count(self._release),
                  "set_lane": retrace_count(self._set_lane)}
        if self._assign is not None:
            counts["assign"] = retrace_count(self._assign)
        if self._verify is not None:
            counts["verify"] = retrace_count(self._verify)
        if self._decode_chain is not None:
            counts["decode_chain"] = retrace_count(self._decode_chain)
        return counts

    def entry_points(self, chunk: int = 0):
        """Every registered jitted transition with abstract example args.

        Returns ``{name: (jitted, args)}`` where ``args`` is a tuple of
        ``ShapeDtypeStruct`` pytrees matching one representative call from
        :meth:`step`; the names are exactly :meth:`trace_counts` keys.
        The static analyzer (:mod:`repro.analysis`) traces each entry
        under these avals to audit its jaxpr and pin its compile manifest
        without touching real data.  ``chunk`` is the prefill chunk width
        to describe (default: one block — each distinct width is its own
        legitimate shape family, see :func:`stable_trace_counts`).
        """
        ab = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)  # noqa: E731
        p = jax.tree_util.tree_map(ab, self.params)
        st = jax.tree_util.tree_map(ab, self.state)
        b, sb = self.pool.slots, self.pool.max_blocks
        c = chunk or self.pool.bs

        def i32(*shape):
            return jax.ShapeDtypeStruct(shape, jnp.int32)

        def f32(*shape):
            return jax.ShapeDtypeStruct(shape, jnp.float32)
        boolv = jax.ShapeDtypeStruct((b,), jnp.bool_)
        scalar_b = jax.ShapeDtypeStruct((), jnp.bool_)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)

        out = {"decode": (self._decode, (p, st, i32(b, 1), boolv)),
               "release": (self._release, (st, i32(b))),
               "set_lane": (self._set_lane,
                            (st, i32(), f32(), i32(), f32(), key))}
        if self.pool.paged:
            tb = self.pool.tail // self.pool.bs
            out["prefill_chunk"] = (
                self._prefill_chunk,
                (p, st, i32(1, c), i32(), scalar_b, i32(c // self.pool.bs)))
            out["refreeze"] = (self._refreeze, (st, i32(b, tb)))
            out["assign"] = (self._assign, (st, i32(), i32(sb), i32()))
        else:
            out["prefill_chunk"] = (
                self._prefill_chunk, (p, st, i32(1, c), i32(), scalar_b))
            out["refreeze"] = (self._refreeze, (st,))
        if self._verify is not None:
            qn = self._spec.k + 1
            out["verify"] = (self._verify,
                             (p, st, i32(b, qn), boolv, i32(b)))
        if self._decode_chain is not None:
            # the overlapped dispatch path: prev is the in-flight tick's
            # un-synced token vector, (ov, ovm) the host override lanes
            out["decode_chain"] = (self._decode_chain,
                                   (p, st, i32(b), i32(b), boolv, boolv))
        return out

    @property
    def adaptive_hist(self) -> Optional[np.ndarray]:
        """Histogram of per-slot draft windows actually *proposed* under
        ``SpecConfig(adaptive=True)`` (index = draft tokens a slot put up
        for verification that tick); ``None`` when adaptive K is off."""
        return None if self._adaptive is None else self._adaptive.hist

    # -- crash-safe warm restart --------------------------------------------
    def _snapshot_guard(self, what: str) -> None:
        if self._alloc is None:
            raise ValueError(f"{what} needs the paged pool: only the "
                             "shared arena + prefix index persist "
                             "(build the engine with paged=True)")
        if self.mesh is not None:
            raise ValueError(f"{what} is unsharded-only: arena leaves are "
                             "persisted as full host tensors")

    def save_snapshot(self, directory: str) -> int:
        """Persist the warm-restart state of the paged pool under
        ``directory`` (atomic write-temp-then-rename via
        :class:`~repro.checkpoint.manager.CheckpointManager`): the shared
        arena leaves, the chained-hash -> physical-page pairs of the
        prefix index, and the allocator's registered population.  In-flight
        request state (tails, tables, occupancy) is deliberately NOT
        saved — after a crash there are no in-flight requests; what
        survives is exactly the shareable frozen content a restarted
        server can hit on.  Returns the step number written.
        """
        self._snapshot_guard("save_snapshot")
        # quiesce first: the arena must never be serialized while a
        # dispatched-but-unsynced tick could still scatter into it
        self.quiesce()
        from repro.checkpoint.manager import CheckpointManager
        t0 = self.scheduler.clock() if self._obs is not None else 0.0
        pairs = self._alloc.export_registered()
        tree = {"arena": self.pool.arena_leaves(self.state),
                "hashes": np.asarray([h for h, _ in pairs], np.int64),
                "ids": np.asarray([b for _, b in pairs], np.int32)}
        mgr = CheckpointManager(directory, keep=2)
        step = (mgr.latest_step() or 0) + 1
        mgr.save(step, tree,
                 meta={"kind": "serving-prefix-cache",
                       "geometry": self.pool.geometry(),
                       "n_registered": len(pairs)},
                 blocking=True)
        if self._obs is not None:
            self._obs.snapshot_event("save", t0,
                                     self.scheduler.clock() - t0,
                                     len(pairs))
        return step

    def load_snapshot(self, directory: str) -> int:
        """Warm-restart from the newest snapshot under ``directory``:
        reload the arena leaves, rebuild the prefix trie and the
        allocator's cached population (every restored page enters at
        refcount 0, revivable by a prefix hit, evictable from the LRU's
        cold end in snapshot order).  The next admission of a prompt whose
        prefix was frozen before the crash skips its prefill entirely.

        Idle-only (restore before serving traffic) and geometry-checked:
        a snapshot from a different pool geometry raises a ``ValueError``
        naming every mismatched field — never a half-restore.  Content-
        addressed hash chains make *stale* content impossible, so geometry
        is the only validation needed; the slot count may freely differ
        (the arena is slot-independent).  Returns the number of restored
        pages.
        """
        self._snapshot_guard("load_snapshot")
        self.quiesce()
        t0 = self.scheduler.clock() if self._obs is not None else 0.0
        if self.scheduler.active or self.scheduler.queue or self._blocks:
            raise ValueError("load_snapshot on a busy engine: restore "
                             "before submitting traffic")
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(directory, keep=2)
        step = mgr.latest_step()
        if step is None:
            raise ValueError(f"no snapshot under {directory!r}")
        manifest = mgr.read_manifest(step)
        if manifest.get("kind") != "serving-prefix-cache":
            raise ValueError(
                f"snapshot step {step} under {directory!r} is not a "
                f"serving prefix cache (kind={manifest.get('kind')!r})")
        mine, theirs = self.pool.geometry(), manifest.get("geometry") or {}
        bad = [f"{k}: engine has {mine[k]!r}, snapshot has "
               f"{theirs.get(k)!r}" for k in mine if theirs.get(k) != mine[k]]
        if bad:
            raise ValueError("snapshot geometry mismatch — "
                             + "; ".join(bad))
        n = int(manifest["n_registered"])
        # to_device=False: the int64 hash chain must come back exactly
        # (jnp.asarray would truncate it to int32 under x64-disabled jax)
        like = {"arena": self.pool.arena_leaves(self.pool.init_state()),
                "hashes": np.zeros(n, np.int64),
                "ids": np.zeros(n, np.int32)}
        tree, _ = mgr.restore(step, like, to_device=False)
        pairs = list(zip((int(h) for h in tree["hashes"]),
                         (int(b) for b in tree["ids"])))
        self._alloc.restore_registered(pairs)     # validates ids first
        self._trie.reload(pairs)
        self.state = self.pool.load_arena(self.state, tree["arena"])
        if self._obs is not None:
            self._obs.snapshot_event("load", t0,
                                     self.scheduler.clock() - t0,
                                     len(pairs))
        return len(pairs)

    # -- one tick -----------------------------------------------------------
    def step(self) -> List[RequestOutput]:
        """Advance the engine one tick; returns a snapshot per token emitted
        (empty while the pool is still prefilling).  Slots freed this tick
        are recycled in ONE batched release at the end (host-padded
        ``[slots]`` vector — a tick finishing many requests costs one
        jitted call, not one per slot).

        Tick order is the fault-tolerance contract: deadline expiry and the
        release flush run FIRST (inside :meth:`_step_inner`), so a slot
        freed by a timeout is re-admittable the same tick but a request
        admitted this tick can never land in a slot whose release is still
        pending from an expiry — admission only sees fully-released slots.
        """
        obs = self._obs
        t_start = self.scheduler.clock() if obs is not None else 0.0
        self._tick_no += 1
        self._in_tick = True
        self._tick_committed = 0
        try:
            return self._step_inner()
        finally:
            if self._faults is not None:
                # double-release fault: push an already-freed slot through
                # the release path again.  The flush must absorb it as a
                # counted warning (and the device transition as a no-op).
                cand = (list(self._pending_release)
                        or [s for s in range(self.pool.slots)
                            if s not in self.scheduler.active])
                if cand and self._faults.take(DOUBLE_RELEASE, self._tick_no):
                    self._pending_release.append(self._faults.choose(cand))
            self._flush_releases()
            self._in_tick = False
            if obs is not None:
                sch = self.scheduler
                obs.tick(
                    start=t_start, now=sch.clock(), tick_no=self._tick_no,
                    committed=self._tick_committed,
                    queue_depth=len(sch.queue), active=len(sch.active),
                    slots=self.pool.slots, counters=self.fault_counters,
                    free_blocks=(self._alloc.free_blocks()
                                 if self._alloc is not None else None),
                    n_phys=(self.pool.n_phys
                            if self._alloc is not None else 0),
                    evictions=(self._alloc.evictions
                               if self._alloc is not None else 0),
                    trie_blocks=(len(self._trie)
                                 if self._trie is not None else 0),
                    spec_hist=(self.spec_hist.tolist()
                               if self._spec is not None else None))

    def _flush_releases(self) -> None:
        """Recycle every pending slot in one batched device release.

        Idempotent at both layers: a slot appearing twice (or pushed again
        after an earlier flush) is detected against the ``_slot_live``
        mirror and counted as a ``double_release`` warning — its allocator
        decref is skipped (host refcounts stay exact) while the device
        release vector, which is naturally idempotent on a free slot,
        still runs once per unique slot.
        """
        if not self._pending_release:
            return
        seen = list(dict.fromkeys(self._pending_release))   # ordered unique
        doubles = len(self._pending_release) - len(seen)
        live = []
        for s in seen:
            if self._slot_live[s]:
                self._slot_live[s] = False
                live.append(s)
            else:
                doubles += 1
        self._pending_release = []
        self.fault_counters["double_release"] += doubles
        # the whole unique set goes to the device — releasing an already
        # free slot there is a masked no-op (even under checkify), which is
        # exactly the property the double-release fault site exercises
        vec = np.full(self.pool.slots, -1, np.int32)
        vec[:len(seen)] = seen
        self.state = self._release(self.state, jnp.asarray(vec))
        if self._alloc is not None:
            for s in live:
                ids = self._blocks.pop(s, [])
                if ids:
                    self._alloc.decref(ids)
                self._reserved.pop(s, None)

    def _admit_paged(self, now: float):
        """Reservation + prefix-hit admission for the queue's head.

        Returns the admitted :class:`~.scheduler.Request`, or None (leaving
        the request queued) when the arena cannot guarantee its worst-case
        page demand on top of every already-admitted request's outstanding
        reservation — the paged analogue of running out of slots.  On
        admission, a prefix-trie hit points the slot's table row at the
        shared pages and skips their prefill.
        """
        sch, bs, alloc = self.scheduler, self.pool.bs, self._alloc
        nxt = sch.queue[0]
        plen = len(nxt.prompt)
        hits: List[int] = []
        if sch.chunk is not None:
            hits = self._trie.match(block_hashes(nxt.prompt, bs))
            # a full-prompt hit would leave no tokens to produce the first
            # token's logits; and hits are quantized down to whole chunks
            # so the remaining prefill reuses the full-width chunk
            # boundaries the frozen blocks were hashed under
            cw = sch.chunk // bs
            n_hit = min(len(hits), (plen - 1) // bs) // cw * cw
            hits = hits[:n_hit]
        revived = sum(1 for i in hits if alloc.refcount(i) == 0)
        need = -(-(plen + nxt.params.max_new_tokens) // bs) - len(hits)
        outstanding = sum(self._reserved.values())
        exhausted = need + revived + outstanding > alloc.free_blocks()
        if (not exhausted and self._faults is not None
                and self._faults.take(PAGE_EXHAUSTION, self._tick_no)):
            # injected arena pressure: behave exactly as if no physical
            # blocks were free, driving the backoff-requeue path
            self.fault_counters["injected_page_exhaustion"] += 1
            exhausted = True
        if exhausted:
            # defer with exponential backoff (head-of-line: FIFO preserved)
            sch.defer_admission(now)
            self.fault_counters["deferred"] += 1
            return None
        req = sch.admit(now)
        if self._obs is not None and sch.chunk is not None:
            self._obs.prefix_match(len(hits), plen // bs)
        self._reserved[req.slot] = need
        self._blocks[req.slot] = list(hits)
        if hits:
            alloc.incref(hits)
            pad = np.zeros(self.pool.max_blocks, np.int32)
            pad[:len(hits)] = hits
            self.state = self._assign(self.state, jnp.int32(req.slot),
                                      jnp.asarray(pad),
                                      jnp.int32(len(hits)))
            req.prefill_done = len(hits) * bs   # shared prefix: no prefill
            self._tail_len[req.slot] = 0
        return req

    def _step_inner(self) -> List[RequestOutput]:
        events: List[RequestOutput] = []
        sch = self.scheduler
        # deadline expiry, then the release flush, THEN admission: slots
        # freed by a timeout (or by a between-tick cancel) are fully
        # released before any new request can be admitted into them — a
        # pending release must never fire on a slot a fresh tenant just
        # claimed.
        now = sch.clock()
        self._expire_deadlines(now, events)
        self._flush_releases()
        # admission: fill every free slot from the queue, writing each new
        # request's sampling lane into device state
        while sch.queue and sch.free_slots():
            if sch.queue[0].next_admit > now:
                break                          # head backing off: FIFO waits
            req = (sch.admit(now) if self._alloc is None
                   else self._admit_paged(now))
            if req is None:
                break                          # arena full: wait for releases
            p = req.params
            self.state = self._set_lane(
                self.state, jnp.int32(req.slot),
                jnp.float32(p.temperature), jnp.int32(p.top_k),
                jnp.float32(p.top_p), sampling.request_key(p))
            self._slot_live[req.slot] = True

        # cancellation-mid-prefill fault: kill a partially-prefilled
        # request between its chunks — its pages must come back (at tick
        # end) without perturbing co-tenant streams
        if self._faults is not None:
            mid = [r for r in sch.active.values()
                   if 0 < r.prefill_done < len(r.prompt)]
            if mid and self._faults.take(CANCEL_PREFILL, self._tick_no):
                out = self._cancel_inner(self._faults.choose(mid).rid)
                if out is not None:
                    events.append(out)

        if self.overlap and self._spec is not None:
            # SPEC PIPELINE (shallow): the verify dispatched last tick is
            # still in flight — the admission work above and the prefill
            # dispatch below overlap it on the host.  It must commit before
            # the refreeze decision (the paged fold scatters into exactly
            # the rows the device deems full, so the tail mirrors need the
            # data-dependent accept counts) and before drafting (the n-gram
            # drafter reads the committed history) — so the designated sync
            # sits between the prefill dispatch and this tick's
            # draft/verify dispatch, which tick t+1 will sync in turn.
            self._prefill_tick(events)
            self._sync_inflight(events)
            self._refreeze_tick(events)
            slots = sch.decoding_slots()
            if not slots:
                return events
            return self._spec_tick(slots, events)

        # refreeze before decode appends (under overlap the mirrors are
        # still exact here: a plain decode appends exactly one token, and
        # the in-flight tick's +1 was applied at its dispatch)
        self._refreeze_tick(events)
        self._prefill_tick(events)

        # decode tick for every slot with a live request past prefill
        slots = sch.decoding_slots()
        if not slots:
            if self.overlap:
                self._sync_inflight(events)   # pipeline drains when idle
            return events
        if self._spec is not None:
            return self._spec_tick(slots, events)
        if self.overlap:
            return self._overlap_decode_tick(slots, events)
        b = self.pool.slots
        t_dec = sch.clock() if self._obs is not None else 0.0
        tokens = np.zeros((b, 1), np.int32)
        mask = np.zeros((b,), bool)
        for s in slots:
            tokens[s, 0] = self._last_tok[s]
            mask[s] = True
        tok, logp, self.state = self._decode(
            self.params, self.state, jnp.asarray(tokens), jnp.asarray(mask))
        picked, logps = np.asarray(tok), np.asarray(logp)
        if self._obs is not None:
            # span covers dispatch through the np.asarray token sync — the
            # tick's designated host<->device boundary
            self._obs.decode_tick(t_dec, sch.clock() - t_dec, len(slots),
                                  spec=False)
        for s in slots:
            if s not in sch.active:
                continue      # cancelled mid-tick (reentrant callback):
                              # the sampled token dies with the slot
            self._tail_len[s] += 1
            self._emit(s, [int(picked[s])], [float(logps[s])], events)
        return events

    def _refreeze_tick(self, events: List[RequestOutput]) -> None:
        """Refreeze every slot whose tail ring is full (only decoding slots
        can fill one; the host list must mirror the device-side
        ``tail_len == tail`` mask exactly, because the paged fold scatters
        into precisely the rows the device deems full)."""
        full = [s for s in range(self.pool.slots)
                if self._tail_len[s] >= self.pool.tail]
        if not full:
            return
        if self._alloc is not None:
            tb = self.pool.tail // self.pool.bs
            if (self._inflight is not None
                    and len(full) * tb + sum(self._reserved.values())
                    > self._alloc.free_blocks()):
                # a slot whose FINISHING window is still in flight can show
                # a speculatively-full tail one tick past its reservation;
                # folding it would alloc pages admission promised to other
                # requests.  Rare fallback: drain the pipeline first — the
                # commit releases dead slots (and their pages) and drops
                # them out of `full`, restoring the never-fails invariant.
                self._sync_inflight(events)
                self._flush_releases()
                full = [s for s in range(self.pool.slots)
                        if self._tail_len[s] >= self.pool.tail]
                if not full:
                    return
            ids = np.zeros((self.pool.slots, tb), np.int32)
            for s in full:
                fresh = self._alloc.alloc(tb)    # CoW: never shared pages
                ids[s] = fresh
                self._blocks.setdefault(s, []).extend(fresh)
                self._reserved[s] = max(0, self._reserved.get(s, 0) - tb)
            self.state = self._refreeze(self.state, jnp.asarray(ids))
        else:
            self.state = self._refreeze(self.state)
        for s in full:
            self._tail_len[s] = 0

    def _prefill_tick(self, events: List[RequestOutput]) -> None:
        """One prefill chunk for the oldest request still owed prompt work.

        The final chunk's first-token sync stays SYNCHRONOUS even under
        overlap — it happens once per request and is the TTFT the SLO
        benchmarks measure; the one-tick commit delay applies to the
        steady-state decode/verify windows only.
        """
        sch = self.scheduler
        req = sch.next_prefill()
        if req is not None:
            t_pf = sch.clock() if self._obs is not None else 0.0
            off0 = req.prefill_done
            chunk = sch.prefill_chunk(req)
            final = req.prefill_done >= len(req.prompt)
            toks = jnp.asarray(np.asarray(chunk, np.int32)[None, :])
            args = (self.params, self.state, toks, jnp.int32(req.slot),
                    jnp.asarray(final))
            if self._alloc is not None:
                nb_new = len(chunk) // self.pool.bs
                fresh = self._alloc.alloc(nb_new) if nb_new else []
                tok, logp, self.state = self._prefill_chunk(
                    *args, jnp.asarray(np.asarray(fresh, np.int32)))
                self._blocks.setdefault(req.slot, []).extend(fresh)
                self._reserved[req.slot] = max(
                    0, self._reserved.get(req.slot, 0) - nb_new)
                # content-address the new blocks, but only when this chunk
                # ran at full width: block bytes depend on the whole token
                # prefix AND the chunk boundaries it was processed under,
                # so only full-width-chunk blocks are reproducible by a
                # future prompt prefilling through the same scheduler
                if sch.chunk is not None and len(chunk) == sch.chunk:
                    hs = block_hashes(req.prompt[:req.prefill_done],
                                      self.pool.bs)
                    for i, bid in enumerate(fresh):
                        h = hs[off0 // self.pool.bs + i]
                        if self._alloc.register(bid, h):
                            self._trie.insert(h, bid)
            else:
                tok, logp, self.state = self._prefill_chunk(*args)
            # device-side tail_len after a chunk = chunk_len % bs, and all
            # chunks before the last are block-aligned
            self._tail_len[req.slot] = req.prefill_done % self.pool.bs
            if final:
                self._emit(req.slot, [int(np.asarray(tok)[0])],
                           [float(np.asarray(logp)[0])], events,
                           prefill=True)
            if self._obs is not None:
                # non-final chunks are async dispatch wall time; the final
                # chunk's span includes the first-token sync above
                self._obs.prefill_chunk(req.rid, req.slot, t_pf,
                                        sch.clock() - t_pf, len(chunk),
                                        final)

    def _overlap_decode_tick(self, slots: List[int],
                             events: List[RequestOutput]
                             ) -> List[RequestOutput]:
        """DEEP PIPELINE: dispatch this tick's decode BEFORE committing the
        previous one.

        The input token panel chains on device: each slot's token is the
        in-flight decode's un-synced output (a jax async value the device
        already holds), host-overridden only where the chain breaks — a
        slot fresh out of prefill, a slot re-admitted since the record was
        taken, or the first tick after an idle pipeline.  The host tail
        mirror advances at dispatch; a plain decode appends exactly one
        token, so the mirror stays exact without waiting, which is what
        keeps the refreeze decision (made before this sync) correct.  The
        dispatched tick is recorded and committed one tick later by
        :meth:`_sync_inflight` — where ``(slot, rid)`` liveness is
        re-checked, so tokens speculatively dispatched for a request that
        dies this tick are never committed.
        """
        sch = self.scheduler
        b = self.pool.slots
        t_dec = sch.clock() if self._obs is not None else 0.0
        rec = self._inflight
        if rec is None:
            # cold pipeline (first tick, or just drained): there is no
            # device token to chain on, so dispatch through the regular
            # decode entry from the host mirrors — same computation, and
            # _decode_chain only ever sees jit-output `prev` operands
            # (mixing host arrays in would key a second compile-cache
            # entry and break the zero-retrace bar)
            tokens = np.zeros((b, 1), np.int32)
            mask = np.zeros((b,), bool)
            for s in slots:
                tokens[s, 0] = self._last_tok[s]
                mask[s] = True
            tok, logp, self.state = self._decode(
                self.params, self.state, jnp.asarray(tokens),
                jnp.asarray(mask))
        else:
            chained = set()
            if rec["kind"] == "decode":
                for s, rid in rec["slots"]:
                    req = sch.active.get(s)
                    if req is not None and req.rid == rid:
                        chained.add(s)
            broken = [s for s in slots if s not in chained]
            if broken:
                ov = np.zeros((b,), np.int32)
                ovm = np.zeros((b,), bool)
                for s in broken:
                    ovm[s] = True
                    ov[s] = self._last_tok[s]
                dov, dovm = jnp.asarray(ov), jnp.asarray(ovm)
            else:
                # unbroken chain (the steady state): constant all-zero
                # overrides, transferred once and reused
                if self._ov_zero is None:
                    self._ov_zero = (
                        jnp.asarray(np.zeros((b,), np.int32)),
                        jnp.asarray(np.zeros((b,), bool)))
                dov, dovm = self._ov_zero
            mkey = tuple(slots)
            dmask = self._mask_cache.get(mkey)
            if dmask is None:
                if len(self._mask_cache) >= 64:
                    self._mask_cache.clear()
                mask = np.zeros((b,), bool)
                mask[list(slots)] = True
                dmask = self._mask_cache[mkey] = jnp.asarray(mask)
            tok, logp, self.state = self._decode_chain(
                self.params, self.state, rec["tok"], dov, dovm, dmask)
        for s in slots:
            self._tail_len[s] += 1
        new_rec = {"kind": "decode", "tok": tok, "logp": logp,
                   "ncommit": None, "dlen": None,
                   "slots": [(s, sch.active[s].rid) for s in slots],
                   "t0": t_dec, "n_slots": len(slots)}
        # commit tick t-1 while tick t computes behind it
        self._sync_inflight(events)
        self._inflight = new_rec
        return events

    def _sync_inflight(self, events: List[RequestOutput]) -> None:
        """Commit the in-flight tick's token window — THE designated sync
        point of the overlapped pipeline.

        This is the engine's only ``jax.block_until_ready`` and is
        registered (file, function) in
        :data:`repro.analysis.lint.DESIGNATED_SYNCS`; the block-until-ready
        lint rule flags the call anywhere else in the tree.  Liveness is
        re-checked per slot against the rid recorded at dispatch: a request
        that expired, was cancelled, or whose slot was re-admitted while
        the window was in flight has its tokens DISCARDED — the release /
        lane-set transitions already wiped the slot's device state, so the
        speculative appends were dead writes.  No-op when nothing is in
        flight (serial engine, drained pipeline).
        """
        rec, self._inflight = self._inflight, None
        if rec is None:
            return
        sch = self.scheduler
        jax.block_until_ready((rec["tok"], rec["logp"]))
        picked = np.asarray(rec["tok"])
        logps = np.asarray(rec["logp"])
        ncs = (np.asarray(rec["ncommit"])
               if rec["ncommit"] is not None else None)
        if self._obs is not None:
            # the decode/verify span under overlap runs dispatch ->
            # delayed sync: true device wall-clock, host work included
            # only where it failed to hide
            now = sch.clock()
            self._obs.decode_tick(rec["t0"], now - rec["t0"],
                                  rec["n_slots"], spec=ncs is not None,
                                  overlapped=True)
        for s, rid in rec["slots"]:
            req = sch.active.get(s)
            if req is None or req.rid != rid:
                continue          # died in flight: the window is discarded
            if ncs is None:
                self._emit(s, [int(picked[s])], [float(logps[s])], events)
            else:
                nc = int(ncs[s])
                self._tail_len[s] += nc      # t0 + accepted stay appended
                self.spec_hist[nc - 1] += 1  # nc - 1 = accepted drafts
                if self._adaptive is not None:
                    self._adaptive.update(s, int(rec["dlen"][s]), nc - 1)
                self._emit(s, [int(t) for t in picked[s, :nc]],
                           [float(l) for l in logps[s, :nc]], events)

    def _spec_tick(self, slots: List[int],
                   events: List[RequestOutput]) -> List[RequestOutput]:
        """One draft–verify decode tick over every decoding slot.

        Per live slot the host drafter proposes up to K continuations of
        the request's own history; the panel is clamped to the slot's tail
        headroom (a nearly-full tail simply speculates less — the regular
        refreeze machinery keeps working unchanged).  One jitted verify
        scores the whole [slots, K+1] panel, accepts per lane, and rolls
        back rejections; the host then commits each slot's window with
        stop scanning inside it.
        """
        sch = self.scheduler
        t_dec = sch.clock() if self._obs is not None else 0.0
        b, k = self.pool.slots, self._spec.k
        # degraded mode: under queue pressure drop the draft window to 0 —
        # every verify tick commits exactly one token, shrinking per-tick
        # latency so live slots finish (and free) sooner.  Host data only:
        # the [slots, K+1] panel shape never changes, so no retrace.
        degraded = (self._degrade_queue > 0
                    and len(sch.queue) >= self._degrade_queue)
        if degraded:
            self.fault_counters["degraded_ticks"] += 1
        tokens = np.zeros((b, k + 1), np.int32)
        mask = np.zeros((b,), bool)
        dlen = np.zeros((b,), np.int32)
        for s in slots:
            req = sch.active[s]
            tokens[s, 0] = self._last_tok[s]
            mask[s] = True
            room = self.pool.tail - 1 - int(self._tail_len[s])
            cap = 0 if degraded else min(k, room)
            if self._adaptive is not None and not degraded:
                # per-slot adaptive K: a slot whose drafts keep getting
                # rejected speculates less (host-side data only — the
                # [slots, K+1] panel shape, and hence the trace, is fixed)
                cap = min(cap, self._adaptive.draft_len(s))
            if cap > 0:
                try:
                    if (self._faults is not None
                            and self._faults.take(DRAFTER_ERROR,
                                                  self._tick_no)):
                        self._faults.raise_fault(DRAFTER_ERROR)
                    drafts = self.drafter.propose(
                        req.prompt + req.generated, cap)
                except Exception:
                    # a crashing drafter degrades its slot to a draftless
                    # tick (one committed token) — never the engine
                    self.fault_counters["drafter_error"] += 1
                    drafts = []
                dlen[s] = len(drafts)
                tokens[s, 1:1 + len(drafts)] = drafts
        slot_rids = [(s, sch.active[s].rid) for s in slots]
        tok, logp, ncommit, self.state = self._verify(
            self.params, self.state, jnp.asarray(tokens),
            jnp.asarray(mask), jnp.asarray(dlen))
        # cancellation-mid-spec-window fault: the victim's drafts were
        # built into the panel and verified, but the window has not
        # committed — the verified tokens must be discarded with the slot
        if self._faults is not None:
            alive = [s for s in slots if s in sch.active]
            if alive and self._faults.take(CANCEL_SPEC, self._tick_no):
                out = self._cancel_inner(
                    sch.active[self._faults.choose(alive)].rid)
                if out is not None:
                    events.append(out)
        if self.overlap:
            # shallow pipeline: the window was dispatched, not synced — it
            # commits at the NEXT tick's _sync_inflight (after that tick's
            # admission/prefill dispatch), rid-checked so a cancellation
            # landing between now and then discards it
            self._inflight = {"kind": "spec", "tok": tok, "logp": logp,
                              "ncommit": ncommit, "dlen": dlen,
                              "slots": slot_rids, "t0": t_dec,
                              "n_slots": len(slots)}
            return events
        picked, logps = np.asarray(tok), np.asarray(logp)
        ncs = np.asarray(ncommit)
        if self._obs is not None:
            # draft + verify dispatch through the window sync
            self._obs.decode_tick(t_dec, sch.clock() - t_dec, len(slots),
                                  spec=True)
        for s in slots:
            if s not in sch.active:
                continue      # cancelled inside the window: its verified
                              # tokens are never committed
            nc = int(ncs[s])
            self._tail_len[s] += nc          # t0 + accepted stay appended
            self.spec_hist[nc - 1] += 1      # nc - 1 = accepted drafts
            if self._adaptive is not None:
                self._adaptive.update(s, int(dlen[s]), nc - 1)
            self._emit(s, [int(t) for t in picked[s, :nc]],
                       [float(l) for l in logps[s, :nc]], events)
        return events

    def _emit(self, slot: int, toks: List[int], logprobs: List[float],
              events: List[RequestOutput], prefill: bool = False) -> None:
        """Commit one tick's token window for a slot; recycle the slot if
        that finished the request.  One RequestOutput snapshot (and one
        ``on_token`` callback) is emitted per window — per token on the
        non-speculative path, per accepted window under speculation."""
        req = self.scheduler.active[slot]
        before = len(req.generated)
        finished = self.scheduler.record_tokens(
            slot, toks, logprobs, decode_tick=not prefill) is not None
        # a stop inside a speculative window truncates the commit, so count
        # what actually landed, not what the tick offered
        self._tick_committed += len(req.generated) - before
        out = req.output()
        events.append(out)
        cb = self._callbacks.get(req.rid)
        if cb is not None:
            cb(out)
        if finished:
            if self._obs is not None:
                self._obs.request_finished(out, self.scheduler.clock())
            self._callbacks.pop(req.rid, None)
            self._pending_release.append(slot)   # batched flush at tick end
            self._tail_len[slot] = 0
            self._last_tok.pop(slot, None)
            if self._adaptive is not None:
                self._adaptive.reset(slot)   # next tenant starts fresh
        elif req.finish_reason is None:
            # (a reentrant cancel from this request's own callback leaves
            # finish_reason "cancelled" — _abort_slot already reset the
            # slot mirrors, so only a still-live request updates them)
            self._last_tok[slot] = req.generated[-1]

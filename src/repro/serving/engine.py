"""Serving engine: prefill -> freeze (compress) -> token-by-token decode.

This is the paper's §6.2 serving design, end to end:

1. ``prefill`` runs the full forward over the prompt and collects every
   layer's K/V (or recurrent state);
2. the prefill cache is magnitude-pruned and packed into the frozen
   compressed prefix (offline preprocessing, exactly like the paper's
   weight packing — "not suitable for dynamic KV values but remains
   effective for cached prompts");
3. ``generate`` decodes one token at a time against the compressed prefix +
   dense tail, optionally refreezing when the tail fills.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_kv import SparseKVCache, freeze_prefix
from repro.distributed import NULL_CTX
from repro.models import lm
from repro.models.attention import DenseKVCache


class Engine:
    def __init__(self, params, cfg, ctx=NULL_CTX, kv_mode: str = "sparse"):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.kv_mode = kv_mode
        self._decode = jax.jit(
            lambda p, c, t: lm.forward_decode(p, c, t, cfg, ctx))
        self._prefill = jax.jit(
            lambda p, b: lm.forward_prefill(p, b, cfg, ctx))

    # ------------------------------------------------------------------
    def prefill(self, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        hidden, collected = self._prefill(self.params, batch)
        p = lm.period_len(cfg)
        kinds = [lm.layer_kind(cfg, j) for j in range(p)]
        layers: Dict[str, Any] = {}
        for j, kind in enumerate(kinds):
            got = collected["layers"][f"l{j}"]
            if kind[0] == "attn":
                layers[f"l{j}"] = {"kv": self._build_kv(got["k"], got["v"])}
            else:
                layers[f"l{j}"] = {"state": got["state"]}
        cache = {"pos": jnp.asarray(collected["len"], jnp.int32),
                 "layers": layers}
        if cfg.family == "encdec":
            cross = collected["cross"]["l0"]
            cache["cross"] = {"k": cross["k"], "v": cross["v"]}
        logits = lm.logits_fn(self.params, hidden[:, -1:], cfg, self.ctx)
        return cache, logits[:, 0]

    def _build_kv(self, k_stack, v_stack):
        """k/v [P, B, Hkv, S, hd] -> per-period cache, host-packed.

        Pass 1 finds the max per-block nnz across layers (global magnitude
        pruning gives ragged block occupancy); pass 2 packs every layer at
        that common capacity so the stacked cache has static shapes — the
        stacked analogue of the paper's fixed offline capacity."""
        cfg = self.cfg
        n_periods = k_stack.shape[0]
        per = []
        cap_k = cap_v = None
        if self.kv_mode == "sparse" and n_periods > 1:
            probes = [freeze_prefix(
                k_stack[i], v_stack[i], cfg.kv_k_sparsity,
                cfg.kv_v_sparsity, tail_size=cfg.kv_tail,
                bs=min(128, k_stack.shape[3])) for i in range(n_periods)]
            cap_k = max(p.k_sp.capacity for p in probes)
            cap_v = max(p.v_sp.capacity for p in probes)
        for i in range(n_periods):
            k, v = k_stack[i], v_stack[i]
            s = k.shape[2]
            if self.kv_mode == "sparse":
                bs = min(128, s)
                per.append(freeze_prefix(
                    k, v, cfg.kv_k_sparsity, cfg.kv_v_sparsity,
                    tail_size=cfg.kv_tail, bs=bs,
                    capacity_k=cap_k, capacity_v=cap_v))
            else:
                pad = cfg.kv_tail
                kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
                per.append(DenseKVCache(kp, vp,
                                        jnp.asarray(s, jnp.int32)))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, jax.Array], steps: int,
                 greedy: bool = True, rng: Optional[jax.Array] = None):
        cache, logits = self.prefill(batch)
        b = batch["tokens"].shape[0]
        toks = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(steps):
            toks.append(tok)
            if self.kv_mode == "sparse":
                cache = self._maybe_refreeze(cache)
            logits, cache = self._decode(self.params, cache, tok[:, None])
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, logits).astype(jnp.int32)
        toks.append(tok)
        return jnp.stack(toks, axis=1), cache

    # ------------------------------------------------------------------
    def _maybe_refreeze(self, cache):
        """Fold full tails back into the compressed prefix (paper §6.2's
        amortized step).  Host-side, between jitted decode steps; note the
        prefix growth changes cache shapes -> one re-trace per refreeze."""
        from repro.core.sparse_kv import refreeze
        cfg = self.cfg
        layers = dict(cache["layers"])
        changed = False
        for name, leaf in layers.items():
            if "kv" not in leaf:
                continue
            kv = leaf["kv"]
            t = kv.k_tail.shape[3]          # stacked [P, B, Hkv, T, D]
            if int(kv.tail_len[0]) < t:
                continue
            n_periods = kv.k_tail.shape[0]
            per = [refreeze(jax.tree_util.tree_map(lambda a: a[i], kv),
                            cfg.kv_k_sparsity, cfg.kv_v_sparsity)
                   for i in range(n_periods)]
            cap_k = max(p.k_sp.capacity for p in per)
            cap_v = max(p.v_sp.capacity for p in per)
            if any(p.k_sp.capacity != cap_k or p.v_sp.capacity != cap_v
                   for p in per):
                # re-pack at a common capacity so the stack is rectangular
                per = [self._repack(p, cap_k, cap_v) for p in per]
            layers[name] = {**leaf, "kv": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *per)}
            changed = True
        if not changed:
            return cache
        return {**cache, "layers": layers}

    def _repack(self, kvc, cap_k, cap_v):
        from repro.core.sparse_kv import SparseKVCache

        def grow(sw, cap):
            pad = cap - sw.capacity
            if pad <= 0:
                return sw
            from repro.core.sparse_format import BlockSparseWeight
            vals = jnp.pad(sw.values,
                           [(0, 0)] * (sw.values.ndim - 1) + [(0, pad)])
            return BlockSparseWeight(sw.bitmap, vals, sw.scale, sw.shape,
                                     sw.block, sw.packed4)
        return SparseKVCache(grow(kvc.k_sp, cap_k), grow(kvc.v_sp, cap_v),
                             kvc.k_tail, kvc.v_tail, kvc.tail_len)

"""Asyncio streaming frontend over the continuous-batching engine.

The engine is NOT thread-safe and everything it owns — scheduler, pool,
the overlapped pipeline's in-flight record — is mutated only by its own
dedicated **engine thread** (:class:`EngineLoop`).  The asyncio HTTP
server (pure stdlib: ``asyncio.start_server``, no third-party deps)
never touches the engine directly: every control-plane operation —
submit, cancel, shutdown — is a closure queued on a thread-safe inbox
that the engine thread drains **at tick boundaries**, i.e. never while a
tick is mid-flight.  That single rule is what carries every PR 8
lifecycle guarantee over to the overlapped engine unchanged:

* a submit that must be shed runs on the engine thread between ticks, so
  load shedding can never race the in-flight dispatch — the shed request
  is rejected before it could ever reach a slot the pipeline still has
  speculative tokens for;
* a cancel lands at a tick boundary and flows through the engine's
  normal abort path; the overlapped commit (:meth:`ContinuousEngine.
  _sync_inflight`) re-checks ``(slot, rid)`` liveness, so the cancelled
  request's speculatively-dispatched window is discarded, never
  committed;
* deadline expiry already runs at tick start inside the engine.

Token streaming flows the other way, engine thread -> event loop: the
per-request ``on_token`` callback hands each :class:`RequestOutput`
snapshot to the request's ``asyncio.Queue`` via
``loop.call_soon_threadsafe`` — the only two thread-crossing primitives
in this file are that call and the inbox lock.

HTTP surface (HTTP/1.1, newline-delimited JSON over chunked transfer
encoding for streams):

* ``POST /v1/generate`` — body ``{"prompt": [ids...], "max_new_tokens":
  n, "temperature": t, "top_k": k, "top_p": p, "seed": s,
  "deadline_s": d, "ttft_deadline_s": d2}`` (all but ``prompt``
  optional).  Streams one JSON line per committed token window:
  ``{"request_id", "tokens": [new ids], "finished", "finish_reason"}``.
* ``POST /v1/cancel`` — body ``{"request_id": n}``; replies
  ``{"cancelled": bool}``.
* ``GET /healthz`` — liveness + tick counter.
* ``POST /v1/shutdown`` — clean shutdown: stop admitting, drain the
  engine thread (which quiesces the overlapped pipeline), then stop the
  server.  The CI smoke test drives exactly this path.
"""
from __future__ import annotations

import asyncio
import json
import threading
from collections import deque
from typing import Any, Callable, Deque, Dict, Optional

from .sampling import SamplingParams

_PARAM_FIELDS = ("temperature", "top_k", "top_p", "seed",
                 "max_new_tokens", "eos_id", "deadline_s",
                 "ttft_deadline_s")


def params_from_json(body: Dict[str, Any]) -> SamplingParams:
    """Build :class:`SamplingParams` from a request body, accepting only
    the whitelisted scalar fields (unknown keys are ignored so clients
    can version forward; ``stop_ids`` is deliberately excluded — token-id
    tuples over JSON invite type confusion and nothing serves them yet).
    """
    kw = {f: body[f] for f in _PARAM_FIELDS if body.get(f) is not None}
    return SamplingParams(**kw)


class EngineLoop:
    """The engine thread: ticks the engine, draining inbox ops at every
    tick boundary.

    ``post(op)`` is callable from any thread; ``op`` runs on the engine
    thread between ticks.  ``stop()`` asks the loop to exit — it drains
    the remaining ops, quiesces the engine (committing or discarding the
    overlapped pipeline's in-flight tick), and returns.
    """

    def __init__(self, engine, idle_wait: float = 0.002):
        self.engine = engine
        self.idle_wait = idle_wait
        self.ticks = 0
        self._ops: Deque[Callable[[], None]] = deque()
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self.error: Optional[BaseException] = None

    def post(self, op: Callable[[], None]) -> None:
        with self._lock:
            self._ops.append(op)
        self._wake.set()

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()

    def run(self) -> None:
        eng = self.engine
        try:
            while True:
                with self._lock:
                    ops = list(self._ops)
                    self._ops.clear()
                for op in ops:
                    op()
                if self._stop.is_set():
                    break
                sch = eng.scheduler
                if sch.done():
                    self._wake.wait(self.idle_wait)
                    self._wake.clear()
                    continue
                if (not sch.active and sch.queue
                        and min(r.next_admit for r in sch.queue)
                        > sch.clock()):
                    # whole queue backing off (paged deferral): sleep the
                    # shortest backoff instead of hot-spinning ticks
                    self._wake.wait(self.idle_wait)
                    self._wake.clear()
                eng.step()
                self.ticks += 1
            eng.quiesce()
        except BaseException as e:     # surfaced by the frontend on join
            self.error = e
            raise


class ServerFrontend:
    """Asyncio HTTP server bridging clients to one :class:`EngineLoop`.

    ``run()`` blocks the calling thread inside ``asyncio.run`` until
    shutdown; ``shutdown()`` is thread-safe.  ``ready`` (if given) is
    called on the event loop with the bound port once the socket is
    listening — tests use it to rendezvous, ``launch/serve --server``
    prints the URL from it.
    """

    def __init__(self, engine, host: str = "127.0.0.1", port: int = 0,
                 on_shutdown: Optional[Callable[[], None]] = None):
        self.host = host
        self.port = port                     # rebound to the real port
        self.loop_thread = EngineLoop(engine)
        self._on_shutdown = on_shutdown
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._shutdown = threading.Event()
        self.requests_served = 0

    # -- lifecycle ----------------------------------------------------------
    def run(self, ready: Optional[Callable[[int], None]] = None) -> None:
        asyncio.run(self._amain(ready))
        if self.loop_thread.error is not None:
            raise RuntimeError("engine thread died") \
                from self.loop_thread.error

    def shutdown(self) -> None:
        """Request a clean shutdown from any thread."""
        self._shutdown.set()
        loop = self._loop
        if loop is not None and loop.is_running():
            loop.call_soon_threadsafe(self._shutdown_evt.set)

    async def _amain(self, ready) -> None:
        self._loop = asyncio.get_running_loop()
        self._shutdown_evt = asyncio.Event()
        if self._shutdown.is_set():          # shutdown() beat run()
            self._shutdown_evt.set()
        engine_thread = threading.Thread(
            target=self.loop_thread.run, name="engine-loop", daemon=True)
        engine_thread.start()
        server = await asyncio.start_server(self._handle, self.host,
                                            self.port)
        self.port = server.sockets[0].getsockname()[1]
        if ready is not None:
            ready(self.port)
        try:
            await self._shutdown_evt.wait()
        finally:
            server.close()
            await server.wait_closed()
            # drain the engine thread off the event loop: it finishes the
            # ops already posted, quiesces the pipeline, then exits
            self.loop_thread.stop()
            await asyncio.get_running_loop().run_in_executor(
                None, engine_thread.join)
            if self._on_shutdown is not None:
                self._on_shutdown()

    # -- HTTP plumbing ------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, target, body = await self._read_request(reader)
            if method is None:
                return
            if method == "POST" and target == "/v1/generate":
                await self._generate(writer, body)
            elif method == "POST" and target == "/v1/cancel":
                await self._cancel(writer, body)
            elif method == "GET" and target == "/healthz":
                lt = self.loop_thread
                await self._json(writer, 200, {
                    "ok": lt.error is None, "ticks": lt.ticks,
                    "requests_served": self.requests_served})
            elif method == "POST" and target == "/v1/shutdown":
                await self._json(writer, 200, {"shutting_down": True})
                self._shutdown_evt.set()
            else:
                await self._json(writer, 404, {"error": "not found"})
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    async def _read_request(reader):
        line = await reader.readline()
        if not line:
            return None, None, None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            return None, None, None
        method, target = parts[0], parts[1]
        length = 0
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, val = h.decode("latin-1").partition(":")
            if name.strip().lower() == "content-length":
                length = int(val.strip())
        raw = await reader.readexactly(length) if length else b""
        try:
            body = json.loads(raw) if raw else {}
        except ValueError:
            body = None
        return method, target, body

    @staticmethod
    async def _json(writer, status: int, obj: Dict[str, Any]) -> None:
        payload = (json.dumps(obj) + "\n").encode()
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found"}.get(
            status, "OK")
        writer.write(
            f"HTTP/1.1 {status} {reason}\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(payload)}\r\n"
            f"Connection: close\r\n\r\n".encode() + payload)
        await writer.drain()

    # -- endpoints ----------------------------------------------------------
    async def _generate(self, writer, body) -> None:
        if (not isinstance(body, dict) or "prompt" not in body
                or not isinstance(body["prompt"], list)):
            await self._json(writer, 400,
                             {"error": "body must be JSON with a "
                                       "'prompt' token-id list"})
            return
        try:
            params = params_from_json(body)
            prompt = [int(t) for t in body["prompt"]]
        except (TypeError, ValueError) as e:
            await self._json(writer, 400, {"error": str(e)})
            return
        loop = asyncio.get_running_loop()
        snapshots: asyncio.Queue = asyncio.Queue()
        rid_fut: asyncio.Future = loop.create_future()

        def op():
            # engine thread, tick boundary: submit + register streaming.
            # A shed fires on_token synchronously in here — the snapshot
            # is queued before the rid resolves, so the client always
            # sees its terminal frame.
            def on_token(out):
                loop.call_soon_threadsafe(snapshots.put_nowait, out)
            rid = self.loop_thread.engine.submit(prompt, params,
                                                 on_token=on_token)
            loop.call_soon_threadsafe(rid_fut.set_result, rid)

        self.loop_thread.post(op)
        rid = await rid_fut
        self.requests_served += 1
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: application/x-ndjson\r\n"
            b"Transfer-Encoding: chunked\r\n"
            b"Connection: close\r\n\r\n")
        sent = 0
        try:
            while True:
                out = await snapshots.get()
                frame = {"request_id": rid,
                         "tokens": list(out.token_ids[sent:]),
                         "finished": out.finished,
                         "finish_reason": out.finish_reason}
                sent = len(out.token_ids)
                data = (json.dumps(frame) + "\n").encode()
                writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
                await writer.drain()
                if out.finished:
                    break
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        except (ConnectionError, OSError):
            # client went away mid-stream: cancel through the inbox so
            # the abort lands at a tick boundary like any other cancel
            self.loop_thread.post(
                lambda: self.loop_thread.engine.cancel(rid))

    async def _cancel(self, writer, body) -> None:
        if not isinstance(body, dict) or "request_id" not in body:
            await self._json(writer, 400,
                             {"error": "body must carry 'request_id'"})
            return
        rid = int(body["request_id"])
        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        self.loop_thread.post(
            lambda: loop.call_soon_threadsafe(
                fut.set_result, self.loop_thread.engine.cancel(rid)))
        await self._json(writer, 200, {"cancelled": bool(await fut)})

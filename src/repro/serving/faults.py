"""Deterministic fault injection for the serving engine.

A :class:`FaultPlan` is a seeded schedule of faults fired at **named
host-side sites** inside :class:`~repro.serving.engine.ContinuousEngine`'s
tick loop.  The engine consults the plan at each site (a dict lookup — no
device work, no extra traces) and the plan decides whether the fault fires
this tick; the contract under test is that the engine *survives the whole
plan*: every request finishes with a valid ``finish_reason``, refcount
invariants hold (run the matrix under the PR 7 checkify sanitized pool,
``REPRO_CHECKIFY=1``), no steady-state retraces appear, and requests the
plan did not touch stay token-identical to a fault-free run.

Sites (the first five are engine-integrated; the last two are harness
fixtures exercised by the tests/benchmarks):

``page-exhaustion``
    Admission-time arena pressure: the paged reservation check behaves as
    if no physical blocks were free, so the queue head is deferred through
    the scheduler's exponential-backoff requeue path.
``drafter-error``
    The speculative drafter raises mid-propose; the engine must degrade
    that slot to a draftless tick (one committed token), never crash.
``cancel-prefill``
    A request with partially-prefilled prompt state is cancelled between
    its chunks; its slot and pages must come back without perturbing
    co-tenant token streams.
``cancel-spec``
    A decoding request is cancelled *inside* the draft–verify window —
    after its drafts were built into the verify panel, before the window
    commits.  The verified tokens must be discarded, the slot released.
``double-release``
    An already-free slot is pushed through the release path again; the
    device transition is an idempotent no-op and the engine counts a
    warning instead of underflowing a refcount.
``snapshot-corruption``
    Not an engine site: :func:`corrupt_snapshot` truncates or scribbles
    over a saved prefix-cache snapshot so restore paths can prove they
    fail with a readable :class:`ValueError`, never a half-restore.
``deadline-race``
    Not an engine site: the harness submits requests whose wall-clock
    deadline expires the same tick EOS lands, pinning the precedence rule
    (a committed stop beats a later deadline check).

Everything here is host-side control flow: no jax imports, nothing
touches the jitted transitions, and the plan is a pure function of its
seed — the same seed replays the same faults against the same wave.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence, Tuple

import numpy as np

# engine-integrated sites
PAGE_EXHAUSTION = "page-exhaustion"
DRAFTER_ERROR = "drafter-error"
CANCEL_PREFILL = "cancel-prefill"
CANCEL_SPEC = "cancel-spec"
DOUBLE_RELEASE = "double-release"
ENGINE_SITES: Tuple[str, ...] = (
    PAGE_EXHAUSTION, DRAFTER_ERROR, CANCEL_PREFILL, CANCEL_SPEC,
    DOUBLE_RELEASE)
# harness-level fixtures (documented above; not consulted by the engine)
SNAPSHOT_CORRUPTION = "snapshot-corruption"
DEADLINE_RACE = "deadline-race"
ALL_SITES: Tuple[str, ...] = ENGINE_SITES + (SNAPSHOT_CORRUPTION,
                                             DEADLINE_RACE)


class FaultError(RuntimeError):
    """The exception an injected fault raises (e.g. inside the drafter)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: fire ``site`` at the first *applicable* engine
    tick ``>= tick`` (a cancel site waits until a victim exists; an
    admission site waits until something is queued)."""
    site: str
    tick: int

    def __post_init__(self):
        if self.site not in ALL_SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; known: {ALL_SITES}")
        if self.tick < 0:
            raise ValueError(f"fault tick must be >= 0: {self.tick}")


class FaultPlan:
    """A seeded, replayable schedule of :class:`Fault`\\ s.

    The engine calls :meth:`take` at each site with its current tick
    number; the plan pops the oldest matching fault whose scheduled tick
    has arrived.  Victim selection (which request a cancel site kills)
    goes through :meth:`choose`, drawn from the plan's own seeded RNG so
    an identical (seed, wave) pair replays identical faults.  ``fired``
    records ``(tick, site)`` for every fault that actually landed —
    the test harness asserts the plan drained (:meth:`exhausted`).

    ``on_fire`` is an optional ``(site, tick) -> None`` observer invoked
    on every firing — the engine points it at the observability layer so
    injected faults land on the request-lifecycle trace timeline.  It
    must stay a pure observer: the plan's decisions never depend on it.
    """

    def __init__(self, faults: Sequence[Fault] = (), seed: int = 0):
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._pending: List[Fault] = sorted(faults, key=lambda f: f.tick)
        self.fired: List[Tuple[int, str]] = []
        self.on_fire = None              # set by the engine when obs is on

    @classmethod
    def generate(cls, seed: int, ticks: int = 24,
                 sites: Optional[Sequence[str]] = None,
                 per_site: int = 1) -> "FaultPlan":
        """A deterministic plan from a seed: ``per_site`` firings of every
        engine-integrated site, scattered over ``[1, ticks)``."""
        rng = np.random.default_rng(seed)
        faults = []
        for site in (sites if sites is not None else ENGINE_SITES):
            for t in rng.integers(1, max(ticks, 2), size=per_site):
                faults.append(Fault(site, int(t)))
        return cls(faults, seed=seed)

    # -- engine-facing API --------------------------------------------------
    def take(self, site: str, tick: int) -> bool:
        """Pop (and record) the oldest pending ``site`` fault due by
        ``tick``.  Returns whether one fired."""
        for i, f in enumerate(self._pending):
            if f.site == site and f.tick <= tick:
                del self._pending[i]
                self.fired.append((tick, site))
                if self.on_fire is not None:
                    self.on_fire(site, tick)
                return True
        return False

    def choose(self, options: Sequence):
        """Seeded victim selection among ``options`` (deterministic for a
        fixed seed and call sequence)."""
        if not options:
            raise ValueError("FaultPlan.choose needs at least one option")
        return options[int(self._rng.integers(len(options)))]

    def raise_fault(self, site: str) -> None:
        raise FaultError(f"injected fault: {site} (seed={self.seed})")

    # -- harness-facing API -------------------------------------------------
    def pending(self) -> List[Fault]:
        return list(self._pending)

    def exhausted(self) -> bool:
        """True once every scheduled fault has fired — the matrix harness
        requires this, so a plan cannot 'pass' by never being applicable."""
        return not self._pending


def corrupt_snapshot(directory: str, mode: str = "truncate",
                     seed: int = 0) -> str:
    """Damage the newest snapshot under ``directory`` in place.

    ``mode="truncate"`` cuts ``arrays.npz`` to half its bytes (a crash
    mid-``rename`` cannot produce this — the atomic write-temp-then-rename
    protocol only exposes whole files — but a torn disk or a partial copy
    can); ``mode="garbage"`` overwrites a seeded byte range in the middle.
    Returns the path of the damaged file.  Restore must answer with a
    readable :class:`ValueError`, never a half-restore.
    """
    steps = sorted(n for n in os.listdir(directory)
                   if n.startswith("step_"))
    if not steps:
        raise ValueError(f"no snapshot steps under {directory!r}")
    path = os.path.join(directory, steps[-1], "arrays.npz")
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(size // 2, 1))
    elif mode == "garbage":
        rng = np.random.default_rng(seed)
        junk = rng.integers(0, 256, size=max(size // 4, 16),
                            dtype=np.uint8).tobytes()
        with open(path, "r+b") as f:
            f.seek(size // 3)
            f.write(junk)
    else:
        raise ValueError(f"unknown corruption mode {mode!r} "
                         "(want 'truncate' or 'garbage')")
    return path

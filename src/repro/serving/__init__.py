from .engine import Engine

from .engine import (Engine, ContinuousEngine, retrace_count,
                     stable_trace_counts)
from .cache_pool import ARENA_KEYS, BlockAllocator, CachePool
from .faults import (ALL_SITES, ENGINE_SITES, Fault, FaultError, FaultPlan,
                     corrupt_snapshot)
from .frontend import EngineLoop, ServerFrontend, params_from_json
from .sampling import RequestMetrics, RequestOutput, SamplingParams
from .scheduler import PrefixTrie, Request, Scheduler, block_hashes
from .spec import AdaptiveDraft, Drafter, NGramDrafter, SpecConfig

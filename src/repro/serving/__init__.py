from .engine import Engine, ContinuousEngine, retrace_count
from .cache_pool import CachePool
from .sampling import RequestMetrics, RequestOutput, SamplingParams
from .scheduler import Scheduler, Request
from .spec import Drafter, NGramDrafter, SpecConfig

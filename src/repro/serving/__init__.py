from .engine import Engine, ContinuousEngine, retrace_count
from .cache_pool import CachePool
from .scheduler import Scheduler, Request

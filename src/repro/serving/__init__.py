from .engine import (Engine, ContinuousEngine, retrace_count,
                     stable_trace_counts)
from .cache_pool import CachePool
from .sampling import RequestMetrics, RequestOutput, SamplingParams
from .scheduler import Scheduler, Request
from .spec import AdaptiveDraft, Drafter, NGramDrafter, SpecConfig

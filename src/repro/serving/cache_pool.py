"""Slot-pooled, jit-stable sparse-KV cache for continuous batching.

The legacy one-shot engine stored the compressed prefix at whatever
capacity the data produced, so every refreeze grew the cache shapes and
forced a fresh ``jax.jit`` trace of the decode step — fatal for a serving
engine.  The pool inverts that: **storage is sized once, data moves within
it**.

Per layer period, every slot owns

* a fixed grid of ``max_blocks`` compressed sequence blocks — bitmap words
  plus packed values at a *static* per-block capacity (``pack_blocks``
  drops overflow consistently from bitmap and values, so the bitmap always
  describes exactly what is stored);
* a dense ``tail`` ring of ``tail`` tokens for freshly decoded K/V.

Slot occupancy lives in three int32 ``[slots]`` vectors (``pos``,
``prefix_blocks``, ``tail_len``); validity is *masked*, never re-shaped.
Refreeze therefore folds a full tail into the next free prefix blocks **in
place**: compress the tail of every full slot at the pool's static
capacity, scatter the new blocks at each slot's own offset, bump the
lengths.  No shape changes, no retrace — the decode step compiles exactly
once per pool geometry, which is the property the paper's "cache frozen in
model state" design needs to survive heavy multi-tenant traffic.

Both dense and sparse KV live behind this one interface: a dense pool is
just ``k_sparsity = v_sparsity = 0`` (full per-block capacity), for which
compression is a bit-exact round trip.

**Paged mode** (``paged=True``) generalizes the per-slot block grid into a
pool-global arena: compressed blocks live once in ``[P, n_phys, Hkv, X]``
storage, each slot's prefix is a row of the ``[slots, max_blocks]`` int32
**block table**, and a ``[n_phys]`` **refcount** vector tracks sharing —
N requests whose prompts share a prefix point their table rows at the SAME
physical blocks (stored once, attended over once).  Frozen blocks are
immutable; the dense tail ring is each slot's private working copy, and
refreeze/prefill always append FRESH physical ids past the shared prefix —
copy-on-write at the divergence block by construction, never a write into
shared storage.  The table and refcount are data, so every transition
below stays pure over static shapes and decode still compiles exactly once
per pool geometry.  The host-side id lifecycle (free list, LRU reuse of
refcount-0 cached blocks, hash bookkeeping) lives in
:class:`BlockAllocator`; the device transitions only consume the id
vectors it hands out.
"""
from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import checkify as _checkify

from repro.core.sparse_format import _ceil_to, LANE
from repro.core.sparse_kv import append_tail_panel, freeze_chunk_blocks
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class CachePool:
    """Geometry + pure state-transition ops for the pooled serving cache.

    The dataclass itself is immutable config; all state lives in the pytree
    returned by :meth:`init_state` and flows through the pure methods below
    (each is jitted exactly once by the engine).
    """
    cfg: Any
    slots: int
    max_blocks: int          # compressed-prefix capacity, in (bs,)-blocks
    bs: int                  # tokens per compressed block
    tail: int                # dense-tail ring size (tokens)
    cap_k: int               # packed K values per block (static)
    cap_v: int
    paged: bool = False      # pool-global arena + per-slot block table
    n_phys: int = 0          # physical blocks in the paged arena
    checkify: bool = False   # opt-in sanitized mode (see ``checkified``)

    @classmethod
    def build(cls, cfg, slots: int, max_tokens: int,
              bs: int = 0, capacity_slack: float = 1.25,
              paged: bool = False, n_phys: int = 0,
              checkify: Optional[bool] = None) -> "CachePool":
        """Size a pool for ``slots`` concurrent requests of up to
        ``max_tokens`` context each.

        Per-block value capacity is the nominal density times the block
        size, padded by ``capacity_slack`` and rounded to the lane size —
        headroom for the unevenness of the paper's layer-wide magnitude
        rule.  Zero sparsity always gets full capacity (exact round trip).

        ``paged=True`` stores compressed blocks in a shared physical arena
        of ``n_phys`` blocks (default ``slots * max_blocks`` — the same
        prefix bytes as the flat pool) indexed through per-slot block
        tables, so requests sharing a prefix store it once.

        Raises :class:`ValueError` for geometries the pool cannot serve:
        architecture families with state the pooled path would drop
        (cross-attention / frontend embeddings / recurrent layers), and a
        ``kv_tail`` that is not a whole number of blocks (refreeze folds
        the tail in whole blocks).
        """
        try:
            lm._attn_kinds(cfg)   # ssm/hybrid/encdec/frontend families
        except ValueError as e:
            raise ValueError(
                f"CachePool cannot serve arch {cfg.name!r} "
                f"(family {cfg.family!r}): {e}") from None
        bs = bs or min(128, cfg.kv_tail)
        if cfg.kv_tail % bs != 0:
            raise ValueError(
                f"kv_tail={cfg.kv_tail} is not a multiple of the block "
                f"size bs={bs}: refreeze folds the dense tail into whole "
                f"(bs,)-token compressed blocks")
        l = bs * cfg.hd

        def cap(sparsity: float) -> int:
            density = 1.0 - sparsity
            if density >= 1.0:
                return l
            return min(_ceil_to(int(round(density * l * capacity_slack)),
                                LANE), l)
        max_blocks = max(-(-int(max_tokens) // bs), 1)
        if paged:
            n_phys = n_phys or slots * max_blocks
        if checkify is None:
            checkify = os.environ.get("REPRO_CHECKIFY", "0") not in ("", "0")
        return cls(cfg=cfg, slots=slots, max_blocks=max_blocks, bs=bs,
                   tail=cfg.kv_tail, cap_k=cap(cfg.kv_k_sparsity),
                   cap_v=cap(cfg.kv_v_sparsity), paged=paged,
                   n_phys=n_phys if paged else 0, checkify=checkify)

    # -- geometry -----------------------------------------------------------
    @property
    def capacity_tokens(self) -> int:
        """Max context length a slot may be admitted for.

        Conservative: refreeze folds the whole live context into the prefix
        over time, so admission bounds by the prefix storage alone — the
        tail is working space, not extra capacity."""
        return self.max_blocks * self.bs

    def nbytes(self) -> int:
        """Total pooled storage, for capacity planning."""
        return sum(int(np.prod(l.shape)) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(
                       jax.eval_shape(self.init_state)))

    def geometry(self) -> Dict[str, Any]:
        """Everything a snapshot of the paged arena depends on.

        Two pools can exchange arena bytes + prefix hashes iff these match:
        block content is a function of the architecture, the block size,
        the packed capacities, and the compression rule; the arena layout
        adds ``n_phys`` / head-count / dtype.  ``slots`` is deliberately
        ABSENT — the arena is ``[P, n_phys, Hkv, X]``, slot-independent,
        so a restarted server may resize its slot count and still restore
        a warm cache.  (Content-addressed chained hashes make *stale* data
        impossible by construction; geometry is the only thing to check.)
        """
        cfg = self.cfg
        return {
            "arch": cfg.name,
            "paged": self.paged,
            "bs": self.bs,
            "max_blocks": self.max_blocks,
            "n_phys": self.n_phys,
            "cap_k": self.cap_k,
            "cap_v": self.cap_v,
            "n_kv": cfg.n_kv,
            "hd": cfg.hd,
            "n_periods": cfg.n_layers // lm.period_len(cfg),
            "cdtype": np.dtype(cfg.cdtype).name,
            "kv_k_sparsity": cfg.kv_k_sparsity,
            "kv_v_sparsity": cfg.kv_v_sparsity,
        }

    # -- sanitized mode -----------------------------------------------------
    def _check(self, pred, msg: str) -> None:
        """Emit a checkify invariant when the pool was built with
        ``checkify=True`` (no-op otherwise, so the default engine path
        traces zero check primitives).  Eager callers fail immediately
        with ``JaxRuntimeError``; jitted callers must functionalize via
        :func:`checkified`."""
        if self.checkify:
            _checkify.check(pred, msg)

    # -- state --------------------------------------------------------------
    def init_state(self) -> Dict[str, Any]:
        """Zeroed pool pytree.  Leaves under ``layers`` carry a leading
        period axis so the model's ``lax.scan`` slices them per layer.

        Flat pool: compressed leaves are per-slot grids
        ``[P, slots, Hkv, max_blocks, X]``.  Paged pool: compressed leaves
        are the shared arena ``[P, n_phys, Hkv, X]`` plus the pool-level
        ``table [slots, max_blocks]`` / ``refcount [n_phys]`` int32
        vectors; the dense tails stay per-slot either way (the tail is
        private working state, never shared)."""
        cfg = self.cfg
        p = lm.period_len(cfg)
        n_periods = cfg.n_layers // p
        hkv, hd, dt = cfg.n_kv, cfg.hd, cfg.cdtype
        b, sb, w = self.slots, self.max_blocks, self.bs * hd // 32

        def kv_leaf():
            if self.paged:
                n = self.n_phys
                comp = {
                    "k_bitmap": jnp.zeros((n_periods, n, hkv, w), jnp.uint32),
                    "k_values": jnp.zeros((n_periods, n, hkv, self.cap_k),
                                          dt),
                    "v_bitmap": jnp.zeros((n_periods, n, hkv, w), jnp.uint32),
                    "v_values": jnp.zeros((n_periods, n, hkv, self.cap_v),
                                          dt),
                }
            else:
                comp = {
                    "k_bitmap": jnp.zeros((n_periods, b, hkv, sb, w),
                                          jnp.uint32),
                    "k_values": jnp.zeros((n_periods, b, hkv, sb, self.cap_k),
                                          dt),
                    "v_bitmap": jnp.zeros((n_periods, b, hkv, sb, w),
                                          jnp.uint32),
                    "v_values": jnp.zeros((n_periods, b, hkv, sb, self.cap_v),
                                          dt),
                }
            return {
                **comp,
                "k_tail": jnp.zeros((n_periods, b, hkv, self.tail, hd), dt),
                "v_tail": jnp.zeros((n_periods, b, hkv, self.tail, hd), dt),
            }
        state = {
            "pos": jnp.zeros((b,), jnp.int32),
            "prefix_blocks": jnp.zeros((b,), jnp.int32),
            "tail_len": jnp.zeros((b,), jnp.int32),
            "layers": {f"l{j}": {"kv": kv_leaf()} for j in range(p)},
        }
        if self.paged:
            state["table"] = jnp.zeros((b, sb), jnp.int32)
            state["refcount"] = jnp.zeros((self.n_phys,), jnp.int32)
        return state

    def state_axes(self) -> Dict[str, Any]:
        """Logical-axes pytree matching :meth:`init_state` leaf for leaf —
        the pool's own description of how its storage may shard
        (``distributed/serving_sharding`` turns it into NamedShardings).

        Slot occupancy vectors are ``[slots]`` -> the slot axis; every
        flat layer leaf is ``[P, slots, Hkv, ...]`` -> slots over the data
        axes, KV heads over the model axis, block/ring/packed dims
        unsharded (block storage is per-(slot, head) and refreeze's
        scatter is per-slot — no cross-shard writes ever happen).

        Paged: the block table shards with the slots it indexes; the
        arena's physical-block axis is REPLICATED over the data axes (any
        slot on any data shard may point at any physical block — that
        cross-slot reach is the whole point of sharing) while its KV-head
        axis still shards over the model axis, splitting the arena bytes
        the same way the flat grid split; the refcount vector is
        replicated (scatter-adds into it are identical on every shard).
        """
        p = lm.period_len(self.cfg)

        def kv_axes():
            comp = ((None, None, "kv_heads", None) if self.paged
                    else (None, "slots", "kv_heads", None, None))
            tail = (None, "slots", "kv_heads", None, None)
            return {**{k: comp for k in ("k_bitmap", "k_values",
                                         "v_bitmap", "v_values")},
                    "k_tail": tail, "v_tail": tail}
        axes = {
            "pos": ("slots",),
            "prefix_blocks": ("slots",),
            "tail_len": ("slots",),
            "layers": {f"l{j}": {"kv": kv_axes()} for j in range(p)},
        }
        if self.paged:
            axes["table"] = ("slots", None)
            axes["refcount"] = (None,)
        return axes

    # -- transitions (pure; the engine jits each exactly once) --------------
    def refreeze(self, state: Dict[str, Any],
                 new_ids: Optional[jax.Array] = None) -> Dict[str, Any]:
        """Fold every full tail into its slot's next free prefix blocks.

        In-place at static shapes: compress all slots' tails at the pool
        capacity, scatter each full slot's new blocks at its own
        ``prefix_blocks`` offset, select per slot.  Slots whose tail is not
        full come back bit-identical.  The caller must ensure no full slot
        overflows ``max_blocks`` (see ``Scheduler`` admission).

        Paged pool: ``new_ids`` int32 ``[slots, tail // bs]`` must carry a
        FRESH physical block id per (full slot, tail block) — the host
        :class:`BlockAllocator` hands them out, which is what guarantees
        the fold never writes shared storage (copy-on-write at the
        divergence block: the tail is the private copy, the fold targets
        fresh pages).  Rows for non-full slots are ignored.  The ids land
        in the arena + each full slot's table row, and their refcounts go
        to 1.
        """
        cfg = self.cfg
        t, tb = self.tail, self.tail // self.bs
        full = state["tail_len"] >= t                           # [B]
        pb = state["prefix_blocks"]
        if self.paged:
            if new_ids is None:
                raise ValueError("paged refreeze needs fresh ids")
            # masked flat scatter: non-full slots' rows are re-pointed at
            # id == n_phys, which every mode="drop" scatter discards
            ids = jnp.asarray(new_ids, jnp.int32)               # [B, tb]
            drop_ids = jnp.where(full[:, None], ids,
                                 self.n_phys).reshape(-1)       # [B*tb]
            if self.checkify:
                # sanitized mode: ids for full slots must be in-arena AND
                # unreferenced (fresh pages are what guarantees
                # copy-on-write); id == n_phys sentinel rows are the
                # intentional drops.  Guarded so the default path traces
                # zero extra eqns.
                live_id = drop_ids < self.n_phys
                self._check(jnp.all(jnp.where(live_id, drop_ids >= 0,
                                              True)),
                            "refreeze: fresh id out of arena range")
                rc = jnp.take(state["refcount"], drop_ids, mode="clip")
                self._check(jnp.all(jnp.where(live_id, rc == 0, True)),
                            "refreeze: fresh id already referenced "
                            "(copy-on-write violation)")
        new_layers = {}
        for name, leaf in state["layers"].items():
            kv = leaf["kv"]
            p_, b_, hkv, _, hd = kv["k_tail"].shape
            flat = lambda a: a.reshape(p_ * b_, hkv, t, hd)
            k_bm, k_vl, v_bm, v_vl = freeze_chunk_blocks(
                flat(kv["k_tail"]), flat(kv["v_tail"]),
                cfg.kv_k_sparsity, cfg.kv_v_sparsity,
                self.bs, self.cap_k, self.cap_v)

            if self.paged:
                def write(dst, upd):
                    # [P*B, Hkv, tb, X] -> [P, B*tb, Hkv, X] rows, scattered
                    # at the fresh ids on the arena's physical-block axis
                    u = upd.reshape(p_, b_, hkv, tb, -1)
                    u = u.transpose(0, 1, 3, 2, 4).reshape(
                        p_, b_ * tb, hkv, -1)
                    return dst.at[:, drop_ids].set(
                        u.astype(dst.dtype), mode="drop")
            else:
                unflat = lambda a: a.reshape((p_, b_) + a.shape[1:])

                def write(dst, upd):
                    # per-slot offset scatter over the block axis
                    upd = unflat(upd)
                    out = jax.vmap(
                        lambda db, ub, off: jax.lax.dynamic_update_slice(
                            db, ub.astype(db.dtype), (0, 0, off, 0)),
                        in_axes=(1, 1, 0), out_axes=1)(dst, upd, pb)
                    sel = full.reshape((1, b_) + (1,) * (dst.ndim - 2))
                    return jnp.where(sel, out, dst)

            new_layers[name] = {"kv": {
                **kv,
                "k_bitmap": write(kv["k_bitmap"], k_bm),
                "k_values": write(kv["k_values"], k_vl),
                "v_bitmap": write(kv["v_bitmap"], v_bm),
                "v_values": write(kv["v_values"], v_vl),
            }}
        grow = jnp.where(full, tb, 0).astype(jnp.int32)
        if self.checkify:
            self._check(jnp.all(jnp.where(full, pb + tb <= self.max_blocks,
                                          True)),
                        "refreeze: full slot would overflow max_blocks")
        out = {**state, "layers": new_layers,
               "prefix_blocks": pb + grow,
               "tail_len": jnp.where(full, 0, state["tail_len"])}
        if self.paged:
            # table rows grow by tb entries at each full slot's own offset
            # (ids clipped in range: table entries are consumed by kernel
            # index maps, so even dead ones must address real storage)
            row_ids = jnp.clip(ids, 0, self.n_phys - 1)
            grown = jax.vmap(
                lambda row, idr, off: jax.lax.dynamic_update_slice(
                    row, idr, (off,)))(state["table"], row_ids, pb)
            out["table"] = jnp.where(full[:, None], grown, state["table"])
            out["refcount"] = state["refcount"].at[drop_ids].add(
                1, mode="drop")
        return out

    def assign_blocks(self, state: Dict[str, Any], slot: jax.Array,
                      ids: jax.Array, n: jax.Array) -> Dict[str, Any]:
        """Point a freshly-admitted slot's table row at ``n`` existing
        physical blocks (a prefix-cache hit): entries ``[0, n)`` of the
        row become ``ids[:n]``, the blocks' refcounts increment, and the
        slot's lengths jump to the shared prefix (``n`` blocks, empty
        tail) — the prefill those blocks would have required is skipped.

        ``ids`` int32 ``[max_blocks]`` (entries past ``n`` ignored),
        ``slot``/``n`` scalar int32.  Paged pools only.  Pure data motion
        at static shapes: admitting a hit of any length reuses one trace.
        """
        if not self.paged:
            raise ValueError("assign_blocks is a paged-pool transition")
        sb = self.max_blocks
        live = jnp.arange(sb) < n
        if self.checkify:
            self._check(jnp.all((jnp.asarray(n) >= 0)
                                & (jnp.asarray(n) <= sb)),
                        "assign_blocks: n out of range")
            self._check(jnp.all(jnp.where(live,
                                          (ids >= 0) & (ids < self.n_phys),
                                          True)),
                        "assign_blocks: block id out of arena range")
        row = jnp.where(live, jnp.clip(ids, 0, self.n_phys - 1), 0)
        table = jax.lax.dynamic_update_slice(
            state["table"], row[None].astype(jnp.int32), (slot, 0))
        rc_ids = jnp.where(live, ids, self.n_phys)
        n = jnp.asarray(n, jnp.int32)
        return {**state,
                "table": table,
                "refcount": state["refcount"].at[rc_ids].add(1, mode="drop"),
                "pos": state["pos"].at[slot].set(n * self.bs),
                "prefix_blocks": state["prefix_blocks"].at[slot].set(n),
                "tail_len": state["tail_len"].at[slot].set(0)}

    def append_many(self, state: Dict[str, Any],
                    panels: Dict[str, Any], n: jax.Array) -> Dict[str, Any]:
        """Append up to ``m`` fresh K/V tokens per slot into every layer's
        dense tail ring at the slot's own ``tail_len`` offset.

        ``panels``: ``{layer: {"k": [P, B, Hkv, m, D], "v": ...}}``;
        ``n`` int32 scalar or ``[B]`` — valid panel tokens per slot
        (``<= m``; 0 = passthrough).  Advances ``pos``/``tail_len`` by
        ``n``.  Pool-level twin of the verify step's in-layer append:
        the engine's verify forward writes each layer inside its scan
        (``models.attention.pooled_attn_panel``) through the SAME
        :func:`~repro.core.sparse_kv.append_tail_panel` core this method
        uses — change the write semantics there, not here.  This entry
        appends across all layers at once for direct pool callers and the
        rollback/refreeze property tests.  Pure masked writes at static
        shapes — jits once per panel width.
        """
        n = jnp.broadcast_to(jnp.asarray(n, jnp.int32), (self.slots,))
        tl = state["tail_len"]
        new_layers = {}
        for name, leaf in state["layers"].items():
            kv, src = leaf["kv"], panels[name]
            write = jax.vmap(append_tail_panel, in_axes=(0, 0, None, None))
            new_layers[name] = {"kv": {
                **kv,
                "k_tail": write(kv["k_tail"], src["k"], tl, n),
                "v_tail": write(kv["v_tail"], src["v"], tl, n),
            }}
        return {**state, "layers": new_layers,
                "pos": state["pos"] + n, "tail_len": tl + n}

    def rollback(self, state: Dict[str, Any], n: jax.Array
                 ) -> Dict[str, Any]:
        """Un-append the last ``n`` tokens per slot: a pure masked length
        decrement (``pos``/``tail_len``), no storage touched — validity is
        length-gated everywhere, so decremented entries are dead.

        ``n`` int32 scalar or ``[B]``, clamped to ``tail_len`` — a
        rollback can only surrender tail tokens; it never crosses the
        frozen-prefix boundary (refrozen tokens are committed by
        construction: the engine rolls back *within* the tick that
        appended, before any refreeze can fold the tail).  This is what
        makes draft–verify speculation free on this cache: rejected
        drafts cost one subtraction, not a retrace or a re-pack.
        """
        n = jnp.broadcast_to(jnp.asarray(n, jnp.int32), (self.slots,))
        n = jnp.clip(n, 0, state["tail_len"])
        return {**state, "pos": state["pos"] - n,
                "tail_len": state["tail_len"] - n}

    def release(self, state: Dict[str, Any], slot: jax.Array
                ) -> Dict[str, Any]:
        """Recycle one or many slots: zero their lengths.  Stale
        prefix/tail contents stay in storage but are fully masked
        (validity is length-gated everywhere), so the next admission
        simply overwrites them.

        ``slot`` is a scalar or an int32 ``[R]`` vector (batched release —
        one jitted call recycles every slot a tick finished; pad with
        ``-1``, which matches nothing).  Paged pool: every released slot's
        live table entries decrement their blocks' refcounts (shared
        blocks scatter-add correctly when several released slots point at
        the same page) and its table row resets to 0 — the HOST allocator
        decides what a refcount-0 page becomes (cached for re-hit, or
        free).

        **Idempotent**: releasing an already-free slot is a no-op, not a
        refcount underflow — its lengths are already 0, so the paged decref
        mask (gated on ``prefix_blocks``) selects nothing and zeroing the
        lengths again changes nothing.  Even the sanitized-mode underflow
        check passes (it screens only live table entries).  The engine
        counts double releases as warnings (``fault_counters``); the
        device transition absorbs them — a crashing request-teardown path
        can retry safely.
        """
        slot = jnp.atleast_1d(jnp.asarray(slot, jnp.int32))     # [R]
        rel = jnp.any(slot[:, None] == jnp.arange(self.slots)[None, :],
                      axis=0)                                   # [B]
        z = lambda a: jnp.where(rel, 0, a)
        out = {**state, "pos": z(state["pos"]),
               "prefix_blocks": z(state["prefix_blocks"]),
               "tail_len": z(state["tail_len"])}
        if self.paged:
            live = rel[:, None] & (jnp.arange(self.max_blocks)[None, :]
                                   < state["prefix_blocks"][:, None])
            if self.checkify:
                rc = jnp.take(state["refcount"], state["table"],
                              mode="clip")
                self._check(jnp.all(jnp.where(live, rc > 0, True)),
                            "release: refcount underflow (device double "
                            "free)")
            ids = jnp.where(live, state["table"],
                            self.n_phys).reshape(-1)
            out["refcount"] = state["refcount"].at[ids].add(-1, mode="drop")
            out["table"] = jnp.where(rel[:, None], 0, state["table"])
        return out

    # -- snapshot (paged arena <-> host trees) -------------------------------
    def arena_leaves(self, state: Dict[str, Any]) -> Dict[str, Any]:
        """The shared-arena storage of a paged pool as a host tree
        ``{layer: {leaf: np.ndarray}}`` — exactly the leaves a warm-restart
        snapshot must persist (per-slot tails / tables / occupancy are
        in-flight request state and deliberately excluded: after a crash
        there are no in-flight requests, only shareable frozen content).
        """
        if not self.paged:
            raise ValueError("arena_leaves is a paged-pool helper")
        # host snapshot boundary, never traced
        return {name: {k: np.asarray(leaf["kv"][k])  # jitlint: disable=host-sync
                       for k in ARENA_KEYS}
                for name, leaf in state["layers"].items()}

    def load_arena(self, state: Dict[str, Any],
                   leaves: Dict[str, Any]) -> Dict[str, Any]:
        """Inverse of :meth:`arena_leaves`: a fresh state with the arena
        storage replaced by ``leaves`` (shape/dtype-checked per leaf, with
        the failing leaf named — restore must never half-apply)."""
        if not self.paged:
            raise ValueError("load_arena is a paged-pool helper")
        new_layers = {}
        for name, leaf in state["layers"].items():
            kv = dict(leaf["kv"])
            for k in ARENA_KEYS:
                # host restore boundary, never traced
                have, got = kv[k], np.asarray(leaves[name][k])  # jitlint: disable=host-sync
                if have.shape != got.shape or have.dtype != got.dtype:
                    raise ValueError(
                        f"arena leaf {name}/{k}: pool expects "
                        f"{have.shape} {have.dtype}, snapshot carries "
                        f"{got.shape} {got.dtype}")
                kv[k] = jnp.asarray(got)
            new_layers[name] = {"kv": kv}
        return {**state, "layers": new_layers}


# the compressed-block storage leaves of one layer's kv tree — the paged
# arena's persistent content (tails are private in-flight state)
ARENA_KEYS = ("k_bitmap", "k_values", "v_bitmap", "v_values")


# errors screened by the sanitized mode: the pool's own checkify.check
# invariants plus NaN and div-by-zero.  Built-in index OOB checks are
# deliberately NOT enabled — the pool's ``mode="drop"`` scatters use
# id == n_phys as an intentional out-of-range sentinel, which the generic
# OOB screen cannot distinguish from a bug; OOB discipline is covered by
# the explicit sentinel-aware checks above instead.
POOL_CHECKS = (_checkify.user_checks | _checkify.nan_checks
               | _checkify.div_checks)


def checkified_raw(fn: Callable) -> Callable:
    """The jit-composable half of :func:`checkified`: returns the
    functionalized transition ``(err, out) = fn'(*args)`` without the
    host-side throw (the engine jits this and throws at its own sync
    boundary)."""
    return _checkify.checkify(fn, errors=POOL_CHECKS)


def checkified(fn: Callable) -> Callable:
    """Functionalize a pool transition for the sanitized mode.

    ``CachePool(checkify=True)`` (or env ``REPRO_CHECKIFY=1``) plants
    ``checkify.check`` invariants in the transitions; those raise eagerly
    but cannot be traced by a plain ``jax.jit``.  This wrapper runs the
    transition under :func:`jax.experimental.checkify.checkify` and throws
    the first accumulated error on the host — usable under jit.
    """
    checked = _checkify.checkify(fn, errors=POOL_CHECKS)

    def run(*args, **kwargs):
        err, out = checked(*args, **kwargs)
        err.throw()
        return out
    return run


class BlockAllocator:
    """Host-side physical-block lifecycle for the paged pool.

    The device transitions above are pure data motion; THIS object decides
    which ids they move.  Three populations partition ``[0, n_phys)``:

    * **free** — never used or fully reclaimed; a LIFO stack.
    * **live** — refcount > 0: referenced by at least one slot's table row.
    * **cached** — refcount == 0 but still holding a registered
      (content-hashed) block; kept in an LRU so a future prompt sharing
      the prefix can revive it for free.  ``alloc`` evicts from the LRU's
      cold end only when the free stack runs dry, invalidating the hash
      through ``on_evict`` (the engine points that at its prefix index).

    The allocator mirrors refcounts so admission can reason about
    availability without a device sync; the device ``refcount`` vector
    carries the same counts for on-device masking and the property tests.
    """

    def __init__(self, n_phys: int,
                 on_evict: Optional[Callable[[int], None]] = None):
        self.n_phys = n_phys
        self.on_evict = on_evict
        self.evictions = 0               # lifetime LRU evictions (telemetry)
        self._free: List[int] = list(range(n_phys - 1, -1, -1))
        self._ref = np.zeros(n_phys, np.int64)
        self._cached: "OrderedDict[int, int]" = OrderedDict()  # id -> hash
        self._hash2id: Dict[int, int] = {}

    # -- queries -------------------------------------------------------------
    def free_blocks(self) -> int:
        """Blocks an ``alloc`` could hand out right now (free + evictable)."""
        return len(self._free) + len(self._cached)

    def refcount(self, bid: int) -> int:
        # host numpy bookkeeping array, not a device value
        return int(self._ref[bid])  # jitlint: disable=host-sync

    def lookup(self, h: int) -> Optional[int]:
        """Physical id of the block registered under chained hash ``h``."""
        return self._hash2id.get(h)

    # -- lifecycle -------------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Hand out ``n`` fresh ids at refcount 1, evicting the LRU's cold
        end when the free stack runs dry.  The engine's admission
        reservation guarantees this never runs out — treat failure as a
        bookkeeping bug, not backpressure."""
        ids = []
        for _ in range(n):
            if self._free:
                bid = self._free.pop()
            else:
                if not self._cached:
                    raise RuntimeError(
                        "BlockAllocator exhausted: admission reservations "
                        "must cover every alloc")
                bid, h = self._cached.popitem(last=False)      # LRU evict
                del self._hash2id[h]
                self.evictions += 1
                if self.on_evict is not None:
                    self.on_evict(h)
            self._ref[bid] = 1
            ids.append(bid)
        return ids

    def register(self, bid: int, h: int) -> bool:
        """Associate a live block with its chained content hash so future
        prompts can share it.  First writer wins (a concurrent duplicate
        simply stays private); returns whether the hash was recorded."""
        if h in self._hash2id:
            return False
        self._hash2id[h] = bid
        return True

    def hash_of(self, bid: int) -> Optional[int]:
        for h, i in self._hash2id.items():
            if i == bid:
                return h
        return None

    def incref(self, ids: Sequence[int]) -> None:
        """Take shared references (a prefix-cache hit); revives cached
        refcount-0 blocks out of the eviction LRU."""
        for bid in ids:
            if self._ref[bid] == 0:
                self._cached.pop(bid, None)
            self._ref[bid] += 1

    # -- snapshot -------------------------------------------------------------
    def export_registered(self) -> List[tuple]:
        """``(hash, id)`` pairs of every registered (content-hashed) block,
        coldest first — the persistent half of the allocator's state.

        Ordering is the restore-side LRU order: cached refcount-0 blocks in
        their eviction order (cold end first), then live blocks (hottest —
        they were in active use at snapshot time).  Unregistered live
        blocks (private pages of in-flight requests) are deliberately
        absent: after a restart there are no in-flight requests, and an
        unhashed page can never be revived by a prefix hit.
        """
        pairs = list((h, bid) for bid, h in self._cached.items())
        pairs.extend((h, bid) for h, bid in self._hash2id.items()
                     if self._ref[bid] > 0)
        return pairs

    def restore_registered(self, pairs: Sequence[tuple]) -> None:
        """Reset the allocator to a freshly-restarted warm state: every
        ``(hash, id)`` pair becomes a cached refcount-0 block (revivable by
        a prefix hit, evictable from the cold end), everything else is
        free.  Must be called before any allocation; raises ``ValueError``
        on ids out of range or duplicated (a corrupt snapshot must not
        half-apply)."""
        seen = set()
        for h, bid in pairs:
            if not 0 <= bid < self.n_phys:
                raise ValueError(
                    f"snapshot block id {bid} outside arena "
                    f"[0, {self.n_phys})")
            if bid in seen:
                raise ValueError(f"snapshot block id {bid} duplicated")
            seen.add(bid)
        self._ref = np.zeros(self.n_phys, np.int64)
        self._free = [i for i in range(self.n_phys - 1, -1, -1)
                      if i not in seen]
        self._cached = OrderedDict((bid, h) for h, bid in pairs)
        self._hash2id = {h: bid for h, bid in pairs}

    def decref(self, ids: Sequence[int]) -> None:
        """Drop references (slot release).  A block hitting refcount 0
        parks in the LRU if its content hash is registered (revivable),
        else returns to the free stack."""
        for bid in ids:
            if not self._ref[bid] > 0:
                raise RuntimeError(f"double free of block {bid}")
            self._ref[bid] -= 1
            if self._ref[bid] == 0:
                h = next((hh for hh, ii in self._hash2id.items()
                          if ii == bid), None)
                if h is None:
                    self._free.append(bid)
                else:
                    self._cached[bid] = h
                    self._cached.move_to_end(bid)

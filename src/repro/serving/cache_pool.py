"""Slot-pooled, jit-stable sparse-KV cache for continuous batching.

The legacy one-shot engine stored the compressed prefix at whatever
capacity the data produced, so every refreeze grew the cache shapes and
forced a fresh ``jax.jit`` trace of the decode step — fatal for a serving
engine.  The pool inverts that: **storage is sized once, data moves within
it**.

Per layer period, every slot owns

* a fixed grid of ``max_blocks`` compressed sequence blocks — bitmap words
  plus packed values at a *static* per-block capacity (``pack_blocks``
  drops overflow consistently from bitmap and values, so the bitmap always
  describes exactly what is stored);
* a dense ``tail`` ring of ``tail`` tokens for freshly decoded K/V.

Slot occupancy lives in three int32 ``[slots]`` vectors (``pos``,
``prefix_blocks``, ``tail_len``); validity is *masked*, never re-shaped.
Refreeze therefore folds a full tail into the next free prefix blocks **in
place**: compress the tail of every full slot at the pool's static
capacity, scatter the new blocks at each slot's own offset, bump the
lengths.  No shape changes, no retrace — the decode step compiles exactly
once per pool geometry, which is the property the paper's "cache frozen in
model state" design needs to survive heavy multi-tenant traffic.

Both dense and sparse KV live behind this one interface: a dense pool is
just ``k_sparsity = v_sparsity = 0`` (full per-block capacity), for which
compression is a bit-exact round trip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_format import _ceil_to, LANE
from repro.core.sparse_kv import append_tail_panel, freeze_chunk_blocks
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class CachePool:
    """Geometry + pure state-transition ops for the pooled serving cache.

    The dataclass itself is immutable config; all state lives in the pytree
    returned by :meth:`init_state` and flows through the pure methods below
    (each is jitted exactly once by the engine).
    """
    cfg: Any
    slots: int
    max_blocks: int          # compressed-prefix capacity, in (bs,)-blocks
    bs: int                  # tokens per compressed block
    tail: int                # dense-tail ring size (tokens)
    cap_k: int               # packed K values per block (static)
    cap_v: int

    @classmethod
    def build(cls, cfg, slots: int, max_tokens: int,
              bs: int = 0, capacity_slack: float = 1.25) -> "CachePool":
        """Size a pool for ``slots`` concurrent requests of up to
        ``max_tokens`` context each.

        Per-block value capacity is the nominal density times the block
        size, padded by ``capacity_slack`` and rounded to the lane size —
        headroom for the unevenness of the paper's layer-wide magnitude
        rule.  Zero sparsity always gets full capacity (exact round trip).
        """
        lm._attn_kinds(cfg)   # reject ssm/hybrid/encdec/frontend families
        bs = bs or min(128, cfg.kv_tail)
        assert cfg.kv_tail % bs == 0, (cfg.kv_tail, bs)
        l = bs * cfg.hd

        def cap(sparsity: float) -> int:
            density = 1.0 - sparsity
            if density >= 1.0:
                return l
            return min(_ceil_to(int(round(density * l * capacity_slack)),
                                LANE), l)
        max_blocks = max(-(-int(max_tokens) // bs), 1)
        return cls(cfg=cfg, slots=slots, max_blocks=max_blocks, bs=bs,
                   tail=cfg.kv_tail, cap_k=cap(cfg.kv_k_sparsity),
                   cap_v=cap(cfg.kv_v_sparsity))

    # -- geometry -----------------------------------------------------------
    @property
    def capacity_tokens(self) -> int:
        """Max context length a slot may be admitted for.

        Conservative: refreeze folds the whole live context into the prefix
        over time, so admission bounds by the prefix storage alone — the
        tail is working space, not extra capacity."""
        return self.max_blocks * self.bs

    def nbytes(self) -> int:
        """Total pooled storage, for capacity planning."""
        return sum(int(np.prod(l.shape)) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(
                       jax.eval_shape(self.init_state)))

    # -- state --------------------------------------------------------------
    def init_state(self) -> Dict[str, Any]:
        """Zeroed pool pytree.  Leaves under ``layers`` carry a leading
        period axis so the model's ``lax.scan`` slices them per layer."""
        cfg = self.cfg
        p = lm.period_len(cfg)
        n_periods = cfg.n_layers // p
        hkv, hd, dt = cfg.n_kv, cfg.hd, cfg.cdtype
        b, sb, w = self.slots, self.max_blocks, self.bs * hd // 32

        def kv_leaf():
            return {
                "k_bitmap": jnp.zeros((n_periods, b, hkv, sb, w), jnp.uint32),
                "k_values": jnp.zeros((n_periods, b, hkv, sb, self.cap_k),
                                      dt),
                "v_bitmap": jnp.zeros((n_periods, b, hkv, sb, w), jnp.uint32),
                "v_values": jnp.zeros((n_periods, b, hkv, sb, self.cap_v),
                                      dt),
                "k_tail": jnp.zeros((n_periods, b, hkv, self.tail, hd), dt),
                "v_tail": jnp.zeros((n_periods, b, hkv, self.tail, hd), dt),
            }
        return {
            "pos": jnp.zeros((b,), jnp.int32),
            "prefix_blocks": jnp.zeros((b,), jnp.int32),
            "tail_len": jnp.zeros((b,), jnp.int32),
            "layers": {f"l{j}": {"kv": kv_leaf()} for j in range(p)},
        }

    def state_axes(self) -> Dict[str, Any]:
        """Logical-axes pytree matching :meth:`init_state` leaf for leaf —
        the pool's own description of how its storage may shard
        (``distributed/serving_sharding`` turns it into NamedShardings).

        Slot occupancy vectors are ``[slots]`` -> the slot axis; every
        layer leaf is ``[P, slots, Hkv, ...]`` -> slots over the data
        axes, KV heads over the model axis, block/ring/packed dims
        unsharded (block storage is per-(slot, head) and refreeze's
        scatter is per-slot — no cross-shard writes ever happen).
        """
        p = lm.period_len(self.cfg)

        def kv_axes():
            row = (None, "slots", "kv_heads", None, None)
            return {k: row for k in ("k_bitmap", "k_values", "v_bitmap",
                                     "v_values", "k_tail", "v_tail")}
        return {
            "pos": ("slots",),
            "prefix_blocks": ("slots",),
            "tail_len": ("slots",),
            "layers": {f"l{j}": {"kv": kv_axes()} for j in range(p)},
        }

    # -- transitions (pure; the engine jits each exactly once) --------------
    def refreeze(self, state: Dict[str, Any]) -> Dict[str, Any]:
        """Fold every full tail into its slot's next free prefix blocks.

        In-place at static shapes: compress all slots' tails at the pool
        capacity, scatter each full slot's new blocks at its own
        ``prefix_blocks`` offset, select per slot.  Slots whose tail is not
        full come back bit-identical.  The caller must ensure no full slot
        overflows ``max_blocks`` (see ``Scheduler`` admission).
        """
        cfg = self.cfg
        t, tb = self.tail, self.tail // self.bs
        full = state["tail_len"] >= t                           # [B]
        pb = state["prefix_blocks"]
        new_layers = {}
        for name, leaf in state["layers"].items():
            kv = leaf["kv"]
            p_, b_, hkv, _, hd = kv["k_tail"].shape
            flat = lambda a: a.reshape(p_ * b_, hkv, t, hd)
            k_bm, k_vl, v_bm, v_vl = freeze_chunk_blocks(
                flat(kv["k_tail"]), flat(kv["v_tail"]),
                cfg.kv_k_sparsity, cfg.kv_v_sparsity,
                self.bs, self.cap_k, self.cap_v)
            unflat = lambda a: a.reshape((p_, b_) + a.shape[1:])

            def write(dst, upd):
                # per-slot offset scatter over the block axis
                out = jax.vmap(
                    lambda db, ub, off: jax.lax.dynamic_update_slice(
                        db, ub.astype(db.dtype), (0, 0, off, 0)),
                    in_axes=(1, 1, 0), out_axes=1)(dst, upd, pb)
                sel = full.reshape((1, b_) + (1,) * (dst.ndim - 2))
                return jnp.where(sel, out, dst)

            new_layers[name] = {"kv": {
                **kv,
                "k_bitmap": write(kv["k_bitmap"], unflat(k_bm)),
                "k_values": write(kv["k_values"], unflat(k_vl)),
                "v_bitmap": write(kv["v_bitmap"], unflat(v_bm)),
                "v_values": write(kv["v_values"], unflat(v_vl)),
            }}
        grow = jnp.where(full, tb, 0).astype(jnp.int32)
        return {**state, "layers": new_layers,
                "prefix_blocks": pb + grow,
                "tail_len": jnp.where(full, 0, state["tail_len"])}

    def append_many(self, state: Dict[str, Any],
                    panels: Dict[str, Any], n: jax.Array) -> Dict[str, Any]:
        """Append up to ``m`` fresh K/V tokens per slot into every layer's
        dense tail ring at the slot's own ``tail_len`` offset.

        ``panels``: ``{layer: {"k": [P, B, Hkv, m, D], "v": ...}}``;
        ``n`` int32 scalar or ``[B]`` — valid panel tokens per slot
        (``<= m``; 0 = passthrough).  Advances ``pos``/``tail_len`` by
        ``n``.  Pool-level twin of the verify step's in-layer append:
        the engine's verify forward writes each layer inside its scan
        (``models.attention.pooled_attn_panel``) through the SAME
        :func:`~repro.core.sparse_kv.append_tail_panel` core this method
        uses — change the write semantics there, not here.  This entry
        appends across all layers at once for direct pool callers and the
        rollback/refreeze property tests.  Pure masked writes at static
        shapes — jits once per panel width.
        """
        n = jnp.broadcast_to(jnp.asarray(n, jnp.int32), (self.slots,))
        tl = state["tail_len"]
        new_layers = {}
        for name, leaf in state["layers"].items():
            kv, src = leaf["kv"], panels[name]
            write = jax.vmap(append_tail_panel, in_axes=(0, 0, None, None))
            new_layers[name] = {"kv": {
                **kv,
                "k_tail": write(kv["k_tail"], src["k"], tl, n),
                "v_tail": write(kv["v_tail"], src["v"], tl, n),
            }}
        return {**state, "layers": new_layers,
                "pos": state["pos"] + n, "tail_len": tl + n}

    def rollback(self, state: Dict[str, Any], n: jax.Array
                 ) -> Dict[str, Any]:
        """Un-append the last ``n`` tokens per slot: a pure masked length
        decrement (``pos``/``tail_len``), no storage touched — validity is
        length-gated everywhere, so decremented entries are dead.

        ``n`` int32 scalar or ``[B]``, clamped to ``tail_len`` — a
        rollback can only surrender tail tokens; it never crosses the
        frozen-prefix boundary (refrozen tokens are committed by
        construction: the engine rolls back *within* the tick that
        appended, before any refreeze can fold the tail).  This is what
        makes draft–verify speculation free on this cache: rejected
        drafts cost one subtraction, not a retrace or a re-pack.
        """
        n = jnp.broadcast_to(jnp.asarray(n, jnp.int32), (self.slots,))
        n = jnp.clip(n, 0, state["tail_len"])
        return {**state, "pos": state["pos"] - n,
                "tail_len": state["tail_len"] - n}

    def release(self, state: Dict[str, Any], slot: jax.Array
                ) -> Dict[str, Any]:
        """Recycle a slot: zero its lengths.  Stale prefix/tail contents
        stay in storage but are fully masked (validity is length-gated
        everywhere), so the next admission simply overwrites them."""
        keep = jnp.arange(self.slots) != slot
        z = lambda a: jnp.where(keep, a, 0)
        return {**state, "pos": z(state["pos"]),
                "prefix_blocks": z(state["prefix_blocks"]),
                "tail_len": z(state["tail_len"])}

"""Speculative decoding for the continuous-batching engine (draft–verify).

Token generation on this stack is memory-bound: every decode tick streams
the full weight set plus each slot's cache to emit ONE token per slot.
Speculation amortizes that stream — a cheap *drafter* proposes up to ``K``
continuation tokens per slot, and a single **verify** forward scores all
``K+1`` positions at once (a query panel through the same fused
prefix+tail flash-decode kernel).  Accepted drafts commit as a window;
rejected ones are un-appended by a pure length rollback on the pooled
cache.  Per-lane acceptance keeps outputs honest: greedy lanes are
provably token-identical to the non-speculative engine, sampled lanes
keep their exact output distribution via rejection sampling
(:func:`repro.serving.sampling.accept_step`).

The drafter here is **model-free**: n-gram prompt lookup over each
request's own token history (prompt + generated).  No extra weights, no
extra memory traffic — it wins exactly where LLM serving is repetitive
(code, extraction, templated text, self-repeating generations) and
degrades to plain decoding (zero proposals, one committed token per tick)
everywhere else.  A learned drafter can slot in behind the same
:class:`Drafter` protocol without touching the engine.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np


@runtime_checkable
class Drafter(Protocol):
    """Anything that proposes draft continuations from a token history."""

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``history`` (may be empty —
        the engine pads short/absent proposals with invalid lanes)."""
        ...


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for ``ContinuousEngine``.

    k: max draft tokens verified per slot per tick (the verify panel is
      ``k + 1`` wide).  ``k == 0`` disables speculation outright.
    enabled: master switch — ``False`` preserves the non-speculative
      engine bit-for-bit (the verify step is never even built).
    max_ngram/min_ngram: suffix n-gram lengths the default prompt-lookup
      drafter tries, longest first.
    drafter: optional :class:`Drafter` override; ``None`` builds an
      :class:`NGramDrafter` from the n-gram bounds.
    adaptive: per-slot adaptive draft K — each slot's *recent acceptance
      rate* (EMA, decay ``adapt_decay``) scales its next draft window
      within ``[adapt_min_k, k]``.  Host-side data only: the verify panel
      stays ``[slots, k+1]`` wide whatever each slot proposes, so the
      compiled step (and the zero-retrace bar) is untouched.  Outputs are
      unchanged too — acceptance is per token, so proposing fewer drafts
      never changes *which* tokens commit, only how many ride one tick.
    adapt_decay: EMA decay of the per-slot acceptance-rate estimate
      (weight on the past; 0 = last tick only).
    adapt_min_k: floor of the adaptive window — a cold or unlucky slot
      keeps probing with at least this many drafts.
    """

    k: int = 4
    enabled: bool = True
    max_ngram: int = 3
    min_ngram: int = 1
    drafter: Optional[Drafter] = None
    adaptive: bool = False
    adapt_decay: float = 0.75
    adapt_min_k: int = 1

    def __post_init__(self):
        if self.k < 0:
            raise ValueError(f"k must be >= 0: {self.k}")
        if not 1 <= self.min_ngram <= self.max_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram: "
                f"{self.min_ngram}, {self.max_ngram}")
        if not 0.0 <= self.adapt_decay < 1.0:
            raise ValueError(
                f"adapt_decay must be in [0, 1): {self.adapt_decay}")
        if self.adaptive and self.k and not 1 <= self.adapt_min_k <= self.k:
            raise ValueError(
                f"need 1 <= adapt_min_k <= k: {self.adapt_min_k}, {self.k}")

    @property
    def active(self) -> bool:
        return self.enabled and self.k > 0

    def build_drafter(self) -> Drafter:
        if self.drafter is not None:
            return self.drafter
        return NGramDrafter(max_ngram=self.max_ngram,
                            min_ngram=self.min_ngram)


class AdaptiveDraft:
    """Per-slot adaptive draft-length controller (host-side).

    Keeps an EMA of each slot's draft acceptance rate and maps it onto a
    draft window in ``[min_k, k]``: a slot whose history keeps verifying
    speculates at full depth, one whose drafts keep getting rejected backs
    off to the floor (rejected drafts are cheap — a rollback — but they
    widen the verify panel's *useful* fraction, so proposing fewer on cold
    streams keeps accept-rate statistics honest in the spec histogram).
    Ticks where a slot proposed nothing (no n-gram hit / no tail headroom)
    carry no acceptance evidence and leave the estimate untouched.

    Pure ints/floats per slot; the engine resets a slot's estimate when
    its request finishes so the next tenant starts fresh (optimistic at
    full ``k`` — the first tick probes).
    """

    def __init__(self, spec: "SpecConfig"):
        self.k = spec.k
        self.min_k = min(spec.adapt_min_k, spec.k) if spec.k else 0
        self.decay = spec.adapt_decay
        self._rate: dict = {}                 # slot -> EMA acceptance rate
        self.hist = np.zeros(spec.k + 1, np.int64)

    def draft_len(self, slot: int) -> int:
        """The slot's current draft window: ``min_k + rate * (k - min_k)``
        rounded; optimistic full-``k`` until the first evidence arrives."""
        rate = self._rate.get(slot)
        if rate is None:
            return self.k
        return self.min_k + int(round(rate * (self.k - self.min_k)))

    def update(self, slot: int, proposed: int, accepted: int) -> None:
        """Fold one verify tick's outcome into the slot's estimate."""
        self.hist[max(0, min(proposed, self.k))] += 1
        if proposed <= 0:
            return                            # no evidence this tick
        rate = min(max(accepted / proposed, 0.0), 1.0)
        prev = self._rate.get(slot)
        self._rate[slot] = rate if prev is None else \
            self.decay * prev + (1.0 - self.decay) * rate

    def reset(self, slot: int) -> None:
        self._rate.pop(slot, None)


class NGramDrafter:
    """Prompt-lookup drafter: continue the most recent earlier occurrence
    of the history's longest matching suffix n-gram.

    Tries suffix lengths ``max_ngram`` down to ``min_ngram``; for the
    first length whose suffix recurs earlier in the history, proposes the
    ``k`` tokens that followed the most recent match.  Pure host-side
    Python over ints — O(len(history)) per proposal, no device work, no
    model state.  Returns ``[]`` when nothing matches (the slot simply
    decodes non-speculatively that tick).
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        assert 1 <= min_ngram <= max_ngram, (min_ngram, max_ngram)
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, history: Sequence[int], k: int) -> List[int]:
        hist = list(history)
        if k <= 0 or len(hist) < self.min_ngram + 1:
            return []
        for n in range(min(self.max_ngram, len(hist) - 1),
                       self.min_ngram - 1, -1):
            suffix = hist[-n:]
            # most recent occurrence strictly before the suffix itself
            for start in range(len(hist) - n - 1, -1, -1):
                if hist[start:start + n] == suffix:
                    cont = hist[start + n:start + n + k]
                    if cont:
                        return [int(t) for t in cont]
        return []

"""Pallas TPU kernels for the paper's compute hot-spots.

SparAMX's contribution IS a set of kernels; the TPU ports live here:

  dense_matmul       — §4.1 dense AMX kernel  -> MXU macro-tiled GEMM
  sparse_matmul      — §4.3 sparse AMX kernel -> decompress-in-VMEM GEMM
  sparse_gemv        — §4.4 AVX kernel        -> VPU vector path (batch<=8)
  sparse_matmul_int8 — §4.5 INT8 kernels      -> int8 MXU + scales
  sparse_attention   — §6   sparse-KV kernel  -> flash-decode over the
                                                 compressed frozen prefix

``ops`` holds the jit'd dispatch wrappers (+ backend switch), ``ref`` the
pure-jnp oracles every kernel is validated against in interpret mode.
"""
from . import ops, ref
from .ops import (linear, dense_matmul, sparse_matmul, sparse_matmul_int8,
                  sparse_decode_attention, set_backend, get_backend, backend)

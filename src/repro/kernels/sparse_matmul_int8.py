"""INT8 sparse GEMM Pallas kernel — paper §4.5 INT8 kernels on the MXU.

Same decompress-then-dense-dot structure as :mod:`sparse_matmul`, with:
  * int8 packed values (each block holds 2x the weights of a bf16 block per
    byte, mirroring the paper's 16x64 int8 AMX tiles vs 16x32 bf16),
  * int32 MXU accumulation,
  * per-row dynamic activation scale + per-output-channel weight scale
    applied at the epilogue.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.sparse_format import BlockSparseWeight
from .common import CompilerParams, decompress_block


def _kernel(x_ref, sx_ref, bm_ref, val_ref, sw_ref, o_ref, acc_ref, *, bk, bn):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = decompress_block(bm_ref[0, 0], val_ref[0, 0], bk, bn,
                              dtype=jnp.int8)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.int8), w_tile,
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        scaled = (acc_ref[...].astype(jnp.float32)
                  * sx_ref[...]                      # (tm, 1) per-row act scale
                  * sw_ref[0][None, :])              # (bn,) per-channel w scale
        o_ref[...] = scaled.astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("tm", "out_dtype", "interpret"))
def sparse_matmul_int8_pallas(xq: jax.Array, sx: jax.Array,
                              sw: BlockSparseWeight,
                              tm: int = 128, out_dtype=jnp.float32,
                              interpret: bool = True) -> jax.Array:
    """``dequant(xq, sx) @ dequant(sw)``; xq int8 [M, K], sx f32 [M]."""
    if not (sw.values.dtype == jnp.int8 and sw.scale is not None):
        raise ValueError("int8 path needs int8 values and a scale")
    bk, bn = sw.block
    kb, nb, words = sw.bitmap.shape
    cap = sw.capacity
    m, k = xq.shape
    kp, mp = kb * bk, -(-m // tm) * tm
    xq = jnp.pad(xq, ((0, mp - m), (0, kp - k)))
    sx2 = jnp.pad(sx.astype(jnp.float32), (0, mp - m))[:, None]
    w_scale = sw.scale.reshape(nb, bn)

    out = pl.pallas_call(
        partial(_kernel, bk=bk, bn=bn),
        grid=(mp // tm, nb, kb),
        in_specs=[
            pl.BlockSpec((tm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, 1, words), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, cap), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, nb * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sparse_matmul_int8",
    )(xq, sx2, sw.bitmap, sw.values, w_scale)
    return out[:m, : sw.shape[1]]

"""Sparse GEMV Pallas kernel — the paper's §4.4 AVX (vector) kernel on TPU.

At decode batch 1, a 128-row MXU macro-tile wastes 127/128 of its input rows
— the same observation that motivates the paper's AVX kernel (their 16-row
AMX input tile is 15/16 wasted).  This kernel is the VPU-path analogue:

* the input stays as a single ``(tm<=8, bk)`` sliver (8 sublanes = the f32
  native tile, the VPU's natural granule),
* the grid iterates output-block-major ``(Nb, Kb)`` so each output sliver is
  produced by a running vector FMA against decompressed weight rows rather
  than an MXU macro-tile pass,
* decompression is identical to the matmul kernel (bitmap -> prefix-sum ->
  gather), matching the paper's shared format between its AVX and AMX paths.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.sparse_format import BlockSparseWeight
from .common import CompilerParams, decompress_block


def _kernel(x_ref, bm_ref, val_ref, o_ref, acc_ref, *, bk, bn):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = decompress_block(bm_ref[0, 0], val_ref[0, 0], bk, bn,
                              dtype=jnp.float32)
    # vector path: broadcast-multiply-accumulate (VPU), not an MXU pass
    x = x_ref[...].astype(jnp.float32)                # (tm, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, w_tile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def sparse_gemv_pallas(x: jax.Array, sw: BlockSparseWeight,
                       out_dtype=None, interpret: bool = True) -> jax.Array:
    """``x [M<=8, K] @ unpack(sw)`` — batch-1..8 decode path."""
    bk, bn = sw.block
    kb, nb, words = sw.bitmap.shape
    cap = sw.capacity
    m, k = x.shape
    tm = 8
    if m > tm:
        raise ValueError(f"gemv path is for m<={tm}, got {m}")
    kp = kb * bk
    x = jnp.pad(x, ((0, tm - m), (0, kp - k)))
    out_dtype = out_dtype or x.dtype

    out = pl.pallas_call(
        partial(_kernel, bk=bk, bn=bn),
        grid=(nb, kb),
        in_specs=[
            pl.BlockSpec((tm, bk), lambda j, kk: (0, kk)),
            pl.BlockSpec((1, 1, words), lambda j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, cap), lambda j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, bn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((tm, nb * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="sparse_gemv",
    )(x, sw.bitmap, sw.values)
    return out[:m, : sw.shape[1]]

"""INT4 sparse GEMM Pallas kernel — the paper's §8 extension, implemented as
prescribed: "dequantizing INT4 values into INT8 before computation".

Identical structure to :mod:`sparse_matmul_int8`, with one extra VMEM stage:
the packed nibble stream (two weights/byte — HBM traffic halves again vs
int8) is expanded to int8 in registers *before* the bitmap decompression,
then the int8 MXU path runs unchanged.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.sparse_format import BlockSparseWeight
from .common import CompilerParams, decompress_block


def _unpack_nibbles(b):
    lo = (b & jnp.uint8(0xF)).astype(jnp.int8)
    hi = (b >> jnp.uint8(4)).astype(jnp.int8)
    sext = lambda x: ((x ^ jnp.int8(8)) - jnp.int8(8)).astype(jnp.int8)
    out = jnp.stack([sext(lo), sext(hi)], axis=-1)
    return out.reshape(*b.shape[:-1], b.shape[-1] * 2)


def _kernel(x_ref, sx_ref, bm_ref, val_ref, sw_ref, o_ref, acc_ref, *,
            bk, bn):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vals_i8 = _unpack_nibbles(val_ref[0, 0])          # int4 -> int8 in VMEM
    w_tile = decompress_block(bm_ref[0, 0], vals_i8, bk, bn, dtype=jnp.int8)
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.int8), w_tile,
                            preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        scaled = (acc_ref[...].astype(jnp.float32)
                  * sx_ref[...] * sw_ref[0][None, :])
        o_ref[...] = scaled.astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("tm", "out_dtype", "interpret"))
def sparse_matmul_int4_pallas(xq: jax.Array, sx: jax.Array,
                              sw: BlockSparseWeight,
                              tm: int = 128, out_dtype=jnp.float32,
                              interpret: bool = True) -> jax.Array:
    """``dequant(xq, sx) @ dequant4(sw)``; xq int8 [M, K], sx f32 [M]."""
    if not (sw.packed4 and sw.scale is not None):
        raise ValueError("int4 path needs nibble-packed values and a scale")
    bk, bn = sw.block
    kb, nb, words = sw.bitmap.shape
    cap_packed = sw.values.shape[-1]
    m, k = xq.shape
    kp, mp = kb * bk, -(-m // tm) * tm
    xq = jnp.pad(xq, ((0, mp - m), (0, kp - k)))
    sx2 = jnp.pad(sx.astype(jnp.float32), (0, mp - m))[:, None]
    w_scale = sw.scale.reshape(nb, bn)

    out = pl.pallas_call(
        partial(_kernel, bk=bk, bn=bn),
        grid=(mp // tm, nb, kb),
        in_specs=[
            pl.BlockSpec((tm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, 1, words), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, cap_packed), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, nb * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sparse_matmul_int4",
    )(xq, sx2, sw.bitmap, sw.values, w_scale)
    return out[:m, : sw.shape[1]]

"""Dense GEMM Pallas kernel — the paper's §4.1 dense AMX kernel on the MXU.

The paper tiles 2x2 output macro-tiles across 4 AMX accumulator tiles to get
a 1:1 compute:load ratio.  The MXU analogue: each grid cell owns a
``(tm, bn)`` output macro-block accumulated in an f32 VMEM scratch across the
``K`` loop, with (tm, bk) input and (bk, bn) weight blocks streamed through
VMEM — the same "keep accumulators resident, stream operands" structure,
sized for 128x128 systolic tiles instead of 16x32 AMX tiles.

Grid: ``(M/tm, N/bn, K/bk)``; the K dimension is innermost/sequential
("arbitrary"), M and N are parallel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .common import CompilerParams


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pet = jnp.int32 if x_ref.dtype == jnp.int8 else jnp.float32
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=pet)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("block", "out_dtype", "interpret"))
def dense_matmul_pallas(x: jax.Array, w: jax.Array,
                        block=(128, 256, 128), out_dtype=None,
                        interpret: bool = True) -> jax.Array:
    """``x [M, K] @ w [K, N]`` with padding to block multiples."""
    tm, bk, bn = block
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims disagree: x has K={k}, w has K={k2}")
    mp, kp, np_ = -(-m // tm) * tm, -(-k // bk) * bk, -(-n // bn) * bn
    x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out_dtype = out_dtype or (jnp.int32 if x.dtype == jnp.int8 else x.dtype)
    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32

    out = pl.pallas_call(
        _kernel,
        grid=(mp // tm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((tm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, bn), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="dense_matmul",
    )(x, w)
    return out[:m, :n]

"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *semantics* each kernel must match (asserted to by the
per-kernel shape/dtype sweep tests), and double as the XLA fallback used on
non-TPU backends and in the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_format import BlockSparseWeight, unpack
from repro.core.quant import quantize_act_int8


def dense_matmul_ref(x: jax.Array, w: jax.Array,
                     out_dtype=None) -> jax.Array:
    """``x [M, K] @ w [K, N]`` with f32 accumulation (paper §4.1 baseline)."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or x.dtype)


def sparse_matmul_ref(x: jax.Array, sw: BlockSparseWeight,
                      out_dtype=None) -> jax.Array:
    """Load-as-sparse, compute-as-dense (paper §4.3): decompress then GEMM.

    Works on shard_map-sliced weights too (the aux logical shape may exceed
    the local padded arrays; trim only when padding is real)."""
    w = unpack(sw, trim=False)
    kp = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (0, max(kp - x.shape[1], 0))))[:, :kp]
    out = jnp.dot(xp, w, preferred_element_type=jnp.float32)
    n = min(sw.shape[1], w.shape[1])
    return out[:, :n].astype(out_dtype or x.dtype)


def sparse_gemv_ref(x: jax.Array, sw: BlockSparseWeight,
                    out_dtype=None) -> jax.Array:
    """Semantics identical to sparse_matmul; kept separate as the oracle for
    the vector-path kernel (paper §4.4 AVX kernel)."""
    return sparse_matmul_ref(x, sw, out_dtype)


def sparse_matmul_int8_ref(x: jax.Array, sw: BlockSparseWeight,
                           out_dtype=jnp.float32) -> jax.Array:
    """INT8/INT4 path (paper §4.5/§8): dynamic per-row activation quant,
    int32 accumulation, per-channel rescale.  ``sw.values`` is int8 (or
    nibble-packed int4 — ``unpack`` dequantizes to int8 first, exactly the
    paper's prescription)."""
    if not ((sw.values.dtype == jnp.int8 or sw.packed4)
            and sw.scale is not None):
        raise ValueError("int path needs int8/int4 values and a scale")
    xq, sx = quantize_act_int8(x)
    w = unpack(sw, trim=False)                       # int8, padded
    kp = w.shape[0]
    xq = jnp.pad(xq, ((0, 0), (0, max(kp - xq.shape[1], 0))))[:, :kp]
    acc = jnp.dot(xq.astype(jnp.int32), w.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * sx[:, None] \
        * sw.scale[None, : w.shape[1]]
    n = min(sw.shape[1], w.shape[1])
    return out[:, :n].astype(out_dtype)


def gather_paged_prefix(table: jax.Array, bitmap: jax.Array,
                        values: jax.Array, bs: int, d: int
                        ) -> BlockSparseWeight:
    """Paged arena + per-slot block table -> the flat pooled-prefix view.

    ``bitmap [n_phys, Hkv, w]`` / ``values [n_phys, Hkv, C]`` hold every
    compressed block ONCE; ``table [B, Sb]`` int32 maps each slot's
    logical block ``i`` to its physical id.  The gather materializes each
    slot's logical prefix (``[B, Hkv, Sb, X]``) and wraps it in the
    structured :class:`BlockSparseWeight` view the flat reference
    semantics consume — this IS the oracle for the paged kernel's index
    indirection: paged attention == gather-then-flat-attention.  Table
    entries past a slot's valid count select arbitrary (live or dead)
    blocks; callers mask them with ``prefix_len`` exactly as on the flat
    path.
    """
    bm = jnp.take(bitmap, table, axis=0).transpose(0, 2, 1, 3)
    vl = jnp.take(values, table, axis=0).transpose(0, 2, 1, 3)
    sb = table.shape[1]
    return BlockSparseWeight(
        bitmap=bm[:, :, :, None, :], values=vl[:, :, :, None, :],
        scale=None, shape=(sb * bs, d), block=(bs, d))


def _merge_attn(o1, lse1, o2, lse2):
    """Combine two attention partials via their log-sum-exps."""
    m = jnp.maximum(lse1, lse2)
    w1 = jnp.exp(lse1 - m)[..., None]
    w2 = jnp.exp(lse2 - m)[..., None]
    den = w1 + w2
    return (o1 * w1 + o2 * w2) / den, m + jnp.log(den[..., 0])


def gqa_partial_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    sm_scale: float,
                    valid: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Grouped-GQA single-query partial — NO repeat_kv materialization.

    q: [B, Hkv, G, D]; k, v: [B, Hkv, S, D] (bf16 ok — contraction
    accumulates in f32 via preferred_element_type, no f32 copies of the
    cache).  Returns (out [B,Hkv,G,D] f32, lse [B,Hkv,G]).

    This is §Perf iteration 3: the paper flags PyTorch's ``repeat_kv`` as a
    decode bottleneck; the XLA analogue (jnp.repeat + .astype(f32)) was
    ~20x the ideal cache bytes.
    """
    s = jnp.einsum("bhgd,bhsd->bhgs", q, k,
                   preferred_element_type=jnp.float32) * sm_scale
    if valid is not None:
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    if valid is not None:
        p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    l_safe = jnp.maximum(l, 1e-30)
    return o / l_safe[..., None], m_safe + jnp.log(l_safe)


def attn_partial_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     sm_scale: float,
                     valid: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Single-query attention partial: returns (out, lse).

    q: [B, H, D]; k, v: [B, H, S, D] (H = kv heads already matched to q heads);
    valid: optional [B, S] bool mask of real (non-pad) positions.
    """
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if valid is not None:
        s = jnp.where(valid[:, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    # all-masked rows: avoid nan
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    if valid is not None:
        p = jnp.where(valid[:, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32))
    l_safe = jnp.maximum(l, 1e-30)
    return o / l_safe[..., None], m_safe + jnp.log(l_safe)


def _len_valid(n: int, length, b: int) -> jax.Array:
    """[B, n] validity mask from a scalar or per-slot [B] length."""
    length = jnp.asarray(length)
    if length.ndim == 1:
        length = length[:, None]
    return jnp.broadcast_to(jnp.arange(n)[None, :] < length, (b, n))


def _unpack_prefix(q, k_sp, v_sp, hkv):
    """Decompress the frozen prefix to dense [B, Hkv, S, D] (both the
    structured [B, Hkv, Sb, 1, ...] and the flat [(B*Hkv*Sb), 1, ...]
    block layouts)."""
    b, hq, d = q.shape
    if k_sp.bitmap.ndim == 5:       # structured [B, Hkv, Sb, 1, ...]
        return unpack(k_sp), unpack(v_sp)
    kd = unpack(k_sp)                                 # [(B Hkv S), D]
    vd = unpack(v_sp)
    s_len = kd.shape[0] // (b * hkv)
    return (kd.reshape(b, hkv, s_len, d),
            vd.reshape(b, hkv, s_len, d))


def sparse_decode_attention_fused_ref(
        q: jax.Array,
        k_sp: BlockSparseWeight, v_sp: BlockSparseWeight,
        sm_scale: float,
        k_tail: jax.Array, v_tail: jax.Array,
        tail_len: Optional[jax.Array] = None,
        prefix_len: Optional[jax.Array] = None) -> jax.Array:
    """Oracle for the FUSED prefix+tail flash-decode kernel.

    Fused semantics: ONE softmax over the union of valid prefix and tail
    positions — no partials, no lse merge, no special-casing of empty
    prefixes (an all-invalid prefix simply contributes nothing).  Grouped
    GQA throughout: the tail is consumed at [B, Hkv, T, D], never
    materialized to Hq heads.

    q [B, Hq, D]; k_sp/v_sp the compressed frozen prefix (structured or
    flat layout); k_tail/v_tail [B, Hkv, T, D].  ``tail_len`` /
    ``prefix_len`` may be scalar or per-slot [B] int32; slots where both
    are empty return zeros.

    Concat-free: prefix and tail are scored by two grouped einsums (bf16
    cache operands stay bf16 — no f32 copies, no [S+T] concatenation)
    that share ONE softmax normalizer — the fused kernel's online softmax
    unrolled to two panels, each panel exponentiated against its own
    local max (the flash recurrence's rescaling trick, which also keeps
    the bf16-cast ``p`` numerics identical to the two-pass partials').
    """
    b, hq, d = q.shape
    hkv = k_tail.shape[1]
    k, v = _unpack_prefix(q, k_sp, v_sp, hkv)
    s_len, t = k.shape[2], k_tail.shape[2]
    valid_p = _len_valid(
        s_len, prefix_len if prefix_len is not None else s_len, b)
    valid_t = _len_valid(t, tail_len if tail_len is not None else t, b)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)

    def panel(kx, vx, valid):
        """Unnormalized panel statistics (o, l, m) at the panel's own
        max — empty panels return (0, 0, -inf)."""
        s = jnp.einsum("bhgd,bhsd->bhgs", qg, kx,
                       preferred_element_type=jnp.float32) * sm_scale
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1)                          # [B,Hkv,G]
        p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0)[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        o = jnp.einsum("bhgs,bhsd->bhgd", p.astype(vx.dtype), vx,
                       preferred_element_type=jnp.float32)
        return o, jnp.sum(p, axis=-1), m

    o1, l1, m1 = panel(k, v, valid_p)
    o2, l2, m2 = panel(k_tail, v_tail, valid_t)
    m = jnp.maximum(m1, m2)                              # joint max
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    # empty panels have m == -inf, so their weight is exactly exp(-inf)=0
    w1 = jnp.exp(m1 - m_safe)
    w2 = jnp.exp(m2 - m_safe)
    l_safe = jnp.maximum(l1 * w1 + l2 * w2, 1e-30)
    o = (o1 * w1[..., None] + o2 * w2[..., None]) / l_safe[..., None]
    return o.reshape(b, hq, d).astype(q.dtype)


def sparse_decode_attention_panel_ref(
        q: jax.Array,
        k_sp: BlockSparseWeight, v_sp: BlockSparseWeight,
        sm_scale: float,
        k_tail: jax.Array, v_tail: jax.Array,
        tail_len: Optional[jax.Array] = None,
        prefix_len: Optional[jax.Array] = None) -> jax.Array:
    """Query-panel oracle for the fused kernel's speculative verify step.

    Same concat-free two-panel softmax as
    :func:`sparse_decode_attention_fused_ref`, generalized from one query
    to a ``[B, Q, Hq, D]`` panel: every panel query sees the full valid
    prefix, while tail visibility is *intra-window causal* — panel query
    ``j`` sees ``tail_len + j`` tail tokens (``tail_len`` counts the
    tokens visible to query 0, its own appended K/V included; each later
    query additionally sees the K/V its panel predecessors appended).
    ``Q == 1`` reduces exactly to the fused single-query semantics.

    Returns out [B, Q, Hq, D]; slots with nothing valid return zeros.
    """
    b, qn, hq, d = q.shape
    hkv = k_tail.shape[1]
    k, v = _unpack_prefix(q[:, 0], k_sp, v_sp, hkv)
    s_len, t = k.shape[2], k_tail.shape[2]
    valid_p = _len_valid(
        s_len, prefix_len if prefix_len is not None else s_len, b)
    tl = jnp.asarray(tail_len if tail_len is not None else t)
    if tl.ndim == 0:
        tl = jnp.broadcast_to(tl, (b,))
    # [B, Q, T]: query j sees tail tokens < tl + j
    valid_t = (jnp.arange(t)[None, None, :]
               < tl[:, None, None] + jnp.arange(qn)[None, :, None])
    g = hq // hkv
    qg = q.reshape(b, qn, hkv, g, d).transpose(0, 2, 1, 3, 4)

    def panel(kx, vx, valid):
        """valid [B, Qv, S] with Qv in {1, Q} (broadcast over heads)."""
        s = jnp.einsum("bhqgd,bhsd->bhqgs", qg, kx,
                       preferred_element_type=jnp.float32) * sm_scale
        vm = valid[:, None, :, None, :]
        s = jnp.where(vm, s, -jnp.inf)
        m = jnp.max(s, axis=-1)                          # [B,Hkv,Q,G]
        p = jnp.exp(s - jnp.where(jnp.isfinite(m), m, 0.0)[..., None])
        p = jnp.where(vm, p, 0.0)
        o = jnp.einsum("bhqgs,bhsd->bhqgd", p.astype(vx.dtype), vx,
                       preferred_element_type=jnp.float32)
        return o, jnp.sum(p, axis=-1), m

    o1, l1, m1 = panel(k, v, valid_p[:, None, :])
    o2, l2, m2 = panel(k_tail, v_tail, valid_t)
    m = jnp.maximum(m1, m2)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    w1 = jnp.exp(m1 - m_safe)
    w2 = jnp.exp(m2 - m_safe)
    l_safe = jnp.maximum(l1 * w1 + l2 * w2, 1e-30)
    o = (o1 * w1[..., None] + o2 * w2[..., None]) / l_safe[..., None]
    return (o.transpose(0, 2, 1, 3, 4)
            .reshape(b, qn, hq, d).astype(q.dtype))


def sparse_decode_attention_ref(
        q: jax.Array,
        k_sp: BlockSparseWeight, v_sp: BlockSparseWeight,
        sm_scale: float,
        k_tail: Optional[jax.Array] = None,
        v_tail: Optional[jax.Array] = None,
        tail_len: Optional[jax.Array] = None,
        prefix_len: Optional[jax.Array] = None) -> jax.Array:
    """Two-pass (partial + lse merge) oracle for the sparse-KV flash-decode
    kernel (paper §6.2).

    Mathematically identical to :func:`sparse_decode_attention_fused_ref`;
    kept as the partial+merge reference because the context-parallel path
    (``repro.distributed.cp_attention``) is pinned to these semantics —
    per-shard partials must cross chips before the merge.

    q: [B, Hq, D].  k_sp/v_sp hold the *compressed frozen prefix*: their
    logical shape is [(B*Hkv*S), D] blocked row-major, i.e. they were packed
    from the [B*Hkv*S, D] view of the cache.  k_tail/v_tail: dense dynamic
    tail [B, Hkv, T, D] with `tail_len` valid positions.

    ``tail_len`` and ``prefix_len`` may be scalars (uniform batch — the
    legacy one-shot engine) or per-slot int32 ``[B]`` (the pooled
    continuous-batching cache, where every slot has its own lengths).
    ``prefix_len`` masks prefix positions ``>= prefix_len[b]`` — slots whose
    compressed prefix only partially fills the pool's fixed-capacity storage.
    """
    b, hq, d = q.shape
    if k_tail is not None:
        hkv = k_tail.shape[1]
    elif k_sp.bitmap.ndim == 5:     # structured layout carries Hkv
        hkv = k_sp.bitmap.shape[1]
    else:
        hkv = hq
    k, v = _unpack_prefix(q, k_sp, v_sp, hkv)
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    valid_p = None
    if prefix_len is not None:
        valid_p = _len_valid(k.shape[2], prefix_len, b)
    o, lse = gqa_partial_ref(qg, k, v, sm_scale, valid_p)
    if valid_p is not None:
        # an empty prefix must not win the lse merge against a real tail
        empty_p = ~jnp.any(valid_p, axis=-1)
        lse = jnp.where(empty_p[:, None, None], -1e30, lse)
    if k_tail is not None and k_tail.shape[2] > 0:
        t = k_tail.shape[2]
        valid = _len_valid(t, tail_len if tail_len is not None else t, b)
        o2, lse2 = gqa_partial_ref(qg, k_tail, v_tail, sm_scale, valid)
        # a fully-empty tail contributes nothing
        empty = ~jnp.any(valid, axis=-1)
        lse2 = jnp.where(empty[:, None, None], -jnp.inf, lse2)
        lse2_safe = jnp.where(jnp.isfinite(lse2), lse2, lse.min() - 60.0)
        o, _ = _merge_attn(o, lse, o2, lse2_safe)
    return o.reshape(b, hq, d).astype(q.dtype)

"""Jit'd dispatch wrappers over the Pallas kernels and their XLA fallbacks.

Backend policy (``set_backend`` / ``backend()`` context):
  * ``"tpu"``        — real Pallas lowering (requires TPU devices).
  * ``"interpret"``  — Pallas interpret mode: the kernel bodies execute in
                        Python on CPU; used to *validate* the kernels here.
  * ``"xla"``        — pure-jnp reference semantics (fast on CPU; used by the
                        multi-pod dry-run, where roofline terms are then
                        kernel-adjusted — see benchmarks/roofline.py).

All entry points accept arbitrary leading batch dims on ``x``.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_format import BlockSparseWeight
from repro.core.quant import quantize_act_int8
from . import ref
from .dense_matmul import dense_matmul_pallas
from .sparse_matmul import sparse_matmul_pallas
from .sparse_matmul_int8 import sparse_matmul_int8_pallas
from .sparse_gemv import sparse_gemv_pallas
from .sparse_attention import (sparse_decode_attention_pallas,
                               sparse_decode_attention_fused_pallas)

_BACKEND = "tpu" if jax.default_backend() == "tpu" else "xla"


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("tpu", "interpret", "xla"):
        raise ValueError(f"unknown backend {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


@contextlib.contextmanager
def backend(name: str):
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def _pallas() -> Optional[bool]:
    """None -> use XLA ref; True -> interpret pallas; False -> real pallas."""
    if _BACKEND == "xla":
        return None
    return _BACKEND == "interpret"


def _flatten_leading(x):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


# ---------------------------------------------------------------------------
# matmuls
# ---------------------------------------------------------------------------

def dense_matmul(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    x2, lead = _flatten_leading(x)
    interp = _pallas()
    if interp is None:
        out = ref.dense_matmul_ref(x2, w, out_dtype)
    else:
        out = dense_matmul_pallas(x2, w, out_dtype=out_dtype, interpret=interp)
    return out.reshape(*lead, w.shape[-1])


def sparse_matmul(x: jax.Array, sw: BlockSparseWeight,
                  out_dtype=None) -> jax.Array:
    x2, lead = _flatten_leading(x)
    interp = _pallas()
    if interp is None:
        out = ref.sparse_matmul_ref(x2, sw, out_dtype)
    elif x2.shape[0] <= 8:
        out = sparse_gemv_pallas(x2, sw, out_dtype=out_dtype, interpret=interp)
    else:
        out = sparse_matmul_pallas(x2, sw, out_dtype=out_dtype,
                                   interpret=interp)
    return out.reshape(*lead, out.shape[-1])


def sparse_matmul_int8(x: jax.Array, sw: BlockSparseWeight,
                       out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    x2, lead = _flatten_leading(x)
    interp = _pallas()
    if interp is None:
        out = ref.sparse_matmul_int8_ref(x2, sw, out_dtype)
    else:
        xq, sx = quantize_act_int8(x2)
        if sw.packed4:
            from .sparse_matmul_int4 import sparse_matmul_int4_pallas
            out = sparse_matmul_int4_pallas(xq, sx, sw, out_dtype=out_dtype,
                                            interpret=interp)
        else:
            out = sparse_matmul_int8_pallas(xq, sx, sw, out_dtype=out_dtype,
                                            interpret=interp)
    return out.reshape(*lead, out.shape[-1])


def linear(x: jax.Array, w, out_dtype=None) -> jax.Array:
    """Apply a linear layer whose weight is dense, sparse-bf16, or sparse-int8.

    This is the run-time face of the paper's "automatically replace all
    linear layers" feature: callers never branch on the storage format.
    """
    if isinstance(w, BlockSparseWeight):
        if w.packed4 or w.values.dtype == jnp.int8:
            return sparse_matmul_int8(x, w, out_dtype)
        return sparse_matmul(x, w, out_dtype)
    return dense_matmul(x, w, out_dtype)


# ---------------------------------------------------------------------------
# sparse-KV decode attention
# ---------------------------------------------------------------------------

def sparse_decode_attention(q: jax.Array,
                            k_sp: BlockSparseWeight,
                            v_sp: BlockSparseWeight,
                            hkv: int,
                            sm_scale: float,
                            k_tail: Optional[jax.Array] = None,
                            v_tail: Optional[jax.Array] = None,
                            tail_len: Optional[jax.Array] = None,
                            prefix_len: Optional[jax.Array] = None
                            ) -> jax.Array:
    """Decode attention over a compressed frozen prefix + dense tail.

    q: ``[B, Hq, D]`` (one decode tick) or ``[B, Q, Hq, D]`` (a *query
    panel* — the unified serving forward; ``Q > 1`` requires a tail).  A
    ``Q == 1`` panel is squeezed onto the single-query dispatch — decode
    through the panel forward is bit-identical to the 3-D entry.  k_sp/
    v_sp packed from the [B*Hkv*S, D] cache view with block (bs, D);
    k_tail/v_tail: [B, Hkv, T, D].

    ``tail_len``/``prefix_len`` may be scalar (uniform batch) or per-slot
    ``[B]`` int32 (pooled continuous-batching cache).  ``prefix_len`` must
    be a whole number of (bs,)-token blocks; on the Pallas path it becomes a
    per-slot valid-block count the kernel skips past.  For a query panel,
    ``tail_len`` counts the tail tokens visible to panel query 0 (its own
    appended K/V included) and query ``j`` sees ``tail_len + j`` — the
    intra-window causal mask of the draft–verify step.

    When a tail is passed, ONE fused ``pallas_call`` (or, on the XLA
    backend, one grouped-GQA softmax over the concatenated sequence)
    produces the final output: there is no XLA-side tail attention, no lse
    merge, and no ``jnp.repeat`` head materialization on the per-token hot
    path — the K+1-query verify panel rides the exact same kernel with a
    wider query block.  The two-pass partial+merge semantics survive only
    in ``repro.distributed.cp_attention``, where per-shard partials must
    cross chips before the merge.
    """
    interp = _pallas()
    has_tail = k_tail is not None and k_tail.shape[2] > 0
    if q.ndim == 4 and q.shape[1] == 1:
        # a 1-wide panel IS a decode tick: squeeze onto the single-query
        # dispatch so the unified panel forward at Q==1 stays bit-identical
        # to the pre-unification decode path on every backend.
        o = sparse_decode_attention(q[:, 0], k_sp, v_sp, hkv, sm_scale,
                                    k_tail, v_tail, tail_len, prefix_len)
        return o[:, None]
    panel = q.ndim == 4
    if panel:
        if not has_tail:
            raise ValueError("query panels append into (and need) a dense tail")
    if interp is None:
        if panel:
            return ref.sparse_decode_attention_panel_ref(
                q, k_sp, v_sp, sm_scale, k_tail, v_tail, tail_len,
                prefix_len)
        if has_tail:
            return ref.sparse_decode_attention_fused_ref(
                q, k_sp, v_sp, sm_scale, k_tail, v_tail, tail_len,
                prefix_len)
        return ref.sparse_decode_attention_ref(
            q, k_sp, v_sp, sm_scale, None, None, None, prefix_len)

    if panel:
        b, qn, hq, d = q.shape
    else:
        b, hq, d = q.shape
        qn = 1
    g = hq // hkv
    bs = k_sp.block[0]
    if k_sp.block[1] != d:
        raise ValueError(f"KV block width {k_sp.block[1]} must equal head dim {d}")
    words = k_sp.bitmap.shape[-1]
    if k_sp.bitmap.ndim == 5:       # structured [B, Hkv, Sb, 1, X]
        sb = k_sp.bitmap.shape[2]
    else:
        sb = k_sp.bitmap.shape[0] // (b * hkv)
    if panel:
        # query-major rows within each GQA group: row // g = panel index
        qg = (q.reshape(b, qn, hkv, g, d).transpose(0, 2, 1, 3, 4)
              .reshape(b, hkv, qn * g, d))
    else:
        qg = q.reshape(b, hkv, g, d)
    kbm = k_sp.bitmap.reshape(b, hkv, sb, words)
    kvv = k_sp.values.reshape(b, hkv, sb, k_sp.capacity)
    vbm = v_sp.bitmap.reshape(b, hkv, sb, words)
    vvv = v_sp.values.reshape(b, hkv, sb, v_sp.capacity)
    n_blocks = None
    if prefix_len is not None:
        n_blocks = jnp.broadcast_to(
            jnp.asarray(prefix_len, jnp.int32) // bs, (b,))

    if has_tail:
        t = k_tail.shape[2]
        tl = jnp.broadcast_to(jnp.asarray(
            tail_len if tail_len is not None else t, jnp.int32), (b,))
        # pad the ring to whole (bs,)-token panels; padding is masked by tl
        pad = -t % bs
        if pad:
            k_tail = jnp.pad(k_tail, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v_tail = jnp.pad(v_tail, ((0, 0), (0, 0), (0, pad), (0, 0)))
        o = sparse_decode_attention_fused_pallas(
            qg, kbm, kvv, vbm, vvv, k_tail, v_tail, bs=bs,
            sm_scale=sm_scale, interpret=interp, n_blocks=n_blocks,
            tail_len=tl, group=g)
        if panel:
            return (o.reshape(b, hkv, qn, g, d).transpose(0, 2, 1, 3, 4)
                    .reshape(b, qn, hq, d).astype(q.dtype))
    else:
        o, _ = sparse_decode_attention_pallas(
            qg, kbm, kvv, vbm, vvv, bs=bs, sm_scale=sm_scale,
            interpret=interp, n_blocks=n_blocks)
        if prefix_len is not None:
            # a fully-skipped prefix leaves the accumulator untouched
            empty_p = jnp.broadcast_to(jnp.atleast_1d(
                jnp.asarray(prefix_len)) <= 0, (b,))
            o = jnp.where(empty_p[:, None, None, None], 0.0, o)
    return o.reshape(b, hq, d).astype(q.dtype)


def sparse_decode_attention_paged(q: jax.Array,
                                  k_bitmap: jax.Array, k_values: jax.Array,
                                  v_bitmap: jax.Array, v_values: jax.Array,
                                  table: jax.Array,
                                  hkv: int,
                                  sm_scale: float,
                                  bs: int,
                                  k_tail: jax.Array,
                                  v_tail: jax.Array,
                                  tail_len: Optional[jax.Array] = None,
                                  prefix_len: Optional[jax.Array] = None
                                  ) -> jax.Array:
    """Paged twin of :func:`sparse_decode_attention`: the compressed prefix
    lives ONCE in a pool-global arena and each slot reaches it through its
    block-table row.

    q as in :func:`sparse_decode_attention` (``[B, Hq, D]`` tick or
    ``[B, Q, Hq, D]`` panel); ``k_bitmap`` uint32 ``[n_phys, Hkv, w]`` /
    ``k_values [n_phys, Hkv, Ck]`` (same for v) the shared arena; ``table``
    int32 ``[B, Sb]`` physical block ids (entries past
    ``prefix_len // bs`` are dead but must stay in range).  Tail ring and
    length semantics are identical to the flat entry — paging touches only
    where prefix blocks are FETCHED from, never what they mean.

    XLA backend: gather each slot's logical prefix out of the arena and
    reuse the flat reference semantics verbatim (the defining oracle).
    Pallas backend: the fused kernel takes the table as a scalar-prefetch
    operand and its prefix phase loads block ``table[slot, i]`` — the
    shared blocks are streamed per slot but STORED once, which is where
    the memory-bound decode wins.
    """
    interp = _pallas()
    d = q.shape[-1]
    if interp is None:
        k_sp = ref.gather_paged_prefix(table, k_bitmap, k_values, bs, d)
        v_sp = ref.gather_paged_prefix(table, v_bitmap, v_values, bs, d)
        return sparse_decode_attention(q, k_sp, v_sp, hkv, sm_scale,
                                       k_tail, v_tail, tail_len, prefix_len)
    if q.ndim == 4 and q.shape[1] == 1:
        # Q == 1 panel IS a decode tick (see sparse_decode_attention)
        o = sparse_decode_attention_paged(
            q[:, 0], k_bitmap, k_values, v_bitmap, v_values, table, hkv,
            sm_scale, bs, k_tail, v_tail, tail_len, prefix_len)
        return o[:, None]
    panel = q.ndim == 4
    if panel:
        b, qn, hq, _ = q.shape
        qg = (q.reshape(b, qn, hkv, hq // hkv, d).transpose(0, 2, 1, 3, 4)
              .reshape(b, hkv, qn * (hq // hkv), d))
    else:
        b, hq, _ = q.shape
        qn = 1
        qg = q.reshape(b, hkv, hq // hkv, d)
    g = hq // hkv
    n_blocks = None
    if prefix_len is not None:
        n_blocks = jnp.broadcast_to(
            jnp.asarray(prefix_len, jnp.int32) // bs, (b,))
    t = k_tail.shape[2]
    tl = jnp.broadcast_to(jnp.asarray(
        tail_len if tail_len is not None else t, jnp.int32), (b,))
    pad = -t % bs
    if pad:
        k_tail = jnp.pad(k_tail, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_tail = jnp.pad(v_tail, ((0, 0), (0, 0), (0, pad), (0, 0)))
    o = sparse_decode_attention_fused_pallas(
        qg, k_bitmap, k_values, v_bitmap, v_values, k_tail, v_tail, bs=bs,
        sm_scale=sm_scale, interpret=interp, n_blocks=n_blocks,
        tail_len=tl, group=g, block_table=table)
    if panel:
        return (o.reshape(b, hkv, qn, g, d).transpose(0, 2, 1, 3, 4)
                .reshape(b, qn, hq, d).astype(q.dtype))
    return o.reshape(b, hq, d).astype(q.dtype)

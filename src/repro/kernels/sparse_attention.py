"""Sparse-KV flash-decode Pallas kernel — paper §6 on TPU.

The paper prunes the cached K/V values with unstructured magnitude pruning
(30%/50% with <1% accuracy loss) and adapts its sparse kernel to the QK^T and
RV batched matmuls.  Here the compressed **frozen prefix** of the KV cache
(bitmap + packed values per 128-token block, packed once after prefill —
paper §6.2's constant-size cache-in-model-state design) is consumed by a
flash-decoding kernel:

Grid ``(B, Hkv, S_blocks)`` with the sequence dimension innermost/sequential.
Each step decompresses one (bs, D) K block and one V block in VMEM, does the
(G, bs) score panel for the GQA head group on the MXU, and maintains online
softmax statistics in VMEM scratch.  Output is the prefix-partial attention
plus its log-sum-exp so the (tiny, dense) dynamic tail can be merged outside
the kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .common import CompilerParams, decompress_block

NEG_INF = -1e30


def _kernel(nb_ref, q_ref, kbm_ref, kval_ref, vbm_ref, vval_ref,
            o_ref, lse_ref, acc_ref, m_ref, l_ref, *, bs, d, sm_scale):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Per-slot valid-block count (pooled cache: each request fills only a
    # prefix of the fixed-capacity block storage).  Blocks past it are
    # skipped entirely — zero compute, zero softmax contribution.
    @pl.when(s_idx < nb_ref[0, 0])
    def _block():
        k_blk = decompress_block(kbm_ref[0, 0, 0], kval_ref[0, 0, 0], bs, d,
                                 dtype=jnp.float32)              # (bs, D)
        q = q_ref[0, 0].astype(jnp.float32)                      # (G, D)
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))         # (G,)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                          # (G, bs)
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)

        v_blk = decompress_block(vbm_ref[0, 0, 0], vval_ref[0, 0, 0], bs, d,
                                 dtype=jnp.float32)              # (bs, D)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p, v_blk,
                                  preferred_element_type=jnp.float32))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _done():
        l_safe = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[:, 0] + jnp.log(l_safe)).astype(lse_ref.dtype)


@partial(jax.jit, static_argnames=("bs", "sm_scale", "interpret"))
def sparse_decode_attention_pallas(
        q: jax.Array,
        k_bitmap: jax.Array, k_values: jax.Array,
        v_bitmap: jax.Array, v_values: jax.Array,
        bs: int, sm_scale: float, interpret: bool = True,
        n_blocks: jax.Array | None = None):
    """Prefix-partial attention over the compressed cache.

    q:         [B, Hkv, G, D]
    k_bitmap:  uint32 [B, Hkv, Sb, bs*D//32]   (same for v_bitmap)
    k_values:  [B, Hkv, Sb, Ck]                (v_values: [.., Cv])
    n_blocks:  optional int32 [B] — per-slot count of *valid* sequence
               blocks (pooled serving cache); blocks past it are skipped.
               None means every block is valid.
    Returns (out [B, Hkv, G, D] f32, lse [B, Hkv, G] f32).
    """
    b, hkv, g, d = q.shape
    sb = k_bitmap.shape[2]
    words = k_bitmap.shape[3]
    ck, cv = k_values.shape[3], v_values.shape[3]
    if n_blocks is None:
        n_blocks = jnp.full((b,), sb, jnp.int32)
    nb2 = n_blocks.astype(jnp.int32).reshape(b, 1)   # 2-D for SMEM

    out, lse = pl.pallas_call(
        partial(_kernel, bs=bs, d=d, sm_scale=sm_scale),
        grid=(b, hkv, sb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, h, s: (bb, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bb, h, s: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, words), lambda bb, h, s: (bb, h, s, 0)),
            pl.BlockSpec((1, 1, 1, ck), lambda bb, h, s: (bb, h, s, 0)),
            pl.BlockSpec((1, 1, 1, words), lambda bb, h, s: (bb, h, s, 0)),
            pl.BlockSpec((1, 1, 1, cv), lambda bb, h, s: (bb, h, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, h, s: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bb, h, s: (bb, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sparse_decode_attention",
    )(nb2, q, k_bitmap, k_values, v_bitmap, v_values)
    return out, lse

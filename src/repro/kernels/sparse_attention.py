"""Sparse-KV flash-decode Pallas kernels — paper §6 on TPU.

The paper prunes the cached K/V values with unstructured magnitude pruning
(30%/50% with <1% accuracy loss) and adapts its sparse kernel to the QK^T and
RV batched matmuls.  Here the compressed **frozen prefix** of the KV cache
(bitmap + packed values per 128-token block, packed once after prefill —
paper §6.2's constant-size cache-in-model-state design) plus the dense
**dynamic tail** ring are consumed by flash-decoding kernels.

Two entry points share one online-softmax core:

* :func:`sparse_decode_attention_fused_pallas` — the serving hot path.
  Grid ``(B, Hkv, Sb + Tb)`` with the sequence axis innermost/sequential:
  the first ``Sb`` steps decompress one (bs, D) compressed prefix block
  each (skipping past each slot's valid-block count), the remaining ``Tb``
  steps load dense (bs, D) panels straight from the ``[B, Hkv, T, D]``
  tail ring under a per-slot ``tail_len`` validity mask held in SMEM.  The
  same VMEM online-softmax scratch runs across both phases, so ONE
  ``pallas_call`` produces the final attention output — no ``lse`` output,
  no XLA-side tail attention, no lse merge, and no ``jnp.repeat`` GQA head
  materialization anywhere on the per-token path.

  The query operand is a *panel*: ``[B, Hkv, Q*G, D]`` rows ordered
  query-major within the GQA group (``row // G`` is the query's panel
  index).  ``Q == 1`` is the plain decode tick; ``Q == K+1`` is the
  speculative-decoding verify step, where panel query ``j`` additionally
  sees the ``j`` tail tokens its panel predecessors appended — the
  intra-window causal mask is ``token < tail_len + j``, applied per row
  against the same SMEM ``tail_len`` scalar.  The compressed prefix is
  fully visible to every panel query, so the prefix phase is untouched.

* :func:`sparse_decode_attention_pallas` — the prefix-*partial* entry:
  returns ``(out, lse)`` over the compressed prefix only.  Kept for the
  context-parallel decode path (``repro.distributed.cp_attention``), where
  per-shard partials must cross chips before the merge, so fusing the tail
  into the kernel is structurally impossible.

Each sequence step does the (G, bs) score panel for the GQA head group on
the MXU and maintains online softmax statistics in VMEM scratch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from .common import CompilerParams, decompress_block

NEG_INF = -1e30


def _online_update(q, k_blk, v_blk, acc_ref, m_ref, l_ref, *, sm_scale,
                   valid=None):
    """One flash step: score a (bs, D) panel against the (G, D) query group
    and fold it into the online-softmax scratch state."""
    s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if valid is not None:                                    # (1, bs) mask
        s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))         # (G,)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                          # (G, bs)
    if valid is not None:
        p = jnp.where(valid, p, 0.0)
    l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v_blk,
                              preferred_element_type=jnp.float32))
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)


def _kernel(nb_ref, q_ref, kbm_ref, kval_ref, vbm_ref, vval_ref,
            o_ref, lse_ref, acc_ref, m_ref, l_ref, *, bs, d, sm_scale):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Per-slot valid-block count (pooled cache: each request fills only a
    # prefix of the fixed-capacity block storage).  Blocks past it are
    # skipped entirely — zero compute, zero softmax contribution.
    @pl.when(s_idx < nb_ref[0, 0])
    def _block():
        k_blk = decompress_block(kbm_ref[0, 0, 0], kval_ref[0, 0, 0], bs, d,
                                 dtype=jnp.float32)              # (bs, D)
        v_blk = decompress_block(vbm_ref[0, 0, 0], vval_ref[0, 0, 0], bs, d,
                                 dtype=jnp.float32)              # (bs, D)
        q = q_ref[0, 0].astype(jnp.float32)                      # (G, D)
        _online_update(q, k_blk, v_blk, acc_ref, m_ref, l_ref,
                       sm_scale=sm_scale)

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _done():
        l_safe = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[:, 0] + jnp.log(l_safe)).astype(lse_ref.dtype)


@partial(jax.jit, static_argnames=("bs", "sm_scale", "interpret"))
def sparse_decode_attention_pallas(
        q: jax.Array,
        k_bitmap: jax.Array, k_values: jax.Array,
        v_bitmap: jax.Array, v_values: jax.Array,
        bs: int, sm_scale: float, interpret: bool = True,
        n_blocks: jax.Array | None = None):
    """Prefix-partial attention over the compressed cache.

    Kept for the context-parallel path (per-shard partials merge across
    chips); single-chip decode uses the fused entry below.

    q:         [B, Hkv, G, D]
    k_bitmap:  uint32 [B, Hkv, Sb, bs*D//32]   (same for v_bitmap)
    k_values:  [B, Hkv, Sb, Ck]                (v_values: [.., Cv])
    n_blocks:  optional int32 [B] — per-slot count of *valid* sequence
               blocks (pooled serving cache); blocks past it are skipped.
               None means every block is valid.
    Returns (out [B, Hkv, G, D] f32, lse [B, Hkv, G] f32).
    """
    b, hkv, g, d = q.shape
    sb = k_bitmap.shape[2]
    words = k_bitmap.shape[3]
    ck, cv = k_values.shape[3], v_values.shape[3]
    if n_blocks is None:
        n_blocks = jnp.full((b,), sb, jnp.int32)
    nb2 = n_blocks.astype(jnp.int32).reshape(b, 1)   # 2-D for SMEM

    out, lse = pl.pallas_call(
        partial(_kernel, bs=bs, d=d, sm_scale=sm_scale),
        grid=(b, hkv, sb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, h, s: (bb, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bb, h, s: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, words), lambda bb, h, s: (bb, h, s, 0)),
            pl.BlockSpec((1, 1, 1, ck), lambda bb, h, s: (bb, h, s, 0)),
            pl.BlockSpec((1, 1, 1, words), lambda bb, h, s: (bb, h, s, 0)),
            pl.BlockSpec((1, 1, 1, cv), lambda bb, h, s: (bb, h, s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bb, h, s: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, g), lambda bb, h, s: (bb, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sparse_decode_attention",
    )(nb2, q, k_bitmap, k_values, v_bitmap, v_values)
    return out, lse


def _fused_kernel(nb_ref, tl_ref, q_ref, kbm_ref, kval_ref, vbm_ref,
                  vval_ref, kt_ref, vt_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, bs, d, sm_scale, sb, g):
    """Prefix + tail in one sequential sweep.

    Steps ``[0, sb)`` walk the compressed prefix blocks (gated by the
    per-slot valid-block count in SMEM); steps ``[sb, sb+tb)`` walk the
    dense tail ring (gated per token by the per-slot ``tail_len`` in SMEM).
    One online-softmax scratch state spans both phases, so the final step
    writes the fully-normalized attention output — no lse ever leaves the
    kernel.

    The query block is ``(Q*g, D)`` rows ordered query-major within the
    GQA group; tail validity is per row — panel query ``row // g`` sees
    ``tail_len + row // g`` tail tokens (the extra ones are the K/V its
    panel predecessors appended).  ``Q == 1`` reduces to the plain
    single-query mask ``token < tail_len``.
    """
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(jnp.logical_and(s_idx < sb, s_idx < nb_ref[0, 0]))
    def _prefix_block():
        k_blk = decompress_block(kbm_ref[0, 0, 0], kval_ref[0, 0, 0], bs, d,
                                 dtype=jnp.float32)              # (bs, D)
        v_blk = decompress_block(vbm_ref[0, 0, 0], vval_ref[0, 0, 0], bs, d,
                                 dtype=jnp.float32)
        q = q_ref[0, 0].astype(jnp.float32)                      # (Q*g, D)
        _online_update(q, k_blk, v_blk, acc_ref, m_ref, l_ref,
                       sm_scale=sm_scale)

    tail_base = (s_idx - sb) * bs
    qg = q_ref.shape[2]
    # per-row visibility limit: query j (= row // g) sees tail_len + j
    row_q = jax.lax.broadcasted_iota(jnp.int32, (qg, 1), 0) // g

    @pl.when(jnp.logical_and(s_idx >= sb,
                             tail_base < tl_ref[0, 0] + (qg // g - 1)))
    def _tail_block():
        k_blk = kt_ref[0, 0].astype(jnp.float32)                 # (bs, D)
        v_blk = vt_ref[0, 0].astype(jnp.float32)
        q = q_ref[0, 0].astype(jnp.float32)
        tok = tail_base + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        _online_update(q, k_blk, v_blk, acc_ref, m_ref, l_ref,
                       sm_scale=sm_scale,
                       valid=tok < tl_ref[0, 0] + row_q)

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _done():
        l_safe = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def _fused_kernel_paged(tbl_ref, *refs, **kw):
    """Paged wrapper: the block table rides in as a scalar-prefetch operand
    consumed ONLY by the index maps (one level of indirection on the
    prefix phase's block fetch); the kernel body is byte-for-byte the flat
    ``_fused_kernel``."""
    del tbl_ref
    _fused_kernel(*refs, **kw)


@partial(jax.jit, static_argnames=("bs", "sm_scale", "interpret", "group"))
def sparse_decode_attention_fused_pallas(
        q: jax.Array,
        k_bitmap: jax.Array, k_values: jax.Array,
        v_bitmap: jax.Array, v_values: jax.Array,
        k_tail: jax.Array, v_tail: jax.Array,
        bs: int, sm_scale: float, interpret: bool = True,
        n_blocks: jax.Array | None = None,
        tail_len: jax.Array | None = None,
        group: int | None = None,
        block_table: jax.Array | None = None) -> jax.Array:
    """Fused prefix+tail flash-decode: final attention in ONE pallas_call.

    q:             [B, Hkv, Q*G, D] query panel, rows ordered query-major
                   within the GQA group (``row // G`` = panel index).
                   ``group=G`` declares the group size; None means the
                   whole row axis is one query (``Q == 1`` — the plain
                   decode tick).
    k_bitmap:      uint32 [B, Hkv, Sb, bs*D//32]   (same for v_bitmap)
    k_values:      [B, Hkv, Sb, Ck]                (v_values: [.., Cv])
    k_tail/v_tail: dense tail ring [B, Hkv, Tp, D] with ``Tp % bs == 0``
                   (the dispatcher zero-pads the ring to a whole number of
                   (bs,)-token panels; padding is masked by ``tail_len``).
    n_blocks:      optional int32 [B] — per-slot valid prefix blocks;
                   None means all ``Sb`` are valid.
    tail_len:      optional int32 [B] — tail tokens visible to panel query
                   0; query ``j`` sees ``tail_len + j`` (intra-window
                   causal — the verify step appends one K/V per panel
                   query).  None means the whole ring is valid to query 0.
    block_table:   optional int32 [B, Sb] — PAGED prefix: the bitmap/values
                   operands are then a pool-global arena
                   ``[n_phys, Hkv, X]`` and the grid's prefix phase loads
                   physical block ``block_table[slot, i]`` instead of slot
                   block ``(slot, i)``.  The table rides in as a
                   scalar-prefetch operand so the index maps (which run
                   ahead of the kernel body to schedule the block DMAs)
                   can read it; every entry must address real storage
                   (``< n_phys``) even past ``n_blocks`` — dead fetches
                   are gated off the softmax by the same ``n_blocks``
                   check as the flat path, so they are never *read*.
    Returns out [B, Hkv, Q*G, D] f32 — softmax-normalized over the union
    of valid prefix and tail positions (all-empty slots return zeros).
    """
    b, hkv, qg, d = q.shape
    g = group or qg
    if qg % g != 0:
        raise ValueError(f"query panel {qg} not a multiple of group {g}")
    paged = block_table is not None
    if paged:
        if k_bitmap.ndim != 3:   # [n_phys, Hkv, X] arena
            raise ValueError(f"paged arena must be rank-3, got {k_bitmap.shape}")
        sb = block_table.shape[1]
        # rank-4 views so the block shapes match the flat layout's
        # (1, 1, 1, X) fetches: physical block axis leads, Hkv second
        k_bitmap, k_values, v_bitmap, v_values = (
            a[:, :, None, :] for a in (k_bitmap, k_values,
                                       v_bitmap, v_values))
    else:
        sb = k_bitmap.shape[2]
    tp = k_tail.shape[2]
    if not (sb >= 1 and tp >= bs and tp % bs == 0):
        raise ValueError(f"bad geometry: sb={sb}, tail={tp}, block={bs}")
    tb = tp // bs
    words = k_bitmap.shape[3]
    ck, cv = k_values.shape[3], v_values.shape[3]
    if n_blocks is None:
        n_blocks = jnp.full((b,), sb, jnp.int32)
    if tail_len is None:
        tail_len = jnp.full((b,), tp, jnp.int32)
    nb2 = n_blocks.astype(jnp.int32).reshape(b, 1)   # 2-D for SMEM
    tl2 = tail_len.astype(jnp.int32).reshape(b, 1)

    common = dict(
        out_shape=jax.ShapeDtypeStruct((b, hkv, qg, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    scratch = [
        pltpu.VMEM((qg, d), jnp.float32),
        pltpu.VMEM((qg, 128), jnp.float32),
        pltpu.VMEM((qg, 128), jnp.float32),
    ]

    if paged:
        # THE paged change: the prefix phase's block index goes through the
        # table.  Clamped on tail-phase steps like the flat path (the
        # fetched block is ignored there — the pl.when gates never fire).
        pre = lambda bb, h, s, tbl: (tbl[bb, jnp.minimum(s, sb - 1)],
                                     h, 0, 0)
        tail = lambda bb, h, s, tbl: (bb, h, jnp.maximum(s - sb, 0), 0)
        smem = lambda bb, h, s, tbl: (bb, 0)
        bcast = lambda bb, h, s, tbl: (bb, h, 0, 0)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, hkv, sb + tb),
            in_specs=[
                pl.BlockSpec((1, 1), smem, memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1), smem, memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1, qg, d), bcast),
                pl.BlockSpec((1, 1, 1, words), pre),
                pl.BlockSpec((1, 1, 1, ck), pre),
                pl.BlockSpec((1, 1, 1, words), pre),
                pl.BlockSpec((1, 1, 1, cv), pre),
                pl.BlockSpec((1, 1, bs, d), tail),
                pl.BlockSpec((1, 1, bs, d), tail),
            ],
            out_specs=pl.BlockSpec((1, 1, qg, d), bcast),
            scratch_shapes=scratch,
        )
        return pl.pallas_call(
            partial(_fused_kernel_paged, bs=bs, d=d, sm_scale=sm_scale,
                    sb=sb, g=g),
            grid_spec=grid_spec,
            name="sparse_decode_attention_fused_paged",
            **common,
        )(block_table.astype(jnp.int32), nb2, tl2, q,
          k_bitmap, k_values, v_bitmap, v_values, k_tail, v_tail)

    # index maps clamp into range on the other phase's steps (the fetched
    # block is ignored there — the pl.when gates never fire)
    pre = lambda bb, h, s: (bb, h, jnp.minimum(s, sb - 1), 0)
    tail = lambda bb, h, s: (bb, h, jnp.maximum(s - sb, 0), 0)

    out = pl.pallas_call(
        partial(_fused_kernel, bs=bs, d=d, sm_scale=sm_scale, sb=sb, g=g),
        grid=(b, hkv, sb + tb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, h, s: (bb, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda bb, h, s: (bb, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, qg, d), lambda bb, h, s: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, words), pre),
            pl.BlockSpec((1, 1, 1, ck), pre),
            pl.BlockSpec((1, 1, 1, words), pre),
            pl.BlockSpec((1, 1, 1, cv), pre),
            pl.BlockSpec((1, 1, bs, d), tail),
            pl.BlockSpec((1, 1, bs, d), tail),
        ],
        out_specs=pl.BlockSpec((1, 1, qg, d), lambda bb, h, s: (bb, h, 0, 0)),
        scratch_shapes=scratch,
        name="sparse_decode_attention_fused",
        **common,
    )(nb2, tl2, q, k_bitmap, k_values, v_bitmap, v_values, k_tail, v_tail)
    return out

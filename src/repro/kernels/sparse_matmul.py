"""Sparse (bitmap + packed values) GEMM Pallas kernel — paper §4.3 on TPU.

Load-as-sparse, compute-as-dense: each grid cell streams one *compressed*
weight block (bitmap words + up-to-capacity packed values) HBM->VMEM,
expands it to a dense ``(bk, bn)`` tile with
:func:`repro.kernels.common.decompress_block`, and feeds the MXU.  HBM
traffic for weights is ``C/(bk*bn) + 1/16`` of the dense bf16 bytes —
exactly the paper's bandwidth-saving mechanism, minus the AVX->memory->AMX
round-trip which has no TPU analogue (DESIGN.md §2).

Layout (produced by ``repro.core.sparse_format.pack``):
  bitmap  uint32 ``[Kb, Nb, bk*bn//32]``
  values         ``[Kb, Nb, C]``

Grid ``(M/tm, Nb, Kb)``; K innermost/sequential, f32 VMEM accumulator.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.sparse_format import BlockSparseWeight
from .common import CompilerParams, decompress_block


def _kernel(x_ref, bm_ref, val_ref, o_ref, acc_ref, *, bk, bn):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_tile = decompress_block(bm_ref[0, 0], val_ref[0, 0], bk, bn,
                              dtype=val_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], w_tile,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("tm", "out_dtype", "interpret"))
def sparse_matmul_pallas(x: jax.Array, sw: BlockSparseWeight,
                         tm: int = 128, out_dtype=None,
                         interpret: bool = True) -> jax.Array:
    """``x [M, K] @ unpack(sw) [K, N]`` without materializing the dense W in HBM."""
    bk, bn = sw.block
    kb, nb, words = sw.bitmap.shape
    cap = sw.capacity
    m, k = x.shape
    kp = kb * bk
    mp = -(-m // tm) * tm
    x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    out_dtype = out_dtype or x.dtype

    out = pl.pallas_call(
        partial(_kernel, bk=bk, bn=bn),
        grid=(mp // tm, nb, kb),
        in_specs=[
            pl.BlockSpec((tm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((1, 1, words), lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, 1, cap), lambda i, j, kk: (kk, j, 0)),
        ],
        out_specs=pl.BlockSpec((tm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, nb * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="sparse_matmul",
    )(x, sw.bitmap, sw.values)
    return out[:m, : sw.shape[1]]

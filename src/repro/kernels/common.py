"""Shared in-kernel helpers for the SparAMX-style Pallas kernels.

``decompress_block`` is the TPU re-think of the paper's Algorithm 2
(`vpexpandw` + `vpopcntd` + AVX prefix sum):

* AVX bitmap fetch            -> uint32 words already staged in VMEM
* vpopcntd per 32-bit word    -> row-sum of unpacked bits (VPU reduce)
* Alg. 1 parallel prefix sum  -> two-level exclusive cumsum (lane log-shifts)
* vpexpandw expand            -> vector gather ``values[prefix]`` masked by
                                 the bitmap

Crucially there is no AVX->memory->AMX round-trip (the paper's stated
architectural bottleneck, §7): the expanded tile is produced in VMEM and fed
straight to the MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import jax.experimental.pallas.tpu as pltpu

# jax<=0.4.x exposes TPUCompilerParams; newer releases renamed it to
# CompilerParams.  All kernels route through this alias.
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))


def unpack_bits_block(words: jax.Array, bk: int, bn: int) -> jax.Array:
    """uint32 ``(bk*bn//32,)`` -> int32 0/1 mask ``(bk, bn)`` (row-major)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((words[:, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
    return bits.reshape(bk, bn)


def decompress_block(words: jax.Array, values: jax.Array,
                     bk: int, bn: int, dtype=None) -> jax.Array:
    """Expand one compressed block to a dense ``(bk, bn)`` tile in registers.

    words: uint32 ``(bk*bn//32,)`` bitmap; values: ``(C,)`` packed non-zeros.
    """
    mask = unpack_bits_block(words, bk, bn)
    # two-level exclusive prefix sum over the row-major flat order
    within = jnp.cumsum(mask, axis=1) - mask                  # (bk, bn)
    row_nnz = jnp.sum(mask, axis=1, keepdims=True)            # (bk, 1)
    row_off = jnp.cumsum(row_nnz, axis=0) - row_nnz           # (bk, 1)
    idx = jnp.minimum(row_off + within, values.shape[0] - 1)
    dense = jnp.take(values, idx)                             # vector gather
    dense = jnp.where(mask > 0, dense, jnp.zeros((), values.dtype))
    return dense.astype(dtype or values.dtype)

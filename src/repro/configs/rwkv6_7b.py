"""RWKV-6 (Finch) 7B [arXiv:2404.05892; hf] — attention-free,
data-dependent decay; 64 heads of 64."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv=0, d_ff=14336,
    vocab=65536, rwkv_head_dim=64,
)

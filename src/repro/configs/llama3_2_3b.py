"""Llama-3.2-3B [hf:meta-llama/Llama-3.2-1B family; unverified]."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv=8, d_ff=8192,
    vocab=128256, head_dim=128, rope_theta=5e5,
)

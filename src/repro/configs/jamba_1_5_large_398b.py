"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf] — Mamba+attention 1:7
interleave (attention at layer i%8==4), MoE 16e top-2 every other layer."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, d_ff=24576,
    vocab=65536, head_dim=128, rope_theta=1e4,
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4, d_state=16, d_conv=4, ssm_expand=2,
    fsdp=True,
)

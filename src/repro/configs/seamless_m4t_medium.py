"""SeamlessM4T-medium [arXiv:2308.11596; hf] — encoder-decoder, multimodal.
12 encoder + 12 decoder layers; speech frontend is a stub (input_specs
yields precomputed frame embeddings)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv=16,
    d_ff=4096, vocab=256206, head_dim=64, rope_theta=1e4,
    # the speech frontend stub is the encoder src_embeds input itself
)

"""InternVL2-1B [arXiv:2404.16821; hf] — InternViT frontend (stub) +
InternLM2-ish 0.5B LM backbone."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864,
    vocab=151655, head_dim=64, rope_theta=1e6,
    frontend="patch", frontend_tokens=256, tie_embeddings=True,
)

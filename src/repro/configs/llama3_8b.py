"""Llama-3-8B — the paper's own evaluation model (Figs 1,3,11,12; Table 2)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=128256, head_dim=128, rope_theta=5e5,
)

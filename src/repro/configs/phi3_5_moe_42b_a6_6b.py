"""Phi-3.5-MoE-42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct; hf]
16 experts, top-2, every layer."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=6400,
    vocab=32064, head_dim=128, rope_theta=1e4,
    n_experts=16, top_k=2, moe_every=1, fsdp=True,
)

"""Architecture + shape configuration registry.

Each assigned architecture has a ``<id>.py`` here exporting ``CONFIG``.
``reduced()`` yields the family-preserving small variant used by CPU smoke
tests; the full configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | encdec | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- hybrid (jamba) / ssm ---
    attn_every: int = 0          # attention at i % attn_every == attn_offset
    attn_offset: int = 0
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    # --- encoder-decoder ---
    enc_layers: int = 0
    # --- multimodal stub frontend ---
    frontend: str = ""           # "" | "patch" | "frames"
    frontend_tokens: int = 0     # prefix embeddings provided by input_specs
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- the paper's technique ---
    sparsity: float = 0.5
    sparse_policy: str = "balanced"
    kv_k_sparsity: float = 0.3
    kv_v_sparsity: float = 0.5
    kv_tail: int = 128
    # --- distribution / memory knobs ---
    cp_decode: bool = False      # context-parallel shard_map decode attention
    ep_moe: bool = False         # expert-parallel MoE (experts over DP axes)
    serve_fsdp: bool = True      # False: keep serving weights TP-resident
    full_attn_max: int = 4096    # longest seq using the one-einsum attention
    tp_pad: int = 16             # pad head counts to a multiple of this
    remat: bool = True
    scan_layers: bool = True
    attn_impl: str = "masked"    # "masked" | "triangular" (flash schedule)
    seq_shard: bool = True       # Megatron-style sequence sharding of residuals
    fsdp: bool = False           # shard params over data too (ZeRO-3-ish)
    zero1: bool = True           # shard optimizer state over data
    scan_chunk: int = 128        # remat chunk for recurrent (ssm) seq scans

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def padded_heads(self) -> int:
        if self.n_heads == 0:
            return 0
        p = self.tp_pad
        return -(-self.n_heads // p) * p

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def is_moe_layer(self, i: int) -> bool:
        return (self.n_experts > 0) and (i % self.moe_every == self.moe_offset)

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return i % self.attn_every == self.attn_offset
        return True

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny variant for CPU smoke tests."""
        kw = dict(
            n_layers=4, d_model=128, n_heads=4, n_kv=min(self.n_kv, 2) or 0,
            d_ff=256, vocab=512, head_dim=32, tp_pad=1, seq_shard=False,
            fsdp=False, scan_chunk=16,
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2),
                      moe_every=min(self.moe_every, 2),
                      moe_offset=self.moe_offset % min(self.moe_every, 2))
        if self.family == "hybrid":
            kw.update(attn_every=2, attn_offset=1, ssm_expand=2, d_state=4,
                      n_layers=4)
        if self.family == "ssm":
            kw.update(rwkv_head_dim=32, n_heads=4)
        if self.enc_layers:
            kw.update(enc_layers=2, n_layers=2)
        if self.frontend:
            kw.update(frontend_tokens=8)
        return dataclasses.replace(self, **kw)


ARCH_IDS = [
    "qwen3-0.6b", "deepseek-67b", "llama3.2-3b", "phi3-mini-3.8b",
    "llama4-scout-17b-a16e", "phi3.5-moe-42b-a6.6b", "seamless-m4t-medium",
    "internvl2-1b", "rwkv6-7b", "jamba-1.5-large-398b",
]
PAPER_ARCH = "llama3-8b"          # the paper's own evaluation model

_MODULES = {
    "qwen3-0.6b": "qwen3_0_6b",
    "deepseek-67b": "deepseek_67b",
    "llama3.2-3b": "llama3_2_3b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-1b": "internvl2_1b",
    "rwkv6-7b": "rwkv6_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llama3-8b": "llama3_8b",
}


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def applicable_shapes(cfg: ArchConfig) -> Tuple[str, ...]:
    """long_500k needs sub-quadratic attention: SSM/hybrid only (DESIGN §5)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        out.append("long_500k")
    return tuple(out)

"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
MoE 16 experts top-1 + shared expert, every layer; early-fusion multimodal
(text-only backbone here; fusion enters as embedding inputs)."""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv=8, d_ff=8192,
    vocab=202048, head_dim=128, rope_theta=5e5,
    n_experts=16, top_k=1, moe_every=1, shared_expert=True, fsdp=True,
)

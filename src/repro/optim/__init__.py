from .adamw import (OptConfig, lr_schedule, init_opt_state,
                    abstract_opt_state, adamw_step, global_norm)
from .grad_compress import init_error_state, compress_and_reduce

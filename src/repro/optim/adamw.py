"""AdamW + LR schedules + global-norm clipping, from scratch (no optax here).

Mixed-precision layout: model params live in bf16; the optimizer state holds
the fp32 master copy plus fp32 first/second moments.  With ``zero1`` the
whole optimizer state shards over the data axis (see
repro.distributed.sharding.zero1_specs), which is what makes 67B-class
training fit a 256-chip pod (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    end_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(optc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = optc.peak_lr * step / max(optc.warmup_steps, 1)
    prog = jnp.clip((step - optc.warmup_steps)
                    / max(optc.decay_steps - optc.warmup_steps, 1), 0.0, 1.0)
    cos = optc.peak_lr * (optc.end_lr_frac + (1 - optc.end_lr_frac)
                          * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < optc.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree_util.tree_map(f32, params),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def abstract_opt_state(params: Any) -> Dict[str, Any]:
    sds = lambda p, dt: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "master": jax.tree_util.tree_map(
            lambda p: sds(p, jnp.float32), params),
        "m": jax.tree_util.tree_map(lambda p: sds(p, jnp.float32), params),
        "v": jax.tree_util.tree_map(lambda p: sds(p, jnp.float32), params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_step(grads: Any, opt_state: Dict[str, Any], optc: OptConfig,
               params_like: Any = None) -> Tuple[Any, Dict[str, Any], Dict]:
    """Returns (new params cast to their original per-leaf dtypes, new opt
    state, metrics).  ``params_like`` supplies the dtypes (norm scales stay
    fp32 while matmul weights stay bf16)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(optc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, optc.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if optc.clip_norm else 1.0

    b1, b2 = optc.b1, optc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + optc.eps)
        p_new = p - lr * (update + optc.weight_decay * p)
        return m, v, p_new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(opt_state["master"])
    new_m, new_v, new_p = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        m2, v2, p2 = upd(g, m, v, p)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(p2)
    unf = treedef.unflatten
    new_state = {"step": step, "master": unf(new_p), "m": unf(new_m),
                 "v": unf(new_v)}
    if params_like is not None:
        params = jax.tree_util.tree_map(
            lambda m, p: m.astype(p.dtype), new_state["master"], params_like)
    else:
        params = jax.tree_util.tree_map(lambda p: p.astype(jnp.bfloat16),
                                        new_state["master"])
    return params, new_state, {"lr": lr, "grad_norm": gnorm}

"""Gradient compression with error feedback for the DP all-reduce.

At 512+ chips, the `pod`-axis gradient reduction crosses DCN — the slowest
link in the system.  Classic remedy: compress the per-shard gradients before
the reduction and keep the quantization error in a local accumulator
("error feedback", 1-bit-Adam/EF21 style):

    q_t   = compress(g_t + e_t)
    e_t+1 = (g_t + e_t) - q_t
    g_hat = all_reduce(q_t)

Schemes:
  * ``bf16``  — cast to bf16 (2x DCN bytes saved vs fp32 reduction)
  * ``int8``  — per-tensor symmetric int8 (4x saved), error feedback
                absorbs the quantization noise

The compressed reduction is exercised inside ``shard_map`` over the DP axes
(see repro.train.step.make_compressed_train_step) so the reduce operand in
the HLO really is the compressed dtype — visible in the dry-run collective
bytes (§Roofline).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def init_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_and_reduce(grads: Any, err_state: Any, axis_names,
                        scheme: str = "bf16") -> Tuple[Any, Any]:
    """Inside shard_map: compress, psum over ``axis_names``, decompress.

    Returns (reduced fp32 grads averaged over the DP group, new error state).
    int8 uses a group-shared scale (pmax of local amax — a scalar collective)
    so the int32 reduction dequantizes exactly.
    """
    n = 1
    for ax in axis_names:
        # jax.lax.axis_size only exists on newer jax; psum(1) is portable
        n *= (jax.lax.axis_size(ax) if hasattr(jax.lax, "axis_size")
              else jax.lax.psum(1, ax))

    def one(g, e):
        acc = g.astype(jnp.float32) + e
        if scheme == "bf16":
            q = acc.astype(jnp.bfloat16)
            new_e = acc - q.astype(jnp.float32)
            g_hat = jax.lax.psum(q, axis_names).astype(jnp.float32) / n
        elif scheme == "int8":
            amax = jax.lax.pmax(jnp.max(jnp.abs(acc)), axis_names)
            scale = jnp.maximum(amax, 1e-12) / 127.0
            q = jnp.clip(jnp.round(acc / scale), -127, 127).astype(jnp.int8)
            new_e = acc - q.astype(jnp.float32) * scale
            g_hat = (jax.lax.psum(q.astype(jnp.int32), axis_names)
                     .astype(jnp.float32) * scale / n)
        else:
            raise ValueError(scheme)
        return g_hat, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_hat = treedef.unflatten([o[0] for o in outs])
    new_err = treedef.unflatten([o[1] for o in outs])
    return g_hat, new_err

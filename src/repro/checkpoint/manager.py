"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore.

Properties needed at 1000-node scale, implemented here at single-process
scope with the same contracts:

* **atomicity** — a checkpoint directory is staged under ``.tmp-<step>`` and
  ``os.rename``d into place; readers can never observe a torn write; a crash
  mid-save leaves only a tmp dir that the next run garbage-collects.
* **async** — ``save`` snapshots arrays to host memory synchronously (one
  device->host copy) and writes to disk on a background thread, so the train
  loop resumes immediately (overlap of I/O with compute).
* **keep-k + manifest** — ``manifest.json`` records step, params digest and
  config; old checkpoints are pruned once the newer one is durable.
* **elastic restore** — arrays are stored logically (full tensors); restore
  ``device_put``s onto *any* mesh/sharding, so a job can come back on a
  different pod count after a failure (elastic scaling).  At real scale this
  becomes per-shard files + resharding-on-read; the contract is the same.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree: Any, arrays: Dict[str, np.ndarray]) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = arrays[key]
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != want:
            # npz round-trips bf16 (ml_dtypes) as raw void bytes: view-cast
            if arr.dtype.kind == "V" and arr.dtype.itemsize == \
                    np.dtype(want).itemsize:
                arr = arr.view(want)
            else:
                arr = arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._gc_tmp()

    # -- write ------------------------------------------------------------
    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             blocking: bool = False) -> None:
        host = _flatten(state)          # device->host copy happens here
        self.wait()                     # one in-flight save at a time

        def write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {"step": step, "time": time.time(),
                        "n_arrays": len(host), **(meta or {})}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- read -------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like``; device_put per-leaf onto
        ``shardings`` (any mesh — elastic) when given."""
        path = os.path.join(self.dir, f"step_{step:010d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        state = _unflatten_into(like, arrays)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        else:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, manifest

    # -- hygiene ----------------------------------------------------------
    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def _gc_tmp(self) -> None:
        for name in os.listdir(self.dir):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore.

Properties needed at 1000-node scale, implemented here at single-process
scope with the same contracts:

* **atomicity** — a checkpoint directory is staged under ``.tmp-<step>`` and
  ``os.rename``d into place; readers can never observe a torn write; a crash
  mid-save leaves only a tmp dir that the next run garbage-collects.
* **async** — ``save`` snapshots arrays to host memory synchronously (one
  device->host copy) and writes to disk on a background thread, so the train
  loop resumes immediately (overlap of I/O with compute).
* **keep-k + manifest** — ``manifest.json`` records step, params digest and
  config; old checkpoints are pruned once the newer one is durable.
* **elastic restore** — arrays are stored logically (full tensors); restore
  ``device_put``s onto *any* mesh/sharding, so a job can come back on a
  different pod count after a failure (elastic scaling).  At real scale this
  becomes per-shard files + resharding-on-read; the contract is the same.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_into(tree: Any, arrays: Dict[str, np.ndarray]) -> Any:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise ValueError(
                f"checkpoint missing array {key!r}: the saved tree does "
                f"not match the restore template (has "
                f"{sorted(arrays)[:8]}{'...' if len(arrays) > 8 else ''})")
        arr = arrays[key]
        want_shape = getattr(leaf, "shape", None)
        if want_shape is not None and tuple(arr.shape) != tuple(want_shape):
            raise ValueError(
                f"checkpoint geometry mismatch at {key!r}: restore "
                f"template expects shape {tuple(want_shape)}, checkpoint "
                f"holds {tuple(arr.shape)}")
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != want:
            # npz round-trips bf16 (ml_dtypes) as raw void bytes: view-cast
            if arr.dtype.kind == "V" and arr.dtype.itemsize == \
                    np.dtype(want).itemsize:
                arr = arr.view(want)
            else:
                arr = arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._gc_tmp()

    # -- write ------------------------------------------------------------
    def save(self, step: int, state: Any, meta: Optional[Dict] = None,
             blocking: bool = False) -> None:
        host = _flatten(state)          # device->host copy happens here
        self.wait()                     # one in-flight save at a time

        def write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {"step": step, "time": time.time(),
                        "n_arrays": len(host), **(meta or {})}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._prune()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- read -------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def read_manifest(self, step: int) -> Dict:
        """The manifest alone — cheap pre-restore validation (geometry
        checks before arrays are even read)."""
        path = os.path.join(self.dir, f"step_{step:010d}", "manifest.json")
        try:
            with open(path) as f:
                return json.load(f)
        except FileNotFoundError:
            raise ValueError(
                f"checkpoint step {step} has no manifest at {path!r}: "
                f"not a checkpoint directory (available steps: "
                f"{self.steps()})") from None
        except json.JSONDecodeError as e:
            raise ValueError(
                f"checkpoint manifest {path!r} is corrupt "
                f"(truncated or overwritten): {e}") from None

    def restore(self, step: int, like: Any,
                shardings: Any = None,
                to_device: bool = True) -> Tuple[Any, Dict]:
        """Restore into the structure of ``like``; device_put per-leaf onto
        ``shardings`` (any mesh — elastic) when given.

        ``to_device=False`` returns plain host ``np.ndarray`` leaves
        untouched — required for trees carrying values jax would silently
        mangle (e.g. int64 content hashes truncate to int32 under default
        x64-disabled jax); the caller owns any device conversion.

        Failure modes are all readable ``ValueError``\\ s naming the
        problem: a truncated/corrupted ``arrays.npz`` (torn copy, bad
        disk), a missing array key, or a shape mismatch between the
        checkpoint and the restore template (which leaf, expected vs
        found) — never an exception from deep inside tree unflattening,
        and never a half-applied restore.
        """
        path = os.path.join(self.dir, f"step_{step:010d}")
        npz = os.path.join(path, "arrays.npz")
        try:
            with np.load(npz) as z:
                arrays = {k: z[k] for k in z.files}
        except FileNotFoundError:
            raise ValueError(
                f"checkpoint step {step} not found under {self.dir!r} "
                f"(available steps: {self.steps()})") from None
        except (zipfile.BadZipFile, EOFError, OSError, ValueError) as e:
            raise ValueError(
                f"checkpoint arrays {npz!r} are corrupt (truncated or "
                f"overwritten — atomic rename means this was damaged "
                f"after the save): {e}") from None
        manifest = self.read_manifest(step)
        state = _unflatten_into(like, arrays)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        elif to_device:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, manifest

    # -- hygiene ----------------------------------------------------------
    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def _gc_tmp(self) -> None:
        for name in os.listdir(self.dir):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

from .manager import CheckpointManager

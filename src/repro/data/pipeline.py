"""Deterministic, shardable synthetic token pipeline.

Every (step, example) cell is a pure function of the seed, so:

* **restart determinism** — resuming from a checkpoint at step N regenerates
  exactly the batches N, N+1, ... (no data-loader state to snapshot);
* **elasticity** — a different DP degree re-slices the same global batch by
  example index, so scaling the mesh up/down mid-run keeps the data order;
* **multi-host** — each host materializes only its addressable shard via
  ``jax.make_array_from_callback``.

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs (so small models have learnable structure for the
train-loss-goes-down tests and the accuracy benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    motif_count: int = 64


def _example_tokens(dc: DataConfig, step: int, idx: np.ndarray) -> np.ndarray:
    """Deterministic [len(idx), seq_len+1] int32 tokens."""
    rngs = [np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, int(i)])) for i in idx]
    out = np.empty((len(idx), dc.seq_len + 1), np.int32)
    motif_rng = np.random.default_rng(np.random.SeedSequence([dc.seed, 7]))
    motifs = motif_rng.integers(0, dc.vocab,
                                (dc.motif_count, dc.motif_len), np.int64)
    for r, rng in enumerate(rngs):
        # zipf-ish unigram mixture
        z = rng.zipf(1.3, dc.seq_len + 1).astype(np.int64)
        toks = (z - 1) % dc.vocab
        # overwrite random spans with repeated motifs (learnable bigrams)
        n_spans = (dc.seq_len + 1) // (dc.motif_len * 4)
        for _ in range(max(n_spans, 1)):
            m = motifs[rng.integers(0, dc.motif_count)]
            pos = rng.integers(0, dc.seq_len + 1 - dc.motif_len)
            toks[pos:pos + dc.motif_len] = m
        out[r] = toks.astype(np.int32)
    return out


def host_batch(dc: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Full global batch on one host (tests / single-process runs)."""
    idx = np.arange(dc.global_batch)
    toks = _example_tokens(dc, step, idx)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
            "mask": np.ones((dc.global_batch, dc.seq_len), np.float32)}


def sharded_batch(dc: DataConfig, step: int, sharding) -> Dict[str, jax.Array]:
    """Global batch materialized shard-locally under ``sharding`` (batch dim
    sharded; seq dim replicated or sharded — the callback honors both)."""
    shape = (dc.global_batch, dc.seq_len)

    def make(fill, dtype):
        def cb(index):
            rows = np.arange(index[0].start or 0,
                             index[0].stop or dc.global_batch)
            toks = _example_tokens(dc, step, rows)
            cols = index[1] if len(index) > 1 else slice(None)
            return fill(toks)[:, cols].astype(dtype)
        return jax.make_array_from_callback(shape, sharding, cb)

    return {
        "tokens": make(lambda t: t[:, :-1], np.int32),
        "labels": make(lambda t: t[:, 1:], np.int32),
        "mask": make(lambda t: np.ones_like(t[:, 1:]), np.float32),
    }


def iterate(dc: DataConfig, start_step: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield host_batch(dc, step)
        step += 1

from .pipeline import DataConfig, host_batch, sharded_batch, iterate

"""Layer 3: the compile manifest — a committed lockfile of what compiles.

For every geometry cell and engine entry point the manifest records

* the **abstract signature** (dtype + shape of every argument leaf) — a
  change here is retrace-shaped: callers built against the old signature
  now trigger a fresh trace per call site;
* a **structural hash** of the traced jaxpr (primitive sequence, avals,
  stable params, nested sub-jaxprs) — the compile fingerprint;
* the **donation set** of the entry's pjit — lost donation silently
  doubles peak pool memory;
* the **transfer count** — host callbacks/transfers inside the step
  (must be zero; the jaxpr audit hard-fails them, the manifest pins the
  count so a rule gap still shows up as drift).

``python -m repro.analysis --update`` regenerates
``src/repro/analysis/jit_manifest.lock`` and prints a human-readable
diff; ``--check`` (the CI gate) fails with a pointed message when the
current tree drifts from the committed lockfile.
"""
from __future__ import annotations

import difflib
import hashlib
import json
from pathlib import Path
from typing import Any, Dict, List, Sequence

import jax

from .jaxpr_audit import (DEFAULT_GEOMETRIES, TRANSFER_PRIMS, Geometry,
                          _sub_jaxprs, build_audit_engine)

LOCKFILE = Path(__file__).resolve().parent / "jit_manifest.lock"

_FORMAT = 1

# param reprs containing any of these are id/address-dependent and would
# make the hash unstable across processes; they are dropped (nested
# jaxprs are hashed by recursion instead)
_UNSTABLE_REPR = ("0x", "<function", "<lambda", "object at", "<jax")


def _signature(args) -> str:
    """Deterministic one-line abstract signature of an args tuple."""
    leaves, treedef = jax.tree_util.tree_flatten(args)
    shapes = ",".join(f"{l.dtype}{list(l.shape)}" for l in leaves)
    return f"{treedef.num_leaves} leaves: {shapes}"


def _hash_lines(jaxpr, out: List[str]) -> None:
    for eqn in jaxpr.eqns:
        # literals carry their value (x*2 vs x*3 must hash apart);
        # variables carry only their aval
        ins = ",".join(f"lit:{v.val!r}" if hasattr(v, "val")
                       else str(v.aval) for v in eqn.invars)
        outs = ",".join(str(v.aval) for v in eqn.outvars)
        params = []
        for k in sorted(eqn.params):
            v = eqn.params[k]
            if _sub_jaxprs(v):
                continue                      # hashed by recursion below
            r = repr(v)
            if any(tok in r for tok in _UNSTABLE_REPR):
                continue
            params.append(f"{k}={r}")
        out.append(f"{eqn.primitive.name}({ins})->({outs})"
                   f"{{{';'.join(params)}}}")
        for k in sorted(eqn.params):
            subs = _sub_jaxprs(eqn.params[k])
            for i, sub in enumerate(subs):
                out.append(f"<{eqn.primitive.name}.{k}[{i}]>")
                _hash_lines(sub, out)
                out.append("</>")


def _structural_hash(closed) -> str:
    lines: List[str] = []
    _hash_lines(closed.jaxpr, lines)
    digest = hashlib.sha256("\n".join(lines).encode()).hexdigest()
    return f"sha256:{digest[:16]}"


def _donated(closed) -> List[int]:
    """Donated argument indices of the entry's top-level pjit."""
    for eqn in closed.jaxpr.eqns:
        if eqn.primitive.name == "pjit":
            don = eqn.params.get("donated_invars", ())
            return [i for i, d in enumerate(don) if d]
    return []


def _transfers(closed) -> int:
    count = 0

    def walk(jaxpr):
        nonlocal count
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in TRANSFER_PRIMS:
                count += 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub)
    walk(closed.jaxpr)
    return count


def fingerprint(closed, args) -> Dict[str, Any]:
    """One lockfile record for a traced entry point."""
    return {
        "signature": _signature(args),
        "hash": _structural_hash(closed),
        "donated": _donated(closed),
        "transfers": _transfers(closed),
    }


def build_manifest(geometries: Sequence[Geometry] = DEFAULT_GEOMETRIES,
                   cfg=None) -> Dict[str, Any]:
    """Trace every geometry cell's entry points and fingerprint them."""
    manifest: Dict[str, Any] = {"_format": _FORMAT}
    for g in geometries:
        eng = build_audit_engine(g, cfg=cfg)
        cell: Dict[str, Any] = {}
        for name, (fn, args) in sorted(eng.entry_points().items()):
            closed = jax.make_jaxpr(fn)(*args)
            cell[name] = fingerprint(closed, args)
        manifest[g.name] = cell
    return manifest


def render_manifest(manifest: Dict[str, Any]) -> str:
    """Human-readable rendering (the --update diff is over this form)."""
    lines = [f"# jit compile manifest (format {manifest.get('_format')})"]
    for geo in sorted(k for k in manifest if not k.startswith("_")):
        lines.append(f"[{geo}]")
        for entry, rec in sorted(manifest[geo].items()):
            lines.append(f"  {entry}:")
            lines.append(f"    signature: {rec['signature']}")
            lines.append(f"    hash:      {rec['hash']}")
            lines.append(f"    donated:   {rec['donated']}")
            lines.append(f"    transfers: {rec['transfers']}")
    return "\n".join(lines) + "\n"


def write_manifest(manifest: Dict[str, Any], path: Path = LOCKFILE) -> str:
    """Write the lockfile; returns a unified diff vs the previous content
    (empty when nothing changed or no lockfile existed)."""
    path = Path(path)
    diff = ""
    if path.is_file():
        old = json.loads(path.read_text())
        diff = "\n".join(difflib.unified_diff(
            render_manifest(old).splitlines(),
            render_manifest(manifest).splitlines(),
            fromfile="jit_manifest.lock (committed)",
            tofile="jit_manifest.lock (current tree)", lineterm=""))
    path.write_text(json.dumps(manifest, indent=2, sort_keys=True) + "\n")
    return diff


def check_manifest(manifest: Dict[str, Any],
                   path: Path = LOCKFILE) -> List[str]:
    """Compare the current tree's manifest against the committed lockfile.

    Returns pointed drift messages (empty = pass).  Wording names the
    class of regression each field guards so a CI failure reads as a
    diagnosis, not a checksum mismatch.
    """
    path = Path(path)
    if not path.is_file():
        return [f"lockfile {path} missing — run "
                "`python -m repro.analysis --update` and commit it"]
    locked = json.loads(path.read_text())
    problems: List[str] = []
    if locked.get("_format") != manifest.get("_format"):
        problems.append("lockfile format version drift — regenerate with "
                        "--update")
    geos = [k for k in manifest if not k.startswith("_")]
    for geo in geos:
        if geo not in locked:
            problems.append(f"{geo}: geometry cell missing from lockfile "
                            "(new geometry? run --update)")
            continue
        for entry, rec in manifest[geo].items():
            old = locked[geo].get(entry)
            if old is None:
                problems.append(
                    f"{geo}/{entry}: new jitted entry point not in "
                    "lockfile — audit it, then run --update")
                continue
            if old["signature"] != rec["signature"]:
                problems.append(
                    f"{geo}/{entry}: retrace-shaped signature change\n"
                    f"    locked:  {old['signature']}\n"
                    f"    current: {rec['signature']}")
            elif old["hash"] != rec["hash"]:
                problems.append(
                    f"{geo}/{entry}: jaxpr structural hash changed "
                    f"({old['hash']} -> {rec['hash']}) — the compiled "
                    "step is not the one the lockfile pinned; review the "
                    "diff, then run --update")
            if rec["transfers"] > old["transfers"]:
                problems.append(
                    f"{geo}/{entry}: NEW host transfer inside the jitted "
                    f"step ({old['transfers']} -> {rec['transfers']})")
            lost = set(old["donated"]) - set(rec["donated"])
            if lost:
                problems.append(
                    f"{geo}/{entry}: donation LOST for args "
                    f"{sorted(lost)} — peak pool memory doubles for "
                    "those buffers")
        for entry in locked[geo]:
            if entry not in manifest[geo]:
                problems.append(
                    f"{geo}/{entry}: entry point vanished from the "
                    "engine registry (lockfile stale? run --update)")
    for geo in locked:
        if not geo.startswith("_") and geo not in geos:
            problems.append(f"{geo}: geometry cell in lockfile but not "
                            "produced by this tree (run --update)")
    return problems

"""CLI for the jitlint static-analysis suite.

``python -m repro.analysis``            — same as ``--check`` (the CI gate)
``python -m repro.analysis --update``   — regenerate jit_manifest.lock,
                                          print a human-readable diff
``python -m repro.analysis --report P`` — also write the dtype-promotion
                                          report (JSON) to P

Exit status: 0 clean, 1 findings/drift, 2 internal error.  All three
layers run off ONE trace pass per geometry cell — the audit walks each
closed jaxpr and the manifest fingerprints the same object.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List

import jax

from .jaxpr_audit import (DEFAULT_GEOMETRIES, AuditFinding,
                          audit_jaxpr, build_audit_engine)
from .lint import lint_tree
from .manifest import (LOCKFILE, _FORMAT, check_manifest, fingerprint,
                       write_manifest)


def _trace_pass(cfg=None):
    """One trace of every geometry cell's entry points, feeding both the
    jaxpr audit and the compile manifest."""
    findings: List[AuditFinding] = []
    report: List[Dict[str, Any]] = []
    manifest: Dict[str, Any] = {"_format": _FORMAT}
    for g in DEFAULT_GEOMETRIES:
        eng = build_audit_engine(g, cfg=cfg)
        cell: Dict[str, Any] = {}
        for name, (fn, args) in sorted(eng.entry_points().items()):
            closed = jax.make_jaxpr(fn)(*args)
            fs, sites = audit_jaxpr(closed, name, g, n_phys=eng.pool.n_phys)
            findings.extend(fs)
            report.extend(sites)
            cell[name] = fingerprint(closed, args)
        manifest[g.name] = cell
    return findings, report, manifest


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jit-stability static analysis (lint + jaxpr audit "
                    "+ compile manifest)")
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--check", action="store_true",
                      help="verify the tree against rules and the "
                           "committed lockfile (default)")
    mode.add_argument("--update", action="store_true",
                      help="regenerate jit_manifest.lock and print the "
                           "diff")
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write the dtype-promotion report (JSON) here")
    ap.add_argument("--lockfile", metavar="PATH", default=str(LOCKFILE),
                    help="lockfile location (default: committed one)")
    args = ap.parse_args(argv)

    failed = False

    # layer 1 — AST lint (cheap; runs first so syntax-level problems
    # surface before any tracing)
    lint_findings = lint_tree()
    for f in lint_findings:
        print(f"LINT  {f.rule}: {f.path}:{f.line}: {f.message}")
    if lint_findings:
        failed = True
    print(f"lint: {len(lint_findings)} finding(s)")

    # layers 2+3 — one trace pass per geometry cell
    audit_findings, dtype_report, manifest = _trace_pass()
    for f in audit_findings:
        loc = f" [{f.file}:{f.line}]" if f.file else ""
        print(f"AUDIT {f.rule}: {f.geometry}/{f.entry}: {f.message}{loc}")
    if audit_findings:
        failed = True
    denied = [s for s in dtype_report if not s["allowed"]]
    print(f"audit: {len(audit_findings)} finding(s), "
          f"{len(dtype_report)} dtype-widening site(s) "
          f"({len(denied)} denied)")

    if args.report:
        with open(args.report, "w") as fh:
            json.dump(dtype_report, fh, indent=2, sort_keys=True)
        print(f"dtype report -> {args.report}")

    if args.update:
        existed = Path(args.lockfile).is_file()
        diff = write_manifest(manifest, path=args.lockfile)
        print(diff if diff else
              "manifest: lockfile unchanged" if existed else
              "manifest: lockfile created")
        print(f"manifest -> {args.lockfile}")
    else:
        drift = check_manifest(manifest, path=args.lockfile)
        for msg in drift:
            print(f"MANIFEST {msg}")
        if drift:
            failed = True
        n = sum(len(v) for k, v in manifest.items()
                if not k.startswith("_"))
        print(f"manifest: {n} entry point(s) across "
              f"{len(manifest) - 1} geometries "
              f"{'DRIFTED' if drift else 'match the lockfile'}")

    if failed:
        print("analysis: FAIL")
        return 1
    print("analysis: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())

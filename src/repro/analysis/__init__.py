"""jitlint: static jit-stability analysis for the serving hot path.

Every performance claim in this repo rests on invariants that used to be
tribal knowledge: zero retraces per pool geometry, no host<->device sync
inside the tick loop, static shapes through every pure transition, no
``jnp.concatenate`` / ``jnp.repeat`` in the per-token path.  This package
machine-checks them in three layers:

* :mod:`~repro.analysis.lint` — AST rules over ``src/repro`` (banned
  host-sync calls, bare ``assert`` in jit-reachable code, banned hot-path
  ops), with an explicit ``# jitlint: disable=<rule>`` pragma for the
  documented exceptions;
* :mod:`~repro.analysis.jaxpr_audit` — traces every registered
  :class:`~repro.serving.engine.ContinuousEngine` entry point under
  abstract inputs for a small geometry matrix (flat/paged x spec on/off)
  and walks the closed jaxprs: zero host-callback/transfer primitives, no
  dynamic shapes, a dtype-promotion report (silent bf16->f32 upcasts), and
  bounds discipline on block-table gathers against the arena;
* :mod:`~repro.analysis.manifest` — a committed lockfile
  (``jit_manifest.lock``) of (entry point, abstract signature, jaxpr
  structural hash, donation set, transfer count) per geometry.  ``--check``
  fails CI when a diff introduces a retrace-shaped signature change, a new
  transfer, or lost donation; ``--update`` regenerates it.

CLI: ``python -m repro.analysis --check`` (CI gate) / ``--update``.
"""
from .lint import Finding, lint_file, lint_tree, RULES          # noqa: F401
from .jaxpr_audit import (AuditFinding, audit_jaxpr,            # noqa: F401
                          collect_entries, run_audit)
from .manifest import (build_manifest, check_manifest,          # noqa: F401
                       fingerprint, render_manifest, write_manifest,
                       LOCKFILE)

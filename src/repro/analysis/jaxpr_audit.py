"""Layer 2: trace-time audit of every registered engine entry point.

For a small geometry matrix (flat/paged pool x speculation on/off) this
module builds a :class:`~repro.serving.engine.ContinuousEngine` over
**abstract** parameters (``jax.eval_shape`` of the init — no weights ever
materialize), pulls its registered jitted transitions from
:meth:`~repro.serving.engine.ContinuousEngine.entry_points` (the same
registry :meth:`trace_counts` reports on), traces each under its
``ShapeDtypeStruct`` example args, and walks the closed jaxprs:

``transfer-prim``
    No host-callback or transfer primitive anywhere in a transition
    (``pure_callback``, ``io_callback``, ``debug_callback``,
    ``device_put``, infeed/outfeed).  A transition that phones home per
    tick is a silent serving-throughput bug.

``dynamic-shape``
    Every intermediate aval must have a fully static integer shape — a
    dynamically-shaped op would force per-length retraces, which is
    exactly what the pool design exists to prevent.

``dtype-promote``
    Report of every ``convert_element_type`` that silently widens
    ``bfloat16 -> float32``.  Deliberate f32 accumulation (the kernels'
    ``preferred_element_type`` discipline, rms-norm/rope/softmax math) is
    allowlisted per file below; anything else must carry a
    ``# jitlint: disable=dtype-promote`` pragma at the flagged source
    line or it is a finding.  Every site — allowed or not — lands in the
    JSON report for the CI artifact.

``table-gather-bounds``
    Any gather/scatter whose operand leads with the paged arena axis
    (``n_phys`` rows — the audit geometry picks a prime arena size so the
    dimension is unambiguous) must stay in ``CLIP`` or ``FILL_OR_DROP``
    mode.  ``PROMISE_IN_BOUNDS`` on a block-table access would turn a
    corrupt table entry into out-of-bounds memory traffic instead of the
    pool's documented clip/sentinel-drop discipline.
"""
from __future__ import annotations

import dataclasses
import functools
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src import source_info_util

from .lint import _PRAGMA_RE

AUDIT_RULES: Dict[str, str] = {
    "transfer-prim": "host callback/transfer primitive inside a jitted "
                     "transition",
    "dynamic-shape": "non-static shape in a jitted transition",
    "dtype-promote": "silent bf16->f32 upcast without pragma/allowlist",
    "table-gather-bounds": "arena gather/scatter not in clip/drop mode",
}

# primitives that move data to/from the host or another device placement
TRANSFER_PRIMS = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "device_put", "infeed", "outfeed",
    "host_local_array_to_global_array", "global_array_to_host_local_array",
    "check",
}

# files where bf16 -> f32 widening is the documented accumulation idiom:
# every kernel accumulates at f32 (``preferred_element_type`` discipline),
# and the normalization / rotary / softmax / router math in the model
# stack runs at f32 by design.  serving/sampling.py is deliberately NOT
# here — its upcast sites carry in-source pragmas (the bf16 tp>1 greedy
# drift caveat in BENCH_mesh.json is why they must stay visible).
DTYPE_ALLOW_FILES: Sequence[str] = (
    "kernels/",
    "core/",
    "models/layers.py",
    "models/flash.py",
    "models/attention.py",
    "models/moe.py",
    "models/ssm.py",
    "models/lm.py",
)


@dataclasses.dataclass(frozen=True)
class Geometry:
    """One cell of the audit matrix."""
    name: str
    paged: bool
    spec: bool


DEFAULT_GEOMETRIES: Tuple[Geometry, ...] = (
    Geometry("flat", paged=False, spec=False),
    Geometry("paged", paged=True, spec=False),
    Geometry("flat-spec", paged=False, spec=True),
    Geometry("paged-spec", paged=True, spec=True),
)

# distinctive prime arena size: no other dimension in the reduced config
# collides with it, so "operand leads with n_phys" identifies arena ops
AUDIT_PHYS_BLOCKS = 29


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    rule: str
    entry: str         # entry-point name (trace_counts key)
    geometry: str      # Geometry.name
    message: str
    file: str = ""     # repo-relative source file, when resolvable
    line: int = 0

    def __str__(self) -> str:
        where = f" ({self.file}:{self.line})" if self.file else ""
        return (f"{self.geometry}/{self.entry}: [{self.rule}] "
                f"{self.message}{where}")


def _audit_cfg():
    """The tiny serving config every geometry traces under: reduced
    qwen3 stack at default bf16 compute (so dtype widening is visible),
    sparse KV, one-block tail."""
    import dataclasses as dc

    from repro.configs import get_config
    cfg = get_config("qwen3-0.6b").reduced()
    return dc.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                      kv_tail=16)


def build_audit_engine(geometry: Geometry, cfg=None):
    """An engine over abstract params for one geometry cell.

    ``jax.eval_shape`` of the initializer means no parameter memory is
    ever allocated; the pool state is real but tiny (reduced config).
    Built with ``overlap=True``: the overlapped engine's entry-point set
    is a strict superset of the serial one (every serial transition plus
    the chained ``decode_chain`` dispatch), so every geometry cell audits
    the pipelined path too — the in-flight tick cannot smuggle a host
    transfer past the matrix."""
    from repro.models import lm
    from repro.serving.engine import ContinuousEngine
    from repro.serving.spec import SpecConfig
    cfg = cfg if cfg is not None else _audit_cfg()
    params = jax.eval_shape(
        functools.partial(lm.init_params, cfg, jax.random.PRNGKey(0)))
    return ContinuousEngine(
        params, cfg, slots=4, max_tokens=64, bs=8, prefill_chunk=16,
        paged=geometry.paged,
        phys_blocks=AUDIT_PHYS_BLOCKS if geometry.paged else 0,
        spec=SpecConfig(k=2) if geometry.spec else None,
        checkify=False, overlap=True)


def collect_entries(geometry: Geometry, cfg=None
                    ) -> Dict[str, Tuple[Any, tuple]]:
    """``{entry name: (jitted, abstract args)}`` for one geometry cell —
    a thin veneer over :meth:`ContinuousEngine.entry_points` so the audit
    and the manifest share one discovery path."""
    return build_audit_engine(geometry, cfg=cfg).entry_points()


# --------------------------------------------------------------------------
# jaxpr traversal
# --------------------------------------------------------------------------

def _walk_eqns(jaxpr, visit) -> None:
    """Depth-first over every eqn of ``jaxpr`` including nested (pjit /
    scan / while / cond) sub-jaxprs."""
    for eqn in jaxpr.eqns:
        visit(eqn)
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                _walk_eqns(sub, visit)


def _sub_jaxprs(v) -> List[Any]:
    core = jax.core
    if isinstance(v, core.ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, core.Jaxpr):
        return [v]
    if isinstance(v, (tuple, list)):
        out: List[Any] = []
        for x in v:
            out.extend(_sub_jaxprs(x))
        return out
    return []


def _frame(eqn) -> Tuple[str, int]:
    """(repo-relative file, line) of the user code that emitted ``eqn``,
    or ("", 0) when no user frame survives."""
    fr = source_info_util.user_frame(eqn.source_info)
    if fr is None:
        return "", 0
    rel = _relativize(fr.file_name)
    return rel, int(fr.start_line or 0)


def _package_root() -> Path:
    return Path(__file__).resolve().parent.parent


def _relativize(file_name: str) -> str:
    try:
        return str(Path(file_name).resolve()
                   .relative_to(_package_root().resolve()))
    except ValueError:
        return file_name


@functools.lru_cache(maxsize=None)
def _pragma_lines(rel: str) -> frozenset:
    """Lines of ``rel`` (repo-relative) carrying a dtype-promote pragma."""
    path = _package_root() / rel
    if not path.is_file():
        return frozenset()
    out = set()
    for i, text in enumerate(path.read_text().splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m and {"dtype-promote", "all"} & {
                r.strip() for r in m.group(1).split(",")}:
            out.add(i)
    return frozenset(out)


def _dtype_allowed(rel: str, line: int) -> Optional[str]:
    """Why a bf16->f32 site is acceptable, or None if it is a finding."""
    if any(rel.startswith(p) for p in DTYPE_ALLOW_FILES):
        return "file-allowlist"
    pragmas = _pragma_lines(rel)
    if line in pragmas or (line - 1) in pragmas or (line + 1) in pragmas:
        return "pragma"
    return None


def audit_jaxpr(closed, entry: str, geometry: Geometry,
                n_phys: int = 0) -> Tuple[List[AuditFinding],
                                          List[Dict[str, Any]]]:
    """Walk one traced entry point.  Returns ``(findings, dtype_sites)``
    where ``dtype_sites`` records every bf16->f32 widening (allowed or
    flagged) for the promotion report."""
    findings: List[AuditFinding] = []
    dtype_sites: List[Dict[str, Any]] = []
    seen_dtype = set()
    promise = jax.lax.GatherScatterMode.PROMISE_IN_BOUNDS

    def visit(eqn):
        name = eqn.primitive.name
        if name in TRANSFER_PRIMS:
            rel, line = _frame(eqn)
            findings.append(AuditFinding(
                "transfer-prim", entry, geometry.name,
                f"primitive `{name}` crosses the host/device boundary "
                "inside a jitted transition", rel, line))
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", ())
            if not all(isinstance(d, int) for d in shape):
                rel, line = _frame(eqn)
                findings.append(AuditFinding(
                    "dynamic-shape", entry, geometry.name,
                    f"`{name}` carries a non-static shape {shape}",
                    rel, line))
                break
        if name == "convert_element_type":
            src = eqn.invars[0].aval
            dst = eqn.params.get("new_dtype")
            if (getattr(src, "dtype", None) == jnp.bfloat16
                    and dst == jnp.float32):
                rel, line = _frame(eqn)
                key = (rel, line)
                if key in seen_dtype:
                    return
                seen_dtype.add(key)
                reason = _dtype_allowed(rel, line)
                dtype_sites.append({
                    "geometry": geometry.name, "entry": entry,
                    "file": rel, "line": line,
                    "from": "bfloat16", "to": "float32",
                    "allowed": reason is not None, "reason": reason})
                if reason is None:
                    findings.append(AuditFinding(
                        "dtype-promote", entry, geometry.name,
                        "silent bf16->f32 upcast (allowlist the file or "
                        "add `# jitlint: disable=dtype-promote`)",
                        rel, line))
        if name in ("gather", "scatter", "scatter-add", "scatter_add",
                    "dynamic_update_slice", "dynamic_slice") and n_phys:
            operand = eqn.invars[0].aval
            shape = getattr(operand, "shape", ())
            mode = eqn.params.get("mode")
            if (shape and shape[0] == n_phys and mode is not None
                    and mode == promise):
                rel, line = _frame(eqn)
                findings.append(AuditFinding(
                    "table-gather-bounds", entry, geometry.name,
                    f"`{name}` over the [{n_phys}, ...] arena uses "
                    "PROMISE_IN_BOUNDS; block-table access must stay in "
                    "CLIP or FILL_OR_DROP mode", rel, line))

    _walk_eqns(closed.jaxpr, visit)
    return findings, dtype_sites


def run_audit(geometries: Sequence[Geometry] = DEFAULT_GEOMETRIES,
              cfg=None) -> Tuple[List[AuditFinding], List[Dict[str, Any]]]:
    """Trace + audit every entry point of every geometry cell.

    Returns ``(findings, dtype_report)``; an empty findings list is the
    CI bar.  The dtype report lists every bf16->f32 site with its
    allow/deny verdict — uploaded as a CI artifact so widening changes
    are reviewable even when they are allowed.
    """
    findings: List[AuditFinding] = []
    report: List[Dict[str, Any]] = []
    for g in geometries:
        eng = build_audit_engine(g, cfg=cfg)
        n_phys = eng.pool.n_phys
        for name, (fn, args) in sorted(eng.entry_points().items()):
            closed = jax.make_jaxpr(fn)(*args)
            fs, sites = audit_jaxpr(closed, name, g, n_phys=n_phys)
            findings.extend(fs)
            report.extend(sites)
    return findings, report

"""Layer 1: repo-specific AST lint over ``src/repro``.

Rules (ids are what the pragma disables):

``host-sync``
    Calls that force a host<->device round trip — ``.item()``,
    ``jax.device_get``, ``np.asarray`` / ``np.array``, and ``int()`` /
    ``float()`` applied to an array-ish expression (an attribute or
    subscript — ``int(cache.tail_len)`` syncs; ``int(len(xs))`` does not)
    — inside **jit-reachable** modules.  The engine's host tick loop
    (``serving/engine.py``, ``serving/scheduler.py``) is the designated
    sync boundary and is out of scope by construction.

``block-until-ready``
    ``.block_until_ready()`` anywhere in ``src/repro`` outside a
    **designated sync point**.  Designated syncs are registered in
    :data:`DESIGNATED_SYNCS` — a ``{repo-relative path: (function
    names,)}`` registry — rather than hardcoded: the overlapped engine's
    one-tick-delayed commit (``ContinuousEngine._sync_inflight``) is the
    canonical entry.  A ``block_until_ready`` inside a registered
    (file, enclosing function) pair is allowed; anywhere else it is
    flagged, pragma or not having to be spelled per site.

``bare-assert``
    ``assert`` statements in jit-reachable code.  Shape/geometry
    contracts must be build-time ``ValueError`` (they fire identically at
    trace time and survive ``python -O``); value-dependent invariants
    belong in the opt-in checkify mode.

``hot-path-op``
    ``jnp.concatenate`` / ``jnp.repeat`` / ``jnp.sort`` / ``jnp.argsort``
    in the hot-path packages (``kernels/``, ``models/``, ``serving/``).
    The per-token decode path eliminated these in PR 3; anything that
    reintroduces one must carry the pragma with a documented reason
    (e.g. the exact-sort sampling fallback, prefill/legacy-only paths).

Pragma syntax: ``# jitlint: disable=rule[,rule...]`` (or ``all``) on the
flagged line, any line the flagged expression spans, or the line
immediately above it.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

RULES: Dict[str, str] = {
    "host-sync": "host<->device sync call in a jit-reachable module",
    "block-until-ready": ".block_until_ready outside the designated "
                         "sync point",
    "bare-assert": "bare assert in jit-reachable code (use ValueError "
                   "or checkify)",
    "hot-path-op": "banned hot-path op (concatenate/repeat/sort) in "
                   "kernels/, models/, serving/",
}

# Modules whose code is traced inside jax.jit (directly or via the model
# forwards).  Host-side orchestration (serving/engine.py, scheduler.py,
# spec.py, launch/, data/, checkpoint/, benchmarks) is deliberately out of
# scope for host-sync/bare-assert: syncing at the tick boundary is its job.
JIT_MODULES: Sequence[str] = (
    "core/",
    "kernels/",
    "models/",
    "optim/",
    "train/",
    "serving/cache_pool.py",
    "serving/sampling.py",
    # host-only, but its injection sites run inside the engine tick loop:
    # it must stay jax-free, assert-free, and sync-free, so hold it to the
    # same bar as the traced modules
    "serving/faults.py",
    # same reasoning: the observability layer is fed from the tick loop
    # and must never grow a device sync of its own (it is pure stdlib —
    # no numpy, no jax — and the host-sync rules keep it that way)
    "obs/",
    "distributed/cp_attention.py",
)

# Packages that contain the serving hot path: per-token decode must never
# re-grow ops PR 3 eliminated.
HOT_PATH_MODULES: Sequence[str] = ("kernels/", "models/", "serving/")

# Designated sync registry: the ONLY (file, enclosing function) pairs where
# a `jax.block_until_ready` / `.block_until_ready()` call is legitimate.
# The overlapped engine pipelines ticks and funnels every commit through
# exactly one delayed sync; growing a second sync site means either
# registering it here (a reviewed, documented decision) or failing lint.
DESIGNATED_SYNCS: Dict[str, Sequence[str]] = {
    "serving/engine.py": ("_sync_inflight",),
}

_PRAGMA_RE = re.compile(r"#\s*jitlint:\s*disable=([\w,\- ]+)")

_HOT_OPS = {"concatenate", "repeat", "sort", "argsort"}
_NP_SYNC = {"asarray", "array"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str          # repo-relative (src/repro/...)
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _pragmas(source_lines: Sequence[str]) -> Dict[int, Set[str]]:
    """1-based line -> set of disabled rule ids."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(source_lines, start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute/name chain as a string, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, jit_reachable: bool, hot_path: bool,
                 designated: Sequence[str] = ()):
        self.path = path
        self.jit_reachable = jit_reachable
        self.hot_path = hot_path
        self.designated = set(designated)
        self._func_stack: List[str] = []
        self.raw: List[Finding] = []

    def _add(self, rule: str, node: ast.AST, msg: str) -> None:
        self.raw.append(Finding(rule, self.path, node.lineno, msg))

    def _visit_func(self, node) -> None:
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Assert(self, node: ast.Assert) -> None:
        if self.jit_reachable:
            self._add("bare-assert", node,
                      "bare `assert` in jit-reachable code; raise "
                      "ValueError at build time or use the checkify mode")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        dotted = _dotted(fn)
        # .item() / .block_until_ready() on anything
        if isinstance(fn, ast.Attribute):
            if fn.attr == "item" and not node.args and not node.keywords:
                if self.jit_reachable:
                    self._add("host-sync", node,
                              "`.item()` forces a device sync")
            if (fn.attr == "block_until_ready"
                    and not (self._func_stack
                             and self._func_stack[-1] in self.designated)):
                self._add("block-until-ready", node,
                          "`.block_until_ready()` outside a designated "
                          "sync point (register the enclosing function "
                          "in analysis.lint.DESIGNATED_SYNCS)")
        if dotted is not None:
            tail = dotted.split(".", 1)
            if dotted in ("jax.device_get",) and self.jit_reachable:
                self._add("host-sync", node,
                          "`jax.device_get` forces a device sync")
            if (self.jit_reachable and len(tail) == 2
                    and tail[0] in ("np", "numpy")
                    and tail[1] in _NP_SYNC):
                self._add("host-sync", node,
                          f"`{dotted}` on a traced value forces a device "
                          "sync (use jnp, or move to the host boundary)")
            if (self.hot_path and len(tail) == 2 and tail[0] == "jnp"
                    and tail[1] in _HOT_OPS):
                self._add("hot-path-op", node,
                          f"`{dotted}` is banned on the serving hot path "
                          "(eliminated in PR 3)")
        if (self.jit_reachable and isinstance(fn, ast.Name)
                and fn.id in ("int", "float") and len(node.args) == 1
                and isinstance(node.args[0], (ast.Attribute, ast.Subscript))
                and not _is_shape_access(node.args[0])):
            self._add("host-sync", node,
                      f"`{fn.id}()` on an array expression forces a "
                      "device sync")
        self.generic_visit(node)


def _is_shape_access(node: ast.AST) -> bool:
    """``x.shape`` / ``x.shape[i]`` / ``x.ndim`` — Python ints already on
    the host; ``int()`` on them is not a sync."""
    if isinstance(node, ast.Subscript):
        node = node.value
    return isinstance(node, ast.Attribute) and node.attr in ("shape", "ndim")


def _span_lines(tree: ast.AST, finding: Finding) -> range:
    """Lines a finding's pragma may live on: the node's span plus the
    line above.  (We re-walk cheaply: pragma resolution only needs the
    flagged line; multi-line calls keep their pragma on the first line.)
    """
    return range(max(finding.line - 1, 1), finding.line + 1)


def lint_source(source: str, path: str, jit_reachable: bool,
                hot_path: bool) -> List[Finding]:
    """Lint one file's source text with explicit scope flags (the fixture
    corpus forces both True; :func:`lint_tree` derives them from the
    path).  ``path`` also keys the designated-sync registry, so only the
    registered files' registered functions may hold a
    ``block_until_ready``."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:                      # pragma: no cover
        return [Finding("parse-error", path, e.lineno or 0, str(e))]
    v = _Visitor(path, jit_reachable, hot_path,
                 designated=DESIGNATED_SYNCS.get(path, ()))
    v.visit(tree)
    lines = source.splitlines()
    pragmas = _pragmas(lines)
    out = []
    for f in v.raw:
        disabled: Set[str] = set()
        # the flagged line, every line of a multi-line statement ending at
        # the flagged line, and the line immediately above
        for ln in (f.line - 1, f.line):
            disabled |= pragmas.get(ln, set())
        # pragma anywhere on the continuation lines of the same statement
        for ln, rules in pragmas.items():
            if f.line < ln <= f.line + 4 and _continues(lines, f.line, ln):
                disabled |= rules
        if f.rule in disabled or "all" in disabled:
            continue
        out.append(f)
    return out


def _continues(lines: Sequence[str], start: int, ln: int) -> bool:
    """True if line ``ln`` (1-based) is plausibly a continuation of the
    statement starting at ``start`` (open parens carry over)."""
    depth = 0
    for i in range(start - 1, min(ln, len(lines))):
        text = lines[i].split("#", 1)[0]
        depth += (text.count("(") + text.count("[")
                  - text.count(")") - text.count("]"))
        if depth <= 0 and i >= start - 1 and i + 1 < ln:
            return False
    return True


def _scope(rel: str) -> Dict[str, bool]:
    return {
        "jit_reachable": any(rel.startswith(m) for m in JIT_MODULES),
        "hot_path": any(rel.startswith(m) for m in HOT_PATH_MODULES),
    }


def lint_file(path: Path, root: Optional[Path] = None,
              jit_reachable: Optional[bool] = None,
              hot_path: Optional[bool] = None) -> List[Finding]:
    """Lint one file.  Scope flags default from the path relative to
    ``root`` (the ``src/repro`` package dir); pass them explicitly to
    force (the fixture-corpus tests do)."""
    path = Path(path)
    root = Path(root) if root is not None else _default_root()
    try:
        rel = str(path.resolve().relative_to(root.resolve()))
    except ValueError:
        rel = path.name
    sc = _scope(rel)
    if jit_reachable is not None:
        sc["jit_reachable"] = jit_reachable
    if hot_path is not None:
        sc["hot_path"] = hot_path
    return lint_source(path.read_text(), rel, **sc)


def _default_root() -> Path:
    return Path(__file__).resolve().parent.parent


def lint_tree(root: Optional[Path] = None) -> List[Finding]:
    """Lint every ``.py`` file under the ``repro`` package."""
    root = Path(root) if root is not None else _default_root()
    findings: List[Finding] = []
    for p in sorted(root.rglob("*.py")):
        findings.extend(lint_file(p, root=root))
    return findings

"""Dense->sparse parameter-tree conversion.

The run-time face of the paper's headline usability feature: "a set of
open-source customized sparse kernels that can speed up any PyTorch model by
automatically replacing all linear layers with our custom sparse
implementation."  Here: walk any params pytree, prune + pack every leaf the
predicate selects, and return a tree the same step functions consume
(``repro.kernels.ops.linear`` dispatches on leaf type).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .sparse_format import BlockSparseWeight, pack, DEFAULT_BLOCK
from .pruning import make_mask
from .quant import quantize_weight_int8

# Param-name suffixes that are linear-layer weights (matmul RHS, [K, N]).
LINEAR_KEYS = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_down", "w_in",
               "w_out", "w_r", "w_k", "w_v", "w_g", "w_o", "w_ck", "w_cv",
               "w_cr", "w_proj", "w1", "w2", "w3", "lm_head")
EXCLUDE_KEYS = ("embed", "norm", "scale", "bias", "router", "pos",
                "a_log", "dt", "mu_", "decay", "bonus")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def default_predicate(path: str, leaf: Any) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if any(k in path for k in EXCLUDE_KEYS):
        return False
    name = path.rsplit("/", 1)[-1]
    return any(name == k or name.endswith("/" + k) for k in LINEAR_KEYS)


def _pack_leaf(w: jax.Array, sparsity: float, policy: str,
               block: Tuple[int, int], mode: str,
               pad_to_blocks: Tuple[int, int],
               capacity: Optional[int]) -> BlockSparseWeight:
    if w.ndim == 3:
        # stacked experts [E, K, N]: fold E into K; blocks never straddle
        # experts as long as K % bk == 0 (asserted).
        e, k, n = w.shape
        if k % block[0] != 0:
            raise ValueError(
                f"expert in-dim {k} must be a multiple of bk={block[0]}")
        w = w.reshape(e * k, n)
    mask = make_mask(w, sparsity, policy, block)
    if mode == "int8":
        q, scale = quantize_weight_int8(jnp.where(mask, w, 0))
        return pack(q, mask, block, capacity=capacity,
                    pad_to_blocks=pad_to_blocks, scale=scale)
    return pack(w.astype(jnp.bfloat16) if mode == "bf16" else w, mask, block,
                capacity=capacity, pad_to_blocks=pad_to_blocks)


def convert_to_sparse(params: Any,
                      sparsity: float = 0.5,
                      policy: str = "balanced",
                      block: Tuple[int, int] = DEFAULT_BLOCK,
                      mode: str = "bf16",
                      pad_to_blocks: Tuple[int, int] = (1, 1),
                      capacity: Optional[int] = None,
                      predicate: Callable[[str, Any], bool] = default_predicate
                      ) -> Any:
    """Replace every selected dense weight with a BlockSparseWeight.

    mode: "bf16" | "keep" | "int8" (int8 adds per-channel scales).
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        p = _path_str(path)
        if predicate(p, leaf):
            out.append(_pack_leaf(leaf, sparsity, policy, block, mode,
                                  pad_to_blocks, capacity))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def sparsity_report(params: Any) -> Dict[str, Dict[str, float]]:
    """Per-leaf compression statistics for converted trees."""
    report: Dict[str, Dict[str, float]] = {}
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, BlockSparseWeight))[0]
    for path, leaf in flat:
        if isinstance(leaf, BlockSparseWeight):
            report[_path_str(path)] = {
                "dense_bytes": leaf.nbytes_dense(),
                "compressed_bytes": leaf.nbytes_compressed(),
                "ratio": leaf.compression_ratio(),
                "capacity": leaf.capacity,
            }
    return report

"""Blocked bitmap+packed-values sparse format (SparAMX -> TPU adaptation).

The paper stores weights as ``weight_metadata`` (a bitmap, 1 bit/weight) plus
``weight_values`` (packed non-zeros), and decompresses 16x32 AMX tiles with
``vpexpandw`` right before a dense AMX matmul ("load-as-sparse,
compute-as-dense").

On TPU the analogue is a *blocked* layout so Pallas BlockSpecs stay static:

* the dense weight ``W[K, N]`` is cut into ``(bk, bn)`` blocks,
* each block's mask is packed into uint32 bitmap words (bit order: row-major
  over the flattened ``bk*bn`` block, 32 bits per word),
* each block's non-zero values are packed — in the same row-major order —
  into a fixed per-tensor **capacity** ``C`` (max block nnz, rounded up to a
  lane multiple).  The fixed capacity replaces the paper's per-thread
  ``weight_value_index``: every grid cell's value slice is statically
  addressable.

Decompression (kernel + reference) mirrors the paper's Algorithm 2:
popcount/prefix-sum to turn the bitmap into gather indices, then an expand —
``vpexpandw`` on AMX, a vector gather on the TPU VPU.

All functions are pure jnp and traceable, so ``jax.eval_shape`` gives
abstract packed layouts for the dry-run without allocating.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK = (256, 128)
LANE = 128  # value capacity is rounded up to this


def _ceil_to(x: int, m: int) -> int:
    return int(-(-x // m) * m)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockSparseWeight:
    """A ``[K, N]`` weight stored as bitmap + packed values.

    Attributes:
      bitmap:  uint32 ``[Kb, Nb, bk*bn // 32]`` — per-block metadata words.
      values:  ``[Kb, Nb, C]`` packed non-zeros (row-major within block).
      scale:   optional fp32 ``[N_pad]`` per-output-channel scale (int8 mode).
      shape:   logical (un-padded) ``(K, N)``.
      block:   ``(bk, bn)`` block shape.
      packed4: values hold two int4 nibbles per uint8 byte (paper §8's INT4
               extension — dequantized to int8 before the MXU pass).
    """

    bitmap: jax.Array
    values: jax.Array
    scale: Optional[jax.Array]
    shape: Tuple[int, int]
    block: Tuple[int, int]
    packed4: bool = False

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        children = (self.bitmap, self.values, self.scale)
        aux = (self.shape, self.block, self.packed4)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        bitmap, values, scale = children
        return cls(bitmap, values, scale, *aux)

    # -- conveniences --------------------------------------------------------
    @property
    def capacity(self) -> int:
        c = self.values.shape[-1]
        return c * 2 if self.packed4 else c

    @property
    def padded_shape(self) -> Tuple[int, int]:
        bk, bn = self.block
        return self.bitmap.shape[-3] * bk, self.bitmap.shape[-2] * bn

    @property
    def lead_shape(self) -> Tuple[int, ...]:
        return tuple(self.bitmap.shape[:-3])

    @property
    def dtype(self):
        return self.values.dtype

    def nbytes_compressed(self) -> int:
        n = self.bitmap.size * 4 + self.values.size * self.values.dtype.itemsize
        if self.scale is not None:
            n += self.scale.size * self.scale.dtype.itemsize
        return n

    def nbytes_dense(self) -> int:
        k, n = self.shape
        return k * n * self.values.dtype.itemsize

    def compression_ratio(self) -> float:
        """compressed bytes / dense bytes (lower is better)."""
        return self.nbytes_compressed() / self.nbytes_dense()


# ---------------------------------------------------------------------------
# int4 nibble packing (paper §8: "extending support to INT4 is feasible by
# dequantizing INT4 values into INT8 before computation")
# ---------------------------------------------------------------------------

def pack_nibbles(v: jax.Array) -> jax.Array:
    """int8 ``[..., C]`` in [-8, 7] -> uint8 ``[..., C//2]`` (lo | hi<<4)."""
    if v.shape[-1] % 2 != 0:
        raise ValueError(f"nibble packing needs an even channel dim, got {v.shape[-1]}")
    u = v.astype(jnp.uint8) & jnp.uint8(0xF)
    lo, hi = u[..., 0::2], u[..., 1::2]
    return lo | (hi << jnp.uint8(4))


def unpack_nibbles(b: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_nibbles` -> int8 ``[..., 2C]`` (sign-extended).

    This is the dequant-to-int8 step the paper prescribes; in the Pallas
    kernel it runs in VMEM right before the bitmap expansion.
    """
    lo = (b & jnp.uint8(0xF)).astype(jnp.int8)
    hi = (b >> jnp.uint8(4)).astype(jnp.int8)
    sext = lambda x: ((x ^ jnp.int8(8)) - jnp.int8(8)).astype(jnp.int8)
    out = jnp.stack([sext(lo), sext(hi)], axis=-1)
    return out.reshape(*b.shape[:-1], b.shape[-1] * 2)


# ---------------------------------------------------------------------------
# bit packing helpers
# ---------------------------------------------------------------------------

def pack_bits(mask: jax.Array) -> jax.Array:
    """Pack a ``[..., L]`` 0/1 mask into ``[..., L//32]`` uint32 words.

    Bit ``b`` of word ``j`` corresponds to flat position ``32*j + b``.
    """
    l = mask.shape[-1]
    if l % 32 != 0:
        raise ValueError(f"mask length {l} not a multiple of 32")
    m = mask.astype(jnp.uint32).reshape(*mask.shape[:-1], l // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(m << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, length: int) -> jax.Array:
    """Inverse of :func:`pack_bits` -> int32 0/1 mask ``[..., length]``."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    out = bits.reshape(*words.shape[:-1], words.shape[-1] * 32)
    return out[..., :length].astype(jnp.int32)


def exclusive_cumsum(x: jax.Array, axis: int = -1) -> jax.Array:
    """The paper's Alg. 1 parallel prefix sum, exclusive variant."""
    inc = jnp.cumsum(x, axis=axis)
    return inc - x


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

def _to_blocks(w: jax.Array, block: Tuple[int, int],
               pad_to_blocks: Tuple[int, int] = (1, 1)) -> jax.Array:
    """``[K, N]`` -> ``[Kb, Nb, bk*bn]`` (row-major within block), padding K/N."""
    bk, bn = block
    k, n = w.shape
    kp = _ceil_to(_ceil_to(k, bk) // bk, pad_to_blocks[0]) * bk
    np_ = _ceil_to(_ceil_to(n, bn) // bn, pad_to_blocks[1]) * bn
    w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    kb, nb = kp // bk, np_ // bn
    w = w.reshape(kb, bk, nb, bn).transpose(0, 2, 1, 3)  # [Kb, Nb, bk, bn]
    return w.reshape(kb, nb, bk * bn)


def _from_blocks(blocks: jax.Array, block: Tuple[int, int],
                 shape: Tuple[int, int]) -> jax.Array:
    """``[..., Kb, Nb, bk*bn]`` -> ``[..., K, N]`` (strips padding)."""
    bk, bn = block
    *lead, kb, nb, _ = blocks.shape
    w = blocks.reshape(*lead, kb, nb, bk, bn)
    w = jnp.moveaxis(w, -2, -3)                       # [..., Kb, bk, Nb, bn]
    w = w.reshape(*lead, kb * bk, nb * bn)
    return w[..., : shape[0], : shape[1]]


def _cap_mask(wb: jax.Array, mb: jax.Array, cap: int) -> jax.Array:
    """Drop the smallest-|.| overflow entries of any block whose nnz exceeds
    ``cap`` — from the *mask* (and therefore the bitmap), so bitmap and packed
    values never disagree.  Blocks with nnz <= cap come back unchanged."""
    score = jnp.where(mb, jnp.abs(wb.astype(jnp.float32)), -jnp.inf)
    idx = jax.lax.top_k(score, cap)[1]                    # [..., cap]
    l = mb.shape[-1]
    flat_i = idx.reshape(-1, cap)
    sel = jax.vmap(lambda i: jnp.zeros((l,), jnp.bool_).at[i].set(True))(
        flat_i)
    return jnp.logical_and(mb, sel.reshape(mb.shape))


def pack_blocks(wb: jax.Array, mb: jax.Array, cap: int,
                cap_may_truncate: bool = True
                ) -> Tuple[jax.Array, jax.Array]:
    """Pack pre-blocked values ``wb [..., L]`` under mask ``mb`` at a *static*
    per-block capacity ``cap`` -> (bitmap uint32 ``[..., L//32]``, values
    ``[..., cap]``).

    This is the jit-stable packing primitive the serving cache pool builds
    on: ``cap`` never depends on the data, and when a block holds more than
    ``cap`` kept entries the overflow is dropped consistently from bitmap
    *and* values (magnitude order), so ``unpack`` always round-trips what the
    bitmap claims.

    ``cap_may_truncate=False`` skips the overflow re-rank when the caller
    can prove ``cap >= max block nnz`` (e.g. it derived ``cap`` from the
    data) — the top-k scan is pure waste there.
    """
    l = wb.shape[-1]
    cap = min(int(cap), l)
    if cap < l and cap_may_truncate:
        mb = _cap_mask(wb, mb, cap)
    mb_i = mb.astype(jnp.int32)
    nnz = mb_i.sum(-1)
    # Stable partition: indices of kept entries first, in row-major order.
    order = jnp.argsort(jnp.logical_not(mb), axis=-1, stable=True)
    vals = jnp.take_along_axis(wb * mb.astype(wb.dtype),
                               order[..., :cap], axis=-1)
    valid = jnp.arange(cap) < nnz[..., None]
    vals = jnp.where(valid, vals, 0).astype(wb.dtype)
    return pack_bits(mb_i), vals


def pack(w: jax.Array,
         mask: jax.Array,
         block: Tuple[int, int] = DEFAULT_BLOCK,
         capacity: Optional[int] = None,
         pad_to_blocks: Tuple[int, int] = (1, 1),
         scale: Optional[jax.Array] = None) -> BlockSparseWeight:
    """Pack ``w`` (zeroed outside ``mask``) into the blocked sparse format.

    Args:
      w: dense ``[K, N]`` weight.
      mask: boolean/0-1 ``[K, N]`` keep-mask.
      block: ``(bk, bn)`` block shape.
      capacity: per-block packed-value capacity; default = max block nnz
        rounded up to ``LANE``.  Must be a static int under tracing
        (pass it explicitly when ``jax.eval_shape``-ing).  If a block holds
        more kept entries than the capacity, the smallest-magnitude overflow
        is dropped from bitmap *and* values together (see ``pack_blocks``).
      pad_to_blocks: pad block-counts ``(Kb, Nb)`` to these multiples so the
        block axes shard evenly over a mesh axis.
      scale: optional per-output-channel scale to carry (int8 mode).
    """
    bk, bn = block
    if (bk * bn) % 32 != 0:
        raise ValueError(f"block {block} must cover a multiple of 32 entries")
    wb = _to_blocks(w, block, pad_to_blocks)              # [Kb, Nb, L]
    mb = _to_blocks(mask.astype(w.dtype), block, pad_to_blocks) > 0

    if capacity is None:
        nnz = mb.astype(jnp.int32).sum(-1)                 # [Kb, Nb]
        cap = _ceil_to(max(int(jnp.max(nnz)), 1), LANE)
    else:
        cap = int(capacity)
    cap = min(cap, bk * bn)

    # capacity derived from the data can never truncate; skip the re-rank
    bitmap, vals = pack_blocks(wb, mb, cap,
                               cap_may_truncate=capacity is not None)
    if scale is not None:
        n_pad = wb.shape[1] * bn
        scale = jnp.pad(scale.astype(jnp.float32), (0, n_pad - scale.shape[0]))
    return BlockSparseWeight(bitmap=bitmap, values=vals, scale=scale,
                             shape=(int(w.shape[0]), int(w.shape[1])),
                             block=block)


def repack_capacity(sw: BlockSparseWeight, capacity: int) -> BlockSparseWeight:
    """Re-store ``sw`` at exactly ``capacity`` packed slots per block.

    Growing pads the value arrays (bit-exact round trip).  Shrinking
    re-ranks each block's kept entries by magnitude and drops the overflow
    from the bitmap *and* the values together, so ``unpack`` of the result
    always equals the dense weight its own bitmap describes.  (The old
    engine repack padded values only, which could leave a bitmap claiming
    entries whose values had been truncated away.)
    """
    if sw.packed4:
        raise ValueError("repack of nibble-packed int4 not supported")
    cap = int(capacity)
    if cap == sw.capacity:
        return sw
    if cap > sw.capacity:
        pad = cap - sw.values.shape[-1]
        vals = jnp.pad(sw.values,
                       [(0, 0)] * (sw.values.ndim - 1) + [(0, pad)])
        return BlockSparseWeight(sw.bitmap, vals, sw.scale, sw.shape,
                                 sw.block, sw.packed4)
    # shrink: decompress block-locally, re-pack at the smaller capacity
    bk, bn = sw.block
    mask, idx = block_gather_indices(sw.bitmap, sw.block)
    idx = jnp.minimum(idx, sw.capacity - 1)
    dense_flat = jnp.take_along_axis(sw.values, idx, axis=-1)
    dense_flat = jnp.where(mask > 0, dense_flat, 0)
    bitmap, vals = pack_blocks(dense_flat, mask > 0, cap)
    return BlockSparseWeight(bitmap, vals, sw.scale, sw.shape,
                             sw.block, sw.packed4)


def block_gather_indices(bitmap: jax.Array, block: Tuple[int, int]):
    """Bitmap -> (mask, gather index) per block — the decompression front half.

    Returns ``mask`` int32 ``[..., L]`` and ``idx`` int32 ``[..., L]`` where
    ``dense_flat = where(mask, values[idx], 0)``.  This is the TPU analogue of
    the paper's popcount + prefix-sum offset computation (Alg. 1 / Alg. 2).
    """
    bk, bn = block
    mask = unpack_bits(bitmap, bk * bn)
    idx = exclusive_cumsum(mask, axis=-1)
    return mask, idx


def unpack(sw: BlockSparseWeight, trim: bool = True) -> jax.Array:
    """Decompress to a dense ``[..., K, N]`` weight — pure-jnp oracle.

    Supports leading stacked dims (layer-stacked / expert-stacked weights):
    all decompression math is block-local, so extra leading dims broadcast.
    """
    mask, idx = block_gather_indices(sw.bitmap, sw.block)
    idx = jnp.minimum(idx, sw.capacity - 1)
    values = unpack_nibbles(sw.values) if sw.packed4 else sw.values
    dense_flat = jnp.take_along_axis(values, idx, axis=-1)
    dense_flat = jnp.where(mask > 0, dense_flat, 0).astype(values.dtype)
    shape = sw.shape if trim else sw.padded_shape
    return _from_blocks(dense_flat, sw.block, shape)


# ---------------------------------------------------------------------------
# abstract packing (for the dry-run: no allocation, shapes only)
# ---------------------------------------------------------------------------

def packed_spec(k: int, n: int, density: float,
                block: Tuple[int, int] = DEFAULT_BLOCK,
                dtype: Any = jnp.bfloat16,
                pad_to_blocks: Tuple[int, int] = (1, 1),
                with_scale: bool = False,
                lead: Tuple[int, ...] = ()) -> BlockSparseWeight:
    """Build a ShapeDtypeStruct-leaved BlockSparseWeight for abstract lowering.

    Capacity is the *balanced* capacity ``ceil(density * bk * bn / LANE) * LANE``
    — the storage the paper's sparsity level implies.  ``lead`` adds stacked
    leading dims (layer/expert stacks).
    """
    bk, bn = block
    kb = _ceil_to(_ceil_to(k, bk) // bk, pad_to_blocks[0])
    nb = _ceil_to(_ceil_to(n, bn) // bn, pad_to_blocks[1])
    cap = min(_ceil_to(max(int(round(density * bk * bn)), 1), LANE), bk * bn)
    sds = jax.ShapeDtypeStruct
    return BlockSparseWeight(
        bitmap=sds(lead + (kb, nb, bk * bn // 32), jnp.uint32),
        values=sds(lead + (kb, nb, cap), dtype),
        scale=sds(lead + (nb * bn,), jnp.float32) if with_scale else None,
        shape=(k, n), block=block)


def balanced_capacity(density: float, block: Tuple[int, int] = DEFAULT_BLOCK) -> int:
    bk, bn = block
    return min(_ceil_to(max(int(round(density * bk * bn)), 1), LANE), bk * bn)

"""Sparse KV cache: compressed frozen prefix + dense dynamic tail (paper §6.2).

The paper observes PyTorch's cache-update path (realloc + ``repeat_kv`` per
token) is >6x slower than freezing the prefill cache in model state and
appending new tokens to a small separate buffer.  We reproduce that design:

* after prefill, K and V are magnitude-pruned (paper: 30% K / 50% V keeps
  downstream accuracy within 1%) and packed with the standard blocked format
  — one (bs=128 tokens, D) block per bitmap row, viewed as [B*Hkv*S, D];
* newly decoded tokens land in a fixed-size dense ring ``tail`` with a
  monotone ``tail_len`` (no realloc, no concatenation on the hot path);
* when the tail fills, ``refreeze`` compresses it into the prefix (off the
  per-token hot path, amortized).

Two cache families build on these primitives:

* :class:`SparseKVCache` — the legacy one-shot layout (data-dependent
  capacity; refreeze grows shapes, so jitted consumers re-trace);
* the **pooled** layout (``freeze_chunk_blocks`` / ``pooled_view``) used by
  ``repro.serving.CachePool`` — per-block storage at a *static* capacity so
  refreeze is an in-place scatter and the serving decode step never
  re-traces.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .sparse_format import (BlockSparseWeight, pack, pack_blocks,
                            packed_spec, balanced_capacity, unpack)
from .pruning import prune_kv

KV_BLOCK_TOKENS = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SparseKVCache:
    """Per-layer compressed KV state.

    k_sp/v_sp: packed from the [B*Hkv*S, D] cache view, block (bs, D).
    k_tail/v_tail: dense [B, Hkv, T, D] ring for fresh tokens.
    tail_len: int32 scalar — valid tail entries.
    """
    k_sp: BlockSparseWeight
    v_sp: BlockSparseWeight
    k_tail: jax.Array
    v_tail: jax.Array
    tail_len: jax.Array

    def tree_flatten(self):
        return (self.k_sp, self.v_sp, self.k_tail, self.v_tail,
                self.tail_len), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def prefix_len(self) -> int:
        b, hkv, _, d = self.k_tail.shape
        return self.k_sp.shape[0] // (b * hkv)


def freeze_prefix(k: jax.Array, v: jax.Array,
                  k_sparsity: float = 0.3, v_sparsity: float = 0.5,
                  tail_size: int = 128,
                  bs: int = KV_BLOCK_TOKENS,
                  capacity_k: Optional[int] = None,
                  capacity_v: Optional[int] = None,
                  structured: bool = True) -> SparseKVCache:
    """Prune + pack a dense prefill cache ``k/v [B, Hkv, S, D]``.

    structured=True stores block arrays as [B, Hkv, Sb, 1, ...] so the
    batch / head / sequence-block dims shard independently (context-parallel
    decode); False keeps the flat [(B*Hkv*Sb), 1, ...] layout.
    """
    b, hkv, s, d = k.shape
    if s % bs != 0:
        raise ValueError(f"prefix length {s} must be a multiple of {bs}")
    kf = k.reshape(b * hkv * s, d)
    vf = v.reshape(b * hkv * s, d)
    k_sp = pack(kf, prune_kv(kf, k_sparsity), block=(bs, d),
                capacity=capacity_k)
    v_sp = pack(vf, prune_kv(vf, v_sparsity), block=(bs, d),
                capacity=capacity_v)
    if structured:
        k_sp = structure_kv(k_sp, b, hkv)
        v_sp = structure_kv(v_sp, b, hkv)
    zeros = jnp.zeros((b, hkv, tail_size, d), k.dtype)
    return SparseKVCache(k_sp, v_sp, zeros, zeros,
                         jnp.zeros((), jnp.int32))


def structure_kv(sw: BlockSparseWeight, b: int, hkv: int
                 ) -> BlockSparseWeight:
    """Flat [(B*Hkv*Sb), 1, X] block arrays -> [B, Hkv, Sb, 1, X].

    aux ``shape`` becomes the per-(b,h) logical (S, D) so ``unpack`` yields
    [B, Hkv, S, D] directly (leading dims broadcast through decompression).
    """
    rows_total, nb, _ = sw.bitmap.shape
    sb = rows_total // (b * hkv)
    bs, d = sw.block
    re = lambda a: a.reshape(b, hkv, sb, nb, a.shape[-1])
    return BlockSparseWeight(
        bitmap=re(sw.bitmap), values=re(sw.values),
        scale=sw.scale, shape=(sb * bs, d), block=sw.block,
        packed4=sw.packed4)


def append_token(cache: SparseKVCache, k_new: jax.Array,
                 v_new: jax.Array) -> SparseKVCache:
    """O(1) per-token append into the dense tail (no realloc, paper §6.2)."""
    idx = cache.tail_len
    k_tail = jax.lax.dynamic_update_slice_in_dim(
        cache.k_tail, k_new[:, :, None, :], idx, axis=2)
    v_tail = jax.lax.dynamic_update_slice_in_dim(
        cache.v_tail, v_new[:, :, None, :], idx, axis=2)
    return SparseKVCache(cache.k_sp, cache.v_sp, k_tail, v_tail, idx + 1)


def refreeze(cache: SparseKVCache,
             k_sparsity: float = 0.3, v_sparsity: float = 0.5
             ) -> SparseKVCache:
    """Fold a full tail back into the compressed prefix (paper §6.2's
    amortized off-hot-path step: "when the tail fills").

    The tail must be block-aligned (tail_size % bs == 0) and full; the
    result has a longer prefix, an empty tail, and (possibly) a larger
    capacity — callers decode against it with the same kernels.
    """
    b, hkv, t, d = cache.k_tail.shape
    bs = cache.k_sp.block[0]
    if t % bs != 0:
        raise ValueError(f"tail {t} not a multiple of block {bs}")
    structured = cache.k_sp.bitmap.ndim == 5
    k_pref = unpack(cache.k_sp)
    v_pref = unpack(cache.v_sp)
    if not structured:
        s = cache.k_sp.shape[0] // (b * hkv)
        k_pref = k_pref.reshape(b, hkv, s, d)
        v_pref = v_pref.reshape(b, hkv, s, d)
    k = jnp.concatenate([k_pref, cache.k_tail.astype(k_pref.dtype)], axis=2)
    v = jnp.concatenate([v_pref, cache.v_tail.astype(v_pref.dtype)], axis=2)
    # note: the old prefix is already pruned; re-pruning is a no-op on it
    # beyond threshold drift, matching the paper's layer-wide magnitude rule
    return freeze_prefix(k, v, k_sparsity, v_sparsity, tail_size=t, bs=bs,
                         structured=structured)


def maybe_refreeze(cache: SparseKVCache, k_sparsity: float,
                   v_sparsity: float) -> SparseKVCache:
    """Host-side helper: refreeze when the tail is full (static check via
    concrete tail_len; used by the serving engine between jitted steps)."""
    t = cache.k_tail.shape[2]
    # documented sync: this helper is the host boundary by design
    if int(cache.tail_len) >= t:  # jitlint: disable=host-sync
        return refreeze(cache, k_sparsity, v_sparsity)
    return cache


# ---------------------------------------------------------------------------
# pooled-cache primitives (serving CachePool — jit-stable, static shapes)
# ---------------------------------------------------------------------------

def freeze_chunk_blocks(k: jax.Array, v: jax.Array,
                        k_sparsity: float, v_sparsity: float,
                        bs: int, cap_k: int, cap_v: int):
    """Compress a block-aligned K/V chunk at *static* per-block capacities.

    ``k/v [B, Hkv, C, D]`` with ``C % bs == 0`` -> ``(k_bitmap [B, Hkv, Cb,
    bs*D//32], k_values [B, Hkv, Cb, cap_k], v_bitmap, v_values)``.

    The magnitude threshold is computed per ``(batch entry, token block)``
    — the paper's layer-wide rule applied per request slot at block
    granularity — then each ``(bs, D)`` token block is packed at the
    pool's fixed capacity via :func:`pack_blocks` — if pruning leaves a
    block denser than the capacity, the overflow is dropped consistently
    from bitmap and values.  Per-*block* (not per-chunk) thresholds are a
    sharing invariant, not a tuning choice: they make a frozen block's
    compressed bytes a pure function of the tokens up to the block's end,
    independent of how prefill happened to be chunked — the property the
    paged cache's content-addressed block index relies on.  Everything
    here is traceable with static shapes, so the serving refreeze can run
    inside a once-compiled ``jax.jit``.
    """
    b, hkv, c, d = k.shape
    if c % bs != 0:
        raise ValueError(f"context {c} not a multiple of block {bs}")
    nb = c // bs

    def block_masks(a, sparsity):
        # [B, Hkv, C, D] -> per-(slot, block) thresholds over (Hkv, bs, D)
        ab = a.reshape(b, hkv, nb, bs, d).transpose(0, 2, 1, 3, 4)
        m = jax.vmap(jax.vmap(lambda x: prune_kv(x, sparsity)))(ab)
        return m.transpose(0, 2, 1, 3, 4).reshape(b, hkv, c, d)

    mask_k = block_masks(k, k_sparsity)
    mask_v = block_masks(v, v_sparsity)

    def blocks(a):
        return a.reshape(b, hkv, c // bs, bs * d)
    k_bm, k_vals = pack_blocks(blocks(k), blocks(mask_k), cap_k)
    v_bm, v_vals = pack_blocks(blocks(v), blocks(mask_v), cap_v)
    return k_bm, k_vals, v_bm, v_vals


def append_tail_panel(tail: jax.Array, new: jax.Array, tail_len: jax.Array,
                      n_valid: jax.Array) -> jax.Array:
    """Masked multi-token append into the dense tail ring.

    ``tail [B, Hkv, T, D]``; ``new [B, Hkv, m, D]`` — up to ``m`` fresh
    K/V tokens per slot, written at each slot's own ``tail_len`` offset;
    ``n_valid int32 [B]`` (or scalar) — how many of the ``m`` panel tokens
    slot ``b`` actually writes (0 = pure passthrough).  Writes that would
    land past the ring end are dropped (the caller's rollback/refreeze
    bookkeeping guarantees the *kept* tokens always fit; only never-kept
    panel padding can overflow).  One batched scatter at static shapes —
    invalid panel tokens route to an out-of-bounds row and fall to
    ``mode="drop"``, so the ring is written in a single pass and the
    speculative verify step jits once per panel width.
    """
    b, _, t, _ = tail.shape
    m = new.shape[2]
    tail_len = jnp.broadcast_to(jnp.asarray(tail_len, jnp.int32), (b,))
    n_valid = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32), (b,))
    j = jnp.arange(m)
    off = tail_len[:, None] + j[None, :]                       # [B, m]
    ok = (j[None, :] < n_valid[:, None]) & (off < t)
    idx = jnp.where(ok, off, t)                                # t => dropped
    return jax.vmap(lambda tl, nw, ix: tl.at[:, ix].set(
        nw.astype(tl.dtype), mode="drop"))(tail, new, idx)


def pooled_view(bitmap: jax.Array, values: jax.Array, bs: int, d: int
                ) -> BlockSparseWeight:
    """Pooled block arrays ``[B, Hkv, Sb, X]`` -> the structured
    ``BlockSparseWeight`` view (``[B, Hkv, Sb, 1, X]``) the decode-attention
    kernels consume.  Zero-copy (reshape only)."""
    sb = bitmap.shape[2]
    return BlockSparseWeight(
        bitmap=bitmap[:, :, :, None, :], values=values[:, :, :, None, :],
        scale=None, shape=(sb * bs, d), block=(bs, d))


def abstract_cache(batch: int, hkv: int, prefix: int, d: int,
                   k_density: float = 0.7, v_density: float = 0.5,
                   tail_size: int = 128, bs: int = KV_BLOCK_TOKENS,
                   dtype=jnp.bfloat16,
                   structured: bool = True) -> SparseKVCache:
    """ShapeDtypeStruct cache for the dry-run (no allocation)."""
    sds = jax.ShapeDtypeStruct
    if structured:
        k_sp = packed_spec(prefix, d, k_density, block=(bs, d), dtype=dtype,
                           lead=(batch, hkv))
        v_sp = packed_spec(prefix, d, v_density, block=(bs, d), dtype=dtype,
                           lead=(batch, hkv))
    else:
        rows = batch * hkv * prefix
        k_sp = packed_spec(rows, d, k_density, block=(bs, d), dtype=dtype)
        v_sp = packed_spec(rows, d, v_density, block=(bs, d), dtype=dtype)
    tail = sds((batch, hkv, tail_size, d), dtype)
    return SparseKVCache(k_sp, v_sp, tail, tail, sds((), jnp.int32))

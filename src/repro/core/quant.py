"""INT8 symmetric quantization (paper §4.5 INT8 kernels).

Weights: per-output-channel symmetric int8 (scale fp32 ``[N]``).
Activations: dynamic per-row (per-token) symmetric int8.
Matmul accumulates in int32 on the MXU and rescales:
``out[m, n] = acc_i32[m, n] * s_act[m] * s_w[n]``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_weight_int8(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """``[K, N]`` -> (int8 ``[K, N]``, fp32 scale ``[N]``)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]), -127, 127)
    return q.astype(jnp.int8), scale


def quantize_act_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """``[..., K]`` -> (int8, fp32 per-row scale ``[...]``)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def quantize_weight_int4(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """``[K, N]`` -> (int4-valued int8 ``[K, N]`` in [-7, 7], fp32 scale
    ``[N]``) — paper §8's INT4 extension (nibble-packed at pack time)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)
    scale = jnp.maximum(amax, 1e-8) / 7.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]), -7, 7)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, axis: int = -1,
               dtype=jnp.float32) -> jax.Array:
    shape = [1] * q.ndim
    shape[axis] = q.shape[axis]
    return (q.astype(jnp.float32) * scale.reshape(shape)).astype(dtype)

"""Core: the paper's contribution — blocked unstructured-sparse weight and
KV-cache formats, pruning policies, int8 quantization, and conversion of
dense parameter trees into sparse ones ("replace all linear layers")."""
from .sparse_format import (BlockSparseWeight, pack, unpack, packed_spec,
                            pack_bits, unpack_bits, balanced_capacity,
                            DEFAULT_BLOCK)
from .pruning import (make_mask, prune_global, prune_balanced, prune_wanda,
                      prune_kv)
from .quant import quantize_weight_int8, quantize_act_int8, dequantize
from .sparse_kv import (SparseKVCache, freeze_prefix, append_token,
                        abstract_cache, refreeze, maybe_refreeze,
                        structure_kv, KV_BLOCK_TOKENS)
from .convert import convert_to_sparse, sparsity_report

__all__ = [
    "BlockSparseWeight", "pack", "unpack", "packed_spec", "pack_bits",
    "unpack_bits", "balanced_capacity", "DEFAULT_BLOCK", "make_mask",
    "prune_global", "prune_balanced", "prune_wanda", "prune_kv",
    "quantize_weight_int8", "quantize_act_int8", "dequantize",
    "SparseKVCache", "freeze_prefix", "append_token", "abstract_cache",
    "KV_BLOCK_TOKENS", "convert_to_sparse", "sparsity_report",
]

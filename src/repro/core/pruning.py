"""Pruning policies producing unstructured keep-masks (paper §2.2, §6.1).

Three policies:

* ``global``   — magnitude threshold over the whole tensor: *exactly* the
  paper's unstructured mask.  Block capacity is set by the densest block.
* ``balanced`` — per-block top-k ("block-balanced unstructured"): every
  ``(bk, bn)`` block keeps exactly ``round(density * bk * bn)`` entries, so
  packed capacity — and therefore bytes moved — matches the nominal density
  exactly.  This is the TPU-native variant (see DESIGN.md §2).
* ``wanda``    — |w| * input-activation norm score (Sun et al., 2024), the
  strongest one-shot unstructured criterion the paper cites; same mask
  mechanics as ``global``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .sparse_format import DEFAULT_BLOCK, _to_blocks, _from_blocks


def prune_global(w: jax.Array, sparsity: float) -> jax.Array:
    """Keep the largest-|w| ``(1-sparsity)`` fraction globally. Returns mask."""
    if sparsity <= 0.0:
        return jnp.ones_like(w, dtype=jnp.bool_)
    a = jnp.abs(w).reshape(-1)
    k = jnp.clip(jnp.round(sparsity * a.size).astype(jnp.int32), 0, a.size - 1)
    thr = jnp.sort(a)[k]
    return jnp.abs(w) >= thr


def prune_balanced(w: jax.Array, sparsity: float,
                   block: Tuple[int, int] = DEFAULT_BLOCK) -> jax.Array:
    """Per-block top-k magnitude mask: exactly-balanced occupancy per block."""
    if sparsity <= 0.0:
        return jnp.ones_like(w, dtype=jnp.bool_)
    bk, bn = block
    l = bk * bn
    keep = max(int(round((1.0 - sparsity) * l)), 1)
    wb = _to_blocks(jnp.abs(w), block)                     # [Kb, Nb, L]
    # top-`keep` indices per block -> scatter a 0/1 mask
    idx = jax.lax.top_k(wb, keep)[1]                       # [Kb, Nb, keep]
    mb = jnp.zeros(wb.shape, jnp.int32)
    mb = jax.vmap(jax.vmap(lambda m, i: m.at[i].set(1)))(mb, idx)
    mask = _from_blocks(mb, block, w.shape)
    return mask > 0


def prune_wanda(w: jax.Array, act_norm: jax.Array, sparsity: float,
                per_output: bool = True) -> jax.Array:
    """Wanda: score = |w| * ||x_k||; prune per output channel (column)."""
    score = jnp.abs(w) * act_norm[:, None]
    if not per_output:
        k = int(round(sparsity * score.size))
        thr = jnp.sort(score.reshape(-1))[max(k - 1, 0)]
        return score >= thr
    keep = max(int(round((1.0 - sparsity) * w.shape[0])), 1)
    thr = jnp.sort(score, axis=0)[-keep, :]
    return score >= thr[None, :]


def prune_kv(kv: jax.Array, sparsity: float) -> jax.Array:
    """Magnitude mask for cached K or V values (paper §6.1).

    ``kv``: ``[..., S, D]``; values with the lowest |.| are dropped per
    (layer-wide) tensor, matching "values with the lowest magnitudes are
    dropped within each layer".
    """
    if sparsity <= 0.0:
        return jnp.ones_like(kv, dtype=jnp.bool_)
    a = jnp.abs(kv).reshape(-1)
    k = jnp.clip(jnp.round(sparsity * a.size).astype(jnp.int32), 0, a.size - 1)
    thr = jnp.sort(a)[k]
    return jnp.abs(kv) >= thr


def make_mask(w: jax.Array, sparsity: float, policy: str = "balanced",
              block: Tuple[int, int] = DEFAULT_BLOCK,
              act_norm: Optional[jax.Array] = None) -> jax.Array:
    if policy == "global":
        return prune_global(w, sparsity)
    if policy == "balanced":
        return prune_balanced(w, sparsity, block)
    if policy == "wanda":
        if act_norm is None:
            raise ValueError("wanda needs per-input-channel act norms")
        return prune_wanda(w, act_norm, sparsity)
    raise ValueError(f"unknown pruning policy {policy!r}")

"""Distribution: logical-axis sharding rules, shard contexts, collectives."""
from .sharding import (ShardCtx, NULL_CTX, default_rules, tree_param_specs,
                       to_named, mesh_axis_size)
from . import serving_sharding

"""Context-parallel sparse-KV flash-decode (§Perf iteration 1).

Baseline problem (measured in EXPERIMENTS.md §Perf): letting the XLA
partitioner handle the decode-attention einsums over a (data x model)-
sharded compressed cache replicates the per-(b,h) score computation across
the model axis and all-gathers cache shards — ~2 orders of magnitude of
extra HBM+ICI traffic per token.

Fix: shard_map the whole prefix attention so every chip touches ONLY its
local cache blocks (batch over dp, sequence-blocks over the remaining
axes), computes a local flash partial (o_i, lse_i), and merges partials
with one tiny pair of collectives per layer:

    m*  = pmax(lse_i)
    w_i = exp(lse_i - m*)                 # = l_i * exp(m_i - m*)
    o   = psum(o_i * w_i) / psum(w_i)     # [B, Hq, D] + [B, Hq] psum only

The dense dynamic tail is computed redundantly per shard (it's ~128 tokens)
and merged locally after the combine, so it never enters the psum.

NOTE — this module is deliberately **pinned to the partial+merge entry
points** (``ref.gqa_partial_ref`` / ``ref._merge_attn`` /
``ref.sparse_decode_attention_ref`` and, on TPU, the prefix-partial
``sparse_decode_attention_pallas``): the per-shard (o_i, lse_i) partials
must cross chips before they can be normalized, so the single-chip fused
prefix+tail kernel (``sparse_decode_attention_fused_pallas``, used by
``ops.sparse_decode_attention`` everywhere else) structurally cannot apply
here.  Everything outside this module goes through the fused path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.sparse_format import BlockSparseWeight, unpack
from repro.core.sparse_kv import SparseKVCache
from repro.kernels import ref
from .sharding import shard_map


def _local_partial(q, k_sp_leaves, v_sp_leaves, sw_meta, hkv, sm_scale):
    """Flash partial over the local cache blocks (grouped GQA — no
    repeat_kv materialization, bf16 cache operands). Returns (o, lse) with
    o/lse shaped [B_loc, Hkv, G, ...]."""
    (kbm, kvv), (vbm, vvv) = k_sp_leaves, v_sp_leaves
    shape, block = sw_meta
    k_sp = BlockSparseWeight(kbm, kvv, None, shape, block)
    v_sp = BlockSparseWeight(vbm, vvv, None, shape, block)
    k = unpack(k_sp)            # [B_loc, Hkv, S_loc, D] (bf16)
    v = unpack(v_sp)
    b, hq, d = q.shape
    qg = q.reshape(b, hkv, hq // hkv, d)
    return ref.gqa_partial_ref(qg, k, v, sm_scale)


def sparse_decode_attention_cp(q: jax.Array, cache: SparseKVCache,
                               hkv: int, sm_scale: float, ctx
                               ) -> jax.Array:
    """q [B, Hq, D]; cache structured (bitmap [B, Hkv, Sb, 1, W])."""
    mesh = ctx.mesh
    b, hq, d = q.shape
    kb = cache.k_sp.bitmap
    if kb.ndim != 5:
        raise ValueError("context-parallel path needs the structured layout")
    sb = kb.shape[2]

    dp = ctx.rules.get("batch")
    dp = tuple(a for a in (dp if isinstance(dp, (tuple, list)) else (dp,))
               if a is not None)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_axes = dp if (dp_size > 1 and b % dp_size == 0) else ()
    tp = ctx.rules.get("ffn")
    seq_axes = tuple(a for a in ((tp,) if b_axes else dp + (tp,))
                     if a is not None)
    seq_size = 1
    for a in seq_axes:
        seq_size *= mesh.shape[a]
    if seq_size <= 1 or sb % seq_size != 0:
        # cannot context-shard: fall back to the replicated two-pass
        # reference (this path stays partial+merge by design — see the
        # module docstring)
        return ref.sparse_decode_attention_ref(
            q, cache.k_sp, cache.v_sp, sm_scale, cache.k_tail,
            cache.v_tail, cache.tail_len)

    bspec = b_axes if b_axes else None
    blk5 = P(bspec, None, seq_axes, None, None)
    tail_spec = P(bspec, None, None, None)
    q_spec = P(bspec, None, None)
    meta = (cache.k_sp.shape, cache.k_sp.block)

    def body(qL, kbm, kvv, vbm, vvv, ktL, vtL, tlen):
        o, lse = _local_partial(qL, (kbm, kvv), (vbm, vvv), meta, hkv,
                                sm_scale)                # [B,Hkv,G,...]
        m_star = jax.lax.pmax(lse, seq_axes)
        w = jnp.exp(lse - m_star)
        num = jax.lax.psum(o * w[..., None], seq_axes)
        den = jax.lax.psum(w, seq_axes)
        o_pref = num / jnp.maximum(den, 1e-30)[..., None]
        lse_pref = m_star + jnp.log(jnp.maximum(den, 1e-30))
        # dense tail: tiny, computed redundantly per shard, merged locally
        t = ktL.shape[2]
        bl, hq_l, d_l = qL.shape
        if t > 0:
            valid = jnp.broadcast_to(jnp.arange(t)[None, :] < tlen, (bl, t))
            qg = qL.reshape(bl, hkv, hq_l // hkv, d_l)
            o_t, lse_t = ref.gqa_partial_ref(qg, ktL, vtL, sm_scale, valid)
            empty = ~jnp.any(valid, axis=-1)
            lse_t = jnp.where(empty[:, None, None], lse_pref - 60.0, lse_t)
            lse_t = jnp.where(jnp.isfinite(lse_t), lse_t, lse_pref - 60.0)
            o_pref, _ = ref._merge_attn(o_pref, lse_pref, o_t, lse_t)
        return o_pref.reshape(bl, hq_l, d_l).astype(qL.dtype)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, blk5, blk5, blk5, blk5, tail_spec, tail_spec,
                  P()),
        out_specs=q_spec, check_vma=False)
    return fn(q, cache.k_sp.bitmap, cache.k_sp.values, cache.v_sp.bitmap,
              cache.v_sp.values, cache.k_tail, cache.v_tail,
              cache.tail_len)

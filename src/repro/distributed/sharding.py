"""Logical-axis sharding rules and the model-visible shard context.

Logical axes used by param specs and activation constraints:

  batch     -> (pod, data)      activations' batch dim
  slots     -> (pod, data)      serving cache-pool slot dim (the pooled
               state's batch axis — see distributed/serving_sharding.py)
  seq       -> model (iff cfg.seq_shard; Megatron sequence sharding of the
               residual stream between attention/MLP blocks)
  ctx       -> data             KV-cache / recurrent-state sequence dim for
               context-parallel long-context decode
  embed     -> data+pod iff cfg.fsdp (ZeRO-3-style weight sharding), else None
  heads, kv_heads, ffn, vocab, expert_in -> model   (tensor parallel)
  experts   -> None baseline (see EP variant in §Perf)
  layers    -> None

Every mapping degrades to ``None`` (replication) when the dim size does not
divide the mesh axis — e.g. kv_heads=8 on model=16 — so any (arch x mesh)
combination lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

try:                                  # jax >= 0.5 top-level export
    shard_map = jax.shard_map
except AttributeError:                # jax 0.4.x experimental location
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                  check_vma=None, axis_names=None, **kw):
        """Translate modern ``jax.shard_map`` kwargs (``check_vma``,
        ``axis_names``) onto the 0.4.x experimental API (``check_rep``,
        ``auto`` = complement of the manual axes)."""
        if check_vma is not None:
            kw["check_rep"] = check_vma
        if axis_names is not None:
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map_legacy(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)

from repro.core.sparse_format import BlockSparseWeight
from repro.models import module as mod


def mesh_axis_size(mesh: Optional[Mesh], axis) -> int:
    if mesh is None or axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


@dataclasses.dataclass
class ShardCtx:
    """Model-visible sharding context. ``mesh=None`` -> single-device no-op."""
    mesh: Optional[Mesh] = None
    rules: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- activation constraints ------------------------------------------
    def spec(self, axes: Sequence[Optional[str]], sizes: Sequence[int] = None
             ) -> PartitionSpec:
        used: set = set()
        out = []
        for i, ax in enumerate(axes):
            mesh_ax = self.rules.get(ax) if ax is not None else None
            if mesh_ax is None:
                out.append(None)
                continue
            flat = tuple(mesh_ax) if isinstance(mesh_ax, (tuple, list)) \
                else (mesh_ax,)
            keep = tuple(a for a in flat if a not in used)
            if sizes is not None and keep:
                n = 1
                for a in keep:
                    n *= self.mesh.shape[a]
                if sizes[i] % n != 0:
                    keep = ()
            used.update(keep)
            out.append(None if not keep else
                       (keep if len(keep) > 1 else keep[0]))
        return PartitionSpec(*out)

    def constrain(self, x: jax.Array, axes: Sequence[Optional[str]]):
        if self.mesh is None or x is None:
            return x
        s = self.spec(axes, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, s))

    @property
    def tp_axis(self) -> Optional[str]:
        return self.rules.get("ffn")

    @property
    def dp_axes(self):
        return self.rules.get("batch")

    def axis_size(self, logical: str) -> int:
        return mesh_axis_size(self.mesh, self.rules.get(logical))


NULL_CTX = ShardCtx()


def default_rules(multi_pod: bool, cfg=None) -> Dict[str, Any]:
    dp = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, Any] = {
        "batch": dp,
        # serving: cache-pool slots are the batch dim of the pooled state —
        # slots over the data axes, kv heads (below) over the model axis
        # gives multi-chip continuous batching (distributed/serving_sharding)
        "slots": dp,
        "ctx": dp + ("model",),   # KV/cache blocks spread over ALL chips
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "vocab": "model",
        "expert_in": "model",
        "experts": None,
        "layers": None,
        "seq": None,
        "embed": None,
        "ssm_inner": "model",
        "state": None,
    }
    if cfg is not None:
        if cfg.seq_shard:
            rules["seq"] = "model"
        if cfg.fsdp:
            rules["embed"] = dp
        if getattr(cfg, "ep_moe", False):
            # expert-parallel: store expert weights already in the EP layout
            # (experts over DP) so the shard_map consumes them reshard-free
            rules["experts"] = dp
    return rules


# ---------------------------------------------------------------------------
# param shardings (dense ParamSpec trees and converted sparse trees)
# ---------------------------------------------------------------------------

def _sparse_leaf_spec(ctx: ShardCtx, sw: BlockSparseWeight,
                      k_ax: Optional[str], n_ax: Optional[str]
                      ) -> BlockSparseWeight:
    """PartitionSpecs for a BlockSparseWeight: block axes inherit the dense
    tensor's logical axes; leading stacked dims and the packed trailing dim
    are unsharded."""
    lead = (None,) * (sw.bitmap.ndim - 3)
    kb, nb = sw.bitmap.shape[-3:-1]
    s2 = ctx.spec(lead + (k_ax, n_ax, None),
                  sw.lead_shape + (kb, nb, 1))
    scale_spec = None
    if sw.scale is not None:
        scale_spec = PartitionSpec(*(lead + (s2[len(lead) + 1],)))
    return BlockSparseWeight(
        bitmap=s2, values=s2, scale=scale_spec,
        shape=sw.shape, block=sw.block, packed4=sw.packed4)


def tree_param_specs(ctx: ShardCtx, spec_tree: Any, params_tree: Any) -> Any:
    """PartitionSpec tree for a (possibly sparse-converted) params tree.

    ``spec_tree`` carries the logical axes (ParamSpec leaves); where the
    params tree has a BlockSparseWeight, block axes inherit the last two
    logical axes of the original spec.
    """
    def one(ps: mod.ParamSpec, leaf):
        if isinstance(leaf, BlockSparseWeight):
            axes = ps.axes or (None,) * len(ps.shape)
            return _sparse_leaf_spec(ctx, leaf, axes[-2], axes[-1])
        return ctx.spec(ps.axes or (None,) * leaf.ndim, leaf.shape)

    return jax.tree_util.tree_map(
        one, spec_tree, params_tree,
        is_leaf=lambda x: mod.is_spec(x) or isinstance(x, BlockSparseWeight))


def zero1_specs(pspec_tree: Any, params_tree: Any, cfg, ctx: ShardCtx) -> Any:
    """ZeRO-1: optimizer-state specs = param specs + data-parallel sharding
    on the first unsharded, dp-divisible dim.  Shrinks fp32 master+moments by
    the dp degree (the difference between 67B fitting a pod or not)."""
    dp = ctx.rules.get("batch")
    dp = tuple(dp) if isinstance(dp, (tuple, list)) else ((dp,) if dp else ())
    dp = tuple(a for a in dp if a is not None)
    dp_size = 1
    for a in dp:
        dp_size *= ctx.mesh.shape[a]

    def one(spec: PartitionSpec, leaf):
        if not getattr(cfg, "zero1", False) or not dp or leaf.ndim == 0:
            return spec
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        used = set()
        for d in dims:
            for a in (d if isinstance(d, tuple) else (d,)):
                if a is not None:
                    used.add(a)
        free = tuple(a for a in dp if a not in used)
        if not free:
            return spec
        n = 1
        for a in free:
            n *= ctx.mesh.shape[a]
        for i, d in enumerate(dims):
            if d is None and leaf.shape[i] % n == 0 and leaf.shape[i] >= n:
                dims[i] = free if len(free) > 1 else free[0]
                break
        return PartitionSpec(*dims)

    return jax.tree_util.tree_map(
        one, pspec_tree, params_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def to_named(ctx: ShardCtx, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))

"""Mesh-aware sparse-conversion planning.

The paper packs weights offline for a fixed thread count; our analogue packs
for a fixed mesh: each eligible 2D weight gets a block shape + block-count
padding so its packed block axes shard exactly like the dense axes they
replace (DESIGN.md §2, §6).

* if the sharded dense axis has >= mesh_size blocks, pad the block count up
  to a multiple (waste <= mesh/Nb, e.g. +2.3% for deepseek's d_ff=22016);
* otherwise the tensor replicates on that axis (small tensors — cheap).

3D expert-stacked weights stay dense under tensor-parallel meshes (their
block order is expert-major, which TP chunking would misinterpret); they go
sparse under expert-parallel sharding (§Perf) or single-shard serving.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sparse_format import (DEFAULT_BLOCK, BlockSparseWeight,
                                      packed_spec, balanced_capacity, pack,
                                      pack_nibbles)
from repro.core.convert import default_predicate, _path_str
from repro.core.pruning import make_mask
from repro.core.quant import quantize_weight_int8, quantize_weight_int4
from repro.models import module as mod
from .sharding import ShardCtx, mesh_axis_size


def _to_int4(sw: BlockSparseWeight) -> BlockSparseWeight:
    """int8-valued packed weight -> nibble-packed int4 (capacity is a
    multiple of 128, hence even)."""
    return BlockSparseWeight(sw.bitmap, pack_nibbles(sw.values), sw.scale,
                             sw.shape, sw.block, packed4=True)


def _fit_block(dim: int, pref: int) -> int:
    """Shrink the preferred block edge for small tensors (no padding blowup);
    keep multiples of 8 so bitmaps stay word-aligned."""
    if dim >= pref:
        return pref
    return max(-(-dim // 8) * 8, 8)


def _plan_leaf(spec: mod.ParamSpec, ctx: ShardCtx, block=DEFAULT_BLOCK
               ) -> Tuple[Tuple[int, int], Tuple[int, int]]:
    """-> (block, pad_to_blocks) for one (possibly layer-stacked) 2D weight."""
    k, n = spec.shape[-2:]
    block = (_fit_block(k, block[0]), _fit_block(n, block[1]))
    bk, bn = block
    axes = (spec.axes or (None,) * len(spec.shape))[-2:]
    kb = -(-k // bk)
    nb = -(-n // bn)
    pk = mesh_axis_size(ctx.mesh, ctx.rules.get(axes[0]))
    pn = mesh_axis_size(ctx.mesh, ctx.rules.get(axes[1]))
    pad_k = pk if (pk > 1 and kb >= pk) else 1
    pad_n = pn if (pn > 1 and nb >= pn) else 1
    return block, (pad_k, pad_n)


def _is_sparsifiable(path: str, spec) -> bool:
    """2D weights, or layer-stacked 2D weights (leading 'layers' axis).
    Expert-stacked (axis 'experts') weights stay dense under TP (see above)."""
    if not mod.is_spec(spec):
        return False
    if not default_predicate(
            path, jax.ShapeDtypeStruct(spec.shape, spec.dtype)):
        return False
    if len(spec.shape) == 2:
        return True
    axes = spec.axes or ()
    return len(spec.shape) == 3 and len(axes) == 3 and axes[0] == "layers"


def convert_abstract(params_abs: Any, spec_tree: Any, cfg, ctx: ShardCtx,
                     mode: str = "bf16", block=DEFAULT_BLOCK) -> Any:
    """ShapeDtypeStruct params -> tree with abstract BlockSparseWeight leaves
    (zero allocation; used by the dry-run)."""
    density = 1.0 - cfg.sparsity
    flat_s = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=mod.is_spec)[0]
    treedef = jax.tree_util.tree_structure(spec_tree, is_leaf=mod.is_spec)
    flat_p = treedef.flatten_up_to(params_abs)
    out = []
    for (path, spec), leaf in zip(flat_s, flat_p):
        p = _path_str(path)
        if _is_sparsifiable(p, spec):
            blk, pad = _plan_leaf(spec, ctx, block)
            dtype = jnp.int8 if mode in ("int8", "int4") else jnp.bfloat16
            lead = tuple(spec.shape[:-2])
            ps = packed_spec(*spec.shape[-2:], density, blk, dtype,
                             pad, with_scale=(mode in ("int8", "int4")),
                             lead=lead)
            if mode == "int4":
                half = jax.ShapeDtypeStruct(
                    ps.values.shape[:-1] + (ps.values.shape[-1] // 2,),
                    jnp.uint8)
                ps = BlockSparseWeight(ps.bitmap, half, ps.scale, ps.shape,
                                       ps.block, packed4=True)
            out.append(ps)
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def convert_concrete(params: Any, spec_tree: Any, cfg, ctx: ShardCtx,
                     mode: str = "bf16", block=DEFAULT_BLOCK) -> Any:
    """Real pruning + packing with the same mesh-aware plan (tests/serving)."""
    density = 1.0 - cfg.sparsity
    flat_s = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=mod.is_spec)[0]
    treedef = jax.tree_util.tree_structure(spec_tree, is_leaf=mod.is_spec)
    flat_p = treedef.flatten_up_to(params)
    out = []
    for (path, spec), leaf in zip(flat_s, flat_p):
        p = _path_str(path)
        if _is_sparsifiable(p, spec):
            blk, pad = _plan_leaf(spec, ctx, block)
            cap = balanced_capacity(density, blk)

            def pack_one(w2):
                mask = make_mask(w2, cfg.sparsity, cfg.sparse_policy, blk)
                if mode in ("int8", "int4"):
                    quant = quantize_weight_int8 if mode == "int8" \
                        else quantize_weight_int4
                    q, scale = quant(jnp.where(mask, w2, 0))
                    sw = pack(q, mask, blk, capacity=cap,
                              pad_to_blocks=pad, scale=scale)
                    return _to_int4(sw) if mode == "int4" else sw
                return pack(w2.astype(jnp.bfloat16), mask, blk,
                            capacity=cap, pad_to_blocks=pad)

            if leaf.ndim == 3:          # layer-stacked: pack per layer
                out.append(jax.vmap(pack_one)(leaf))
            else:
                out.append(pack_one(leaf))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)

"""Mesh shardings for the pooled serving state (ShardCtx-driven).

The continuous-batching engine's entire device state is a pytree of
``[slots]``-leading vectors (lengths, sampling lanes) and pooled cache
leaves ``[P, slots, Hkv, ...]``.  Serving on a multi-chip mesh is therefore
a *placement* problem, not a kernel problem: shard **slots over the data
axes** (each chip owns a subset of concurrent requests) and **KV heads
over the model axis** (each chip owns a subset of each request's cache),
and every jitted engine step — decode/verify panels, chunked prefill,
in-place refreeze, rollback, release, lane writes — runs unchanged, because
all of them are already masked writes at static shapes with no cross-slot
reductions.

This module emits the :class:`~jax.sharding.NamedSharding` trees the engine
passes to ``jax.jit`` as ``in_shardings``/``out_shardings``.  Placement is
derived from logical-axis names (the same ``ShardCtx.spec`` machinery the
training stack uses), so every leaf degrades to replication when its dim
does not divide the mesh axis — any (pool geometry x mesh) combination
lowers, and a 1-device mesh is exactly the unsharded engine
(token-identical, zero extra retraces).

**Paged pool placement.**  The paged arena breaks the "slots over data"
rule on purpose: any slot on any data shard may point its block-table row
at any physical page (cross-slot sharing is the feature), so the arena's
physical-block axis is REPLICATED over the data axes while its KV-head
axis still shards over the model axis — each chip holds all pages but only
its heads' bytes, the same per-chip cache footprint as the flat grid when
``n_phys == slots * max_blocks``.  The block table shards with the slots
it indexes; the refcount vector is replicated (its scatter-adds are
computed identically on every shard, so no reduction is needed).  All of
this is described by ``CachePool.state_axes`` and flows through the same
:func:`tree_shardings` machinery — nothing below is paged-aware.

Weights are *replicated* by the engine (serving decode is memory-bound on
the cache, not the weights): ``ContinuousEngine(mesh=...)`` device_puts
its params onto a fully-replicated placement and pins them that way in
every step's ``in_shardings`` — pre-sharded weights would be gathered.
Tensor-parallel weight placement for serving (reusing
:func:`repro.distributed.tree_param_specs` and threading the committed
shardings through the step jits) is a ROADMAP follow-up.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .sharding import ShardCtx, default_rules


def serving_ctx(mesh: Optional[Mesh], cfg=None) -> ShardCtx:
    """ShardCtx for serving: the default logical-axis rules (slots/batch
    over data, kv_heads/heads/vocab over model) on ``mesh``.  ``mesh=None``
    is the single-device no-op context."""
    if mesh is None:
        return ShardCtx()
    multi_pod = "pod" in mesh.axis_names
    rules = default_rules(multi_pod, cfg)
    # serving activations are [slots, ...]; constrain their batch dim the
    # same way the state's slot dim is sharded
    rules["batch"] = rules["slots"]
    return ShardCtx(mesh, rules)


def leaf_sharding(ctx: ShardCtx, axes: Sequence[Optional[str]],
                  leaf) -> NamedSharding:
    """NamedSharding for one leaf from its logical axes (divisibility-safe:
    any axis that does not divide falls back to replication)."""
    return NamedSharding(ctx.mesh, ctx.spec(axes, leaf.shape))


def tree_shardings(ctx: ShardCtx, axes_tree: Any, tree: Any) -> Any:
    """Map an axes pytree + a matching state pytree to NamedShardings.

    ``axes_tree`` carries one logical-axes tuple per leaf (the owners
    describe their own layout: ``CachePool.state_axes`` for the pool,
    ``sampling.lane_axes`` for the lanes); leaves are matched positionally
    by pytree structure.
    """
    return jax.tree_util.tree_map(
        lambda axes, leaf: leaf_sharding(ctx, axes, leaf),
        axes_tree, tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def state_shardings(ctx: ShardCtx, state: Any, axes_tree: Any) -> Any:
    """Shardings for the engine's full device state (pool + lanes).

    ``state`` may be concrete arrays or ShapeDtypeStructs — only shapes
    are read.  Slots land on the data axes, KV heads on the model axis,
    everything else replicated; leaves whose dims don't divide replicate.
    """
    return tree_shardings(ctx, axes_tree, state)


def token_sharding(ctx: ShardCtx, slots: int) -> NamedSharding:
    """Sharding for per-tick ``[slots, Q]`` token panels (any static Q)."""
    return NamedSharding(ctx.mesh, ctx.spec(("slots", None), (slots, 1)))


def vec_sharding(ctx: ShardCtx, slots: int) -> NamedSharding:
    """Sharding for ``[slots]`` per-tick vectors (masks, draft lengths,
    sampled tokens, chosen-token logprobs)."""
    return NamedSharding(ctx.mesh, ctx.spec(("slots",), (slots,)))


def replicated(ctx: ShardCtx) -> NamedSharding:
    """Fully-replicated placement (scalars, small host-fed operands)."""
    return NamedSharding(ctx.mesh, PartitionSpec())


def shard_state(ctx: ShardCtx, state: Any, axes_tree: Any) -> Any:
    """Commit a concrete state pytree onto its serving shardings (used once
    at engine construction; every jitted step's ``out_shardings`` keeps it
    there afterwards)."""
    return jax.device_put(state, state_shardings(ctx, state, axes_tree))


def describe(ctx: ShardCtx, state: Any, axes_tree: Any) -> Dict[str, str]:
    """Human-readable placement summary (launcher/bench logging)."""
    shardings = state_shardings(ctx, state, axes_tree)
    flat, _ = jax.tree_util.tree_flatten_with_path(shardings)
    out = {}
    for path, s in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = str(s.spec)
    return out

"""Request-lifecycle tracing in the Chrome trace-event format.

One JSON event per line; the finished file is a valid JSON array that
loads directly in ``chrome://tracing`` or https://ui.perfetto.dev (both
also tolerate a truncated file from a crashed process, since each line
is a complete event).  Format reference:
https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

Layout convention used by :class:`repro.obs.Observability`:

* ``pid 0`` ("engine"): tick spans on ``tid 0``, device-step spans
  (decode / verify / prefill-chunk) on ``tid 1``, counter tracks and
  fault/snapshot instants on ``tid 0``.
* ``pid 1`` ("requests"): one row per request id with ``queued`` /
  ``prefill`` / ``decode`` spans and ``submit`` / ``finish:<reason>``
  instants.

Timestamps are **seconds in** (whatever clock the engine's scheduler
uses — ``time.monotonic`` in production, a fake in tests) and
microseconds-on-the-page out, rebased to the first event so traces start
at t=0.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, Optional

__all__ = ["TraceSink"]

PID_ENGINE = 0
PID_REQUESTS = 1


class TraceSink:
    """Append-only trace-event writer.  Thread-safe; cheap enough to call
    from the tick loop (one ``json.dumps`` + buffered write per event)."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh = open(path, "w")
        self._fh.write("[")
        self._lock = threading.Lock()
        self._t0: Optional[float] = None
        self._first = True
        self._closed = False
        self.events_written = 0

    def _us(self, t: float) -> float:
        if self._t0 is None:
            self._t0 = t
        return (t - self._t0) * 1e6

    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if self._closed:
                return
            self._fh.write("" if self._first else ",")
            self._fh.write("\n")
            json.dump(ev, self._fh, separators=(",", ":"))
            self._first = False
            self.events_written += 1

    # -- event kinds ----------------------------------------------------

    def complete(self, name: str, start: float, dur: float, *,
                 pid: int = PID_ENGINE, tid: int = 0, cat: str = "engine",
                 args: Optional[Dict[str, Any]] = None) -> None:
        """"X" span: ``start``/``dur`` in seconds."""
        ev: Dict[str, Any] = {"name": name, "ph": "X", "cat": cat,
                              "ts": self._us(start), "dur": dur * 1e6,
                              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, t: float, *, pid: int = PID_ENGINE,
                tid: int = 0, cat: str = "engine",
                args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {"name": name, "ph": "i", "s": "t",
                              "cat": cat, "ts": self._us(t),
                              "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self._emit(ev)

    def counter(self, name: str, t: float, values: Dict[str, float], *,
                pid: int = PID_ENGINE) -> None:
        """"C" track: Perfetto draws one stacked area chart per name."""
        self._emit({"name": name, "ph": "C", "ts": self._us(t),
                    "pid": pid, "tid": 0, "args": values})

    def process_name(self, pid: int, name: str) -> None:
        self._emit({"name": "process_name", "ph": "M", "pid": pid,
                    "tid": 0, "args": {"name": name}})

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        self._emit({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "args": {"name": name}})

    # -- lifecycle ------------------------------------------------------

    def flush(self) -> None:
        with self._lock:
            if not self._closed:
                self._fh.flush()

    def close(self) -> None:
        """Terminate the JSON array and close the file (idempotent)."""
        with self._lock:
            if self._closed:
                return
            self._fh.write("\n]\n")
            self._fh.close()
            self._closed = True

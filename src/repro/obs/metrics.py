"""Host-side metrics primitives: counters, gauges, latency histograms.

Everything in ``repro.obs`` is deliberately **pure stdlib Python** — no
numpy, no jax.  The package is registered in the jitlint scope
(``analysis/lint.py::JIT_MODULES``) so the host-sync / bare-assert rules
enforce that invariant mechanically: observability code can never grow a
device sync, because it never holds a device value in the first place.
The engine feeds it plain ints/floats/lists at the tick-boundary sync
point and nowhere else.

Percentiles
-----------

:class:`Histogram` keeps two views of the same stream:

* fixed cumulative buckets (Prometheus ``le`` semantics: a sample lands
  in every bucket whose upper bound is ``>= value``), cheap to export;
* the raw samples, so :meth:`Histogram.percentile` is **exact** — it
  reproduces ``numpy.percentile``'s default linear interpolation
  (``pos = (n-1) * q/100``) bit-for-bit, which the tests assert against
  a NumPy reference.  Past ``max_samples`` the raw view degrades to a
  deterministic reservoir (seeded ``random.Random``), so percentiles
  become approximate but the process stays O(1) memory and replayable.

:class:`RollingWindow` is the rolling-median live-rate idiom: push the
per-tick tokens/s, read the median — robust to the one slow tick that
would wreck a mean.
"""
from __future__ import annotations

import dataclasses
import math
import random
import re
import threading
from bisect import bisect_left, insort
from collections import deque
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "RollingWindow",
    "MetricsRegistry",
    "percentile",
    "percentile_summary",
    "DEFAULT_LATENCY_BUCKETS",
]

# Seconds.  Engine ticks on the reduced CPU configs sit in the 1ms-250ms
# band; real serving TTFTs reach seconds under overload.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def percentile(values: Sequence[float], q: float) -> float:
    """``numpy.percentile(values, q)`` (default linear interpolation),
    reimplemented in pure Python so jit-scope code never imports numpy.

    Raises ``ValueError`` on an empty sequence — callers that want a
    soft answer use :func:`percentile_summary` or
    :meth:`Histogram.percentile`, which return ``None`` instead.
    """
    if not values:
        raise ValueError("percentile() of empty sequence")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    s = sorted(values)
    n = len(s)
    if n == 1:
        return s[0]
    pos = (n - 1) * (q / 100.0)
    lo = int(math.floor(pos))
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    # numpy's _lerp flips the formula at frac >= 0.5 to keep the rounding
    # error symmetric; match it so the parity test is exact, not approx
    if frac >= 0.5:
        return s[hi] - (s[hi] - s[lo]) * (1.0 - frac)
    return s[lo] + (s[hi] - s[lo]) * frac


def percentile_summary(values: Iterable[Optional[float]],
                       qs: Sequence[float] = (50, 90, 99),
                       scale: float = 1.0) -> Dict[str, Any]:
    """Shared percentile report used by every ``bench_serving`` mode.

    Filters ``None`` entries (requests that shed before a first token
    have no TTFT), scales (e.g. ``scale=1e3`` for ms), and returns
    ``{"count": n, "p50": ..., "p99": ...}`` with ``None`` values when
    the stream is empty, so callers can always ``json.dump`` the result.
    """
    vals = [v for v in values if v is not None]
    out: Dict[str, Any] = {"count": len(vals)}
    for q in qs:
        key = f"p{q:g}"
        out[key] = percentile(vals, q) * scale if vals else None
    return out


class Counter:
    """Monotonic counter.  Single-writer (the engine tick loop) with
    lock-free reads from the exporter thread — a read races at worst into
    a one-update-stale value, never a torn one."""

    kind = "counter"
    __slots__ = ("_value",)

    def __init__(self) -> None:
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Instantaneous value (queue depth, free pages)."""

    kind = "gauge"
    __slots__ = ("_value",)

    def __init__(self) -> None:
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self._value += n

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket latency histogram with exact percentiles.

    ``bounds`` are the finite upper bucket edges; an implicit ``+Inf``
    bucket always closes the set.  ``observe`` is O(log buckets) plus an
    amortised O(1) reservoir update.
    """

    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
                 max_samples: int = 100_000, seed: int = 0) -> None:
        bounds = tuple(float(b) for b in buckets if math.isfinite(b))
        if not bounds:
            raise ValueError("histogram needs at least one finite bucket")
        if list(bounds) != sorted(set(bounds)):
            raise ValueError(
                f"histogram buckets must be strictly increasing: {bounds}")
        if max_samples < 1:
            raise ValueError(f"max_samples must be >= 1, got {max_samples}")
        self.bounds = bounds
        self._bucket_counts = [0] * (len(bounds) + 1)   # last = +Inf
        self._sum = 0.0
        self._count = 0
        self._samples: List[float] = []
        self._max_samples = max_samples
        self._rng = random.Random(seed)

    def observe(self, v: float) -> None:
        v = float(v)
        # Prometheus `le` semantics: first bound >= v owns the sample
        # (exact edge values land in the bucket they bound).
        self._bucket_counts[bisect_left(self.bounds, v)] += 1
        self._sum += v
        self._count += 1
        if len(self._samples) < self._max_samples:
            self._samples.append(v)
        else:  # Vitter reservoir: deterministic, uniform over the stream
            j = self._rng.randrange(self._count)
            if j < self._max_samples:
                self._samples[j] = v

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def exact(self) -> bool:
        """False once the reservoir has started dropping samples."""
        return self._count <= self._max_samples

    def percentile(self, q: float) -> Optional[float]:
        if not self._samples:
            return None
        return percentile(self._samples, q)

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """``[(le_bound, cumulative_count), ...]`` ending at
        ``(inf, count)`` — the Prometheus ``_bucket`` series."""
        out: List[Tuple[float, int]] = []
        acc = 0
        for bound, c in zip(self.bounds, self._bucket_counts):
            acc += c
            out.append((bound, acc))
        out.append((math.inf, self._count))
        return out

    def snapshot(self) -> Dict[str, Any]:
        return {
            "count": self._count,
            "sum": self._sum,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }


class RollingWindow:
    """Fixed-size window with O(log n) rolling median — the live-rate
    idiom: ``push(tokens/dur)`` each tick, report ``median()``."""

    def __init__(self, size: int = 64) -> None:
        if size < 1:
            raise ValueError(f"window size must be >= 1, got {size}")
        self._size = size
        self._window: Deque[float] = deque()
        self._sorted: List[float] = []

    def push(self, v: float) -> None:
        v = float(v)
        self._window.append(v)
        insort(self._sorted, v)
        if len(self._window) > self._size:
            old = self._window.popleft()
            del self._sorted[bisect_left(self._sorted, old)]

    def __len__(self) -> int:
        return len(self._window)

    def median(self) -> Optional[float]:
        s = self._sorted
        n = len(s)
        if n == 0:
            return None
        mid = n // 2
        return s[mid] if n % 2 else (s[mid - 1] + s[mid]) / 2.0

    def mean(self) -> Optional[float]:
        if not self._window:
            return None
        return sum(self._window) / len(self._window)


@dataclasses.dataclass
class Family:
    """One metric name: a kind, help text, and labelled series."""
    name: str
    kind: str
    help: str
    series: Dict[Tuple[Tuple[str, str], ...], Any]


class MetricsRegistry:
    """Name -> family -> labelled series store.

    ``counter`` / ``gauge`` / ``histogram`` are get-or-create: calling
    them again with the same name+labels returns the same object, so the
    engine can resolve series lazily (per finish reason, per fault site)
    without bookkeeping.  Registration takes a lock (exporter thread may
    be iterating); metric updates are plain attribute writes under the
    single-writer model.
    """

    def __init__(self) -> None:
        self._families: Dict[str, Family] = {}
        self._lock = threading.Lock()

    def _series(self, name: str, kind: str, help: str,
                labels: Dict[str, Any], ctor: Callable[[], Any]) -> Any:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name: {name!r}")
        for k in labels:
            if not _LABEL_RE.match(k):
                raise ValueError(f"invalid label name: {k!r}")
        key = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = Family(name, kind, help, {})
                self._families[name] = fam
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"not {kind}")
            if help and not fam.help:
                fam.help = help
            series = fam.series.get(key)
            if series is None:
                series = ctor()
                fam.series[key] = series
            return series

    def counter(self, name: str, help: str = "", **labels: Any) -> Counter:
        return self._series(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "", **labels: Any) -> Gauge:
        return self._series(name, "gauge", help, labels, Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
                  **labels: Any) -> Histogram:
        return self._series(name, "histogram", help, labels,
                            lambda: Histogram(buckets))

    def families(self) -> List[Family]:
        """Stable-ordered shallow copy for exporters."""
        with self._lock:
            return [dataclasses.replace(f, series=dict(f.series))
                    for f in sorted(self._families.values(),
                                    key=lambda f: f.name)]

    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{series_key: value}`` dict.  Counters/gauges map to a
        number; histograms to ``{count, sum, p50, p90, p99}``."""
        out: Dict[str, Any] = {}
        for fam in self.families():
            for key, series in sorted(fam.series.items()):
                label_s = ",".join(f'{k}="{v}"' for k, v in key)
                full = f"{fam.name}{{{label_s}}}" if label_s else fam.name
                if fam.kind == "histogram":
                    out[full] = series.snapshot()
                else:
                    out[full] = series.value
        return out

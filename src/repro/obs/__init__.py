"""``repro.obs`` — host-side, jit-invisible engine telemetry.

The serving engine takes an optional :class:`Observability` and calls
its hooks **only from host code at the tick-boundary sync point** (plus
the host-only submit/cancel paths).  Nothing in this package imports
numpy or jax — it is registered in the jitlint scope so that stays true
mechanically — and nothing it does can perturb the device program: the
jit manifest, trace counts, and token streams are identical with
observability on or off (``tests/test_obs.py`` asserts all three).

Components:

* :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
  exact-percentile latency histograms, ``registry.snapshot()`` dict.
* :class:`~repro.obs.trace.TraceSink` — request-lifecycle spans in the
  Chrome trace-event format (``chrome://tracing`` / Perfetto).
* :class:`~repro.obs.prometheus.MetricsServer` — background-thread
  ``/metrics`` scrape endpoint; :func:`~repro.obs.prometheus.render`
  for the text exposition itself.
* :class:`Observability` — the facade the engine is wired to.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .metrics import (
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    RollingWindow,
    percentile,
    percentile_summary,
)
from .prometheus import CONTENT_TYPE, MetricsServer, render
from .trace import PID_ENGINE, PID_REQUESTS, TraceSink

__all__ = [
    "Observability",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "RollingWindow",
    "percentile",
    "percentile_summary",
    "DEFAULT_LATENCY_BUCKETS",
    "TraceSink",
    "MetricsServer",
    "render",
    "CONTENT_TYPE",
    "PID_ENGINE",
    "PID_REQUESTS",
]


class Observability:
    """The engine-facing telemetry facade.

    Every hook is a handful of dict/float operations; the engine guards
    each call site with ``if self._obs is not None`` so the obs-off path
    does literally nothing.  Timestamps are whatever clock the engine's
    scheduler runs on (``time.monotonic`` by default, fakes in tests) —
    one timeline, never mixed.

    Monotonic external counters (the engine's ``fault_counters``, the
    allocator's eviction count, the spec accepted-length histogram) are
    *synced by delta* at each tick rather than incremented at their
    origin, so the engine's existing accounting stays the single source
    of truth and obs stays strictly read-only.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 trace_path: Optional[str] = None,
                 report_every: float = 0.0,
                 report_fn: Callable[[str], None] = print) -> None:
        self.registry = registry if registry is not None else MetricsRegistry()
        self.trace = TraceSink(trace_path) if trace_path else None
        self.report_every = report_every
        self._report_fn = report_fn
        self._last_report: Optional[float] = None

        r = self.registry
        self._ticks = r.counter(
            "repro_engine_ticks_total", "engine steps executed")
        self._tokens = r.counter(
            "repro_tokens_committed_total",
            "tokens committed across all requests (prefill first tokens "
            "and accepted speculative windows included)")
        self._submitted = r.counter(
            "repro_requests_submitted_total", "requests submitted")
        self._queue_depth = r.gauge(
            "repro_queue_depth", "requests waiting for a slot")
        self._active = r.gauge(
            "repro_active_slots", "slots holding a live request")
        self._slots = r.gauge("repro_slots_total", "pool slot count")
        self._free_pages = r.gauge(
            "repro_page_pool_free_blocks",
            "free + revivable physical pages (paged pool only)")
        self._phys = r.gauge(
            "repro_page_pool_blocks_total", "physical page count")
        self._trie_blocks = r.gauge(
            "repro_prefix_trie_blocks", "blocks content-addressed in the "
            "prefix trie")
        self._tick_h = r.histogram(
            "repro_tick_seconds", "engine step wall time")
        self._ttft_h = r.histogram(
            "repro_ttft_seconds", "time to first token (queue + prefill)")
        self._tpot_h = r.histogram(
            "repro_tpot_seconds", "per-output-token latency after the "
            "first token")
        self._queue_h = r.histogram(
            "repro_queue_time_seconds", "submit -> slot admission")
        self._prefill_h = r.histogram(
            "repro_prefill_time_seconds", "admission -> first token")
        self._e2e_h = r.histogram(
            "repro_e2e_seconds", "submit -> finish")
        # live tok/s: rolling median of per-tick committed/duration
        self.tok_rate = RollingWindow(64)
        # delta-sync state for external monotonic counters
        self._synced: Dict[Any, float] = {}
        self._last: Dict[str, float] = {}
        self._named_req_rows: set = set()
        if self.trace is not None:
            self.trace.process_name(PID_ENGINE, "engine")
            self.trace.thread_name(PID_ENGINE, 0, "ticks")
            self.trace.thread_name(PID_ENGINE, 1, "device steps")
            self.trace.process_name(PID_REQUESTS, "requests")

    # -- delta sync -----------------------------------------------------

    def _sync_counter(self, name: str, help: str, value: float,
                      **labels: Any) -> None:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        last = self._synced.get(key, 0.0)
        if value > last:
            self.registry.counter(name, help, **labels).inc(value - last)
            self._synced[key] = value
        elif value < last:      # source reset (fresh engine on one obs)
            self._synced[key] = value

    # -- request lifecycle ----------------------------------------------

    def request_submitted(self, rid: int, prompt_len: int,
                          now: float,
                          queue_depth: Optional[int] = None) -> None:
        self._submitted.inc()
        if queue_depth is not None:
            # submit-path refresh: the asyncio frontend submits between
            # ticks, where obs.tick cannot see a shed leave the gauge
            # stale; the engine passes the post-submit depth (a shed never
            # entered the queue, so the gauge and the shed counter agree)
            self._queue_depth.set(queue_depth)
        if self.trace is not None:
            if rid not in self._named_req_rows:
                self._named_req_rows.add(rid)
                self.trace.thread_name(PID_REQUESTS, rid, f"req {rid}")
            self.trace.instant("submit", now, pid=PID_REQUESTS, tid=rid,
                               cat="request", args={"prompt_len": prompt_len})

    def request_finished(self, out: Any, now: float) -> None:
        """``out`` is a ``RequestOutput`` (duck-typed: request_id,
        finish_reason, token_ids, metrics)."""
        m = out.metrics
        reason = out.finish_reason or "unknown"
        self.registry.counter(
            "repro_requests_finished_total", "finished requests by reason",
            reason=reason).inc()
        for h, v in ((self._ttft_h, m.ttft), (self._tpot_h, m.tpot),
                     (self._queue_h, m.queue_time),
                     (self._prefill_h, m.prefill_time),
                     (self._e2e_h, m.e2e_latency)):
            if v is not None:
                h.observe(v)
        if self.trace is None:
            return
        rid = out.request_id
        if rid not in self._named_req_rows:
            self._named_req_rows.add(rid)
            self.trace.thread_name(PID_REQUESTS, rid, f"req {rid}")
        end = m.finished_time if m.finished_time is not None else now
        admitted = m.admitted_time
        first = m.first_token_time
        if admitted is not None:
            self.trace.complete("queued", m.arrival_time,
                                admitted - m.arrival_time,
                                pid=PID_REQUESTS, tid=rid, cat="request")
        elif end > m.arrival_time:   # died in the queue (shed/timeout)
            self.trace.complete("queued", m.arrival_time,
                                end - m.arrival_time,
                                pid=PID_REQUESTS, tid=rid, cat="request")
        if admitted is not None and first is not None:
            self.trace.complete("prefill", admitted, first - admitted,
                                pid=PID_REQUESTS, tid=rid, cat="request")
        if first is not None:
            self.trace.complete("decode", first, end - first,
                                pid=PID_REQUESTS, tid=rid, cat="request",
                                args={"tokens": len(out.token_ids)})
        self.trace.instant(f"finish:{reason}", end, pid=PID_REQUESTS,
                           tid=rid, cat="request",
                           args={"tokens": len(out.token_ids)})

    # -- engine step internals -------------------------------------------

    def prefill_chunk(self, rid: int, slot: int, start: float, dur: float,
                      n_tokens: int, final: bool) -> None:
        self.registry.histogram(
            "repro_prefill_chunk_seconds",
            "one chunked-prefill host dispatch (the final chunk includes "
            "the first-token sync)").observe(dur)
        if self.trace is not None:
            self.trace.complete("prefill_chunk", start, dur, pid=PID_ENGINE,
                                tid=1, cat="device",
                                args={"rid": rid, "slot": slot,
                                      "tokens": n_tokens, "final": final})

    def decode_tick(self, start: float, dur: float, n_slots: int,
                    spec: bool, overlapped: bool = False) -> None:
        """One decode/verify window's device span.  Under the overlapped
        engine the span runs dispatch -> the one-tick-DELAYED sync, so it
        reflects true pipelined wall clock (host work only shows where it
        failed to hide behind the device); the metric keeps its mode label
        and the trace event gains an ``overlapped`` arg."""
        mode = "spec" if spec else "plain"
        self.registry.histogram(
            "repro_decode_tick_seconds",
            "decode dispatch through the token sync", mode=mode).observe(dur)
        if self.trace is not None:
            args: Dict[str, Any] = {"slots": n_slots}
            if overlapped:
                args["overlapped"] = True
            self.trace.complete("verify" if spec else "decode", start, dur,
                                pid=PID_ENGINE, tid=1, cat="device",
                                args=args)

    def prefix_match(self, hit_blocks: int, lookup_blocks: int) -> None:
        self.registry.counter(
            "repro_trie_hit_blocks_total",
            "prompt blocks served from the prefix trie").inc(hit_blocks)
        self.registry.counter(
            "repro_trie_lookup_blocks_total",
            "prompt blocks probed against the prefix trie"
        ).inc(lookup_blocks)
        if hit_blocks > 0:
            self.registry.counter(
                "repro_trie_hit_admissions_total",
                "admissions that reused at least one page").inc()

    def fault(self, site: str, tick: int, now: float) -> None:
        self.registry.counter(
            "repro_fault_injections_total",
            "seeded fault-plan firings by site", site=site).inc()
        if self.trace is not None:
            self.trace.instant(f"fault:{site}", now, pid=PID_ENGINE, tid=0,
                               cat="fault", args={"tick": tick})

    def snapshot_event(self, kind: str, start: float, dur: float,
                       pages: int) -> None:
        self.registry.counter(
            "repro_snapshots_total", "snapshot operations by kind",
            kind=kind).inc()
        if self.trace is not None:
            self.trace.complete(f"snapshot:{kind}", start, dur,
                                pid=PID_ENGINE, tid=0, cat="snapshot",
                                args={"pages": pages})

    # -- the tick-boundary sync point ------------------------------------

    def tick(self, *, start: float, now: float, tick_no: int, committed: int,
             queue_depth: int, active: int, slots: int,
             counters: Dict[str, int],
             free_blocks: Optional[int] = None, n_phys: int = 0,
             evictions: int = 0, trie_blocks: int = 0,
             spec_hist: Optional[Sequence[int]] = None) -> None:
        """Called once per engine step, after the step's releases flush.
        All arguments are plain host ints/floats/lists."""
        dur = now - start
        self._ticks.inc()
        self._tokens.inc(committed)
        self._tick_h.observe(dur)
        self._queue_depth.set(queue_depth)
        self._active.set(active)
        self._slots.set(slots)
        self._trie_blocks.set(trie_blocks)
        if free_blocks is not None:
            self._free_pages.set(free_blocks)
            self._phys.set(n_phys)
        if dur > 0 and committed > 0:
            self.tok_rate.push(committed / dur)
        for event, value in counters.items():
            self._sync_counter(
                "repro_lifecycle_events_total",
                "request-lifecycle / fault-tolerance events by kind",
                float(value), event=event)
        self._sync_counter(
            "repro_page_evictions_total",
            "LRU evictions of revivable pages", float(evictions))
        if spec_hist is not None:
            for accepted, windows in enumerate(spec_hist):
                if windows:
                    self._sync_counter(
                        "repro_spec_windows_total",
                        "speculative verify windows by accepted draft count",
                        float(windows), accepted=str(accepted))
        if self.trace is not None:
            self.trace.complete("tick", start, dur, pid=PID_ENGINE, tid=0,
                                cat="tick", args={"n": tick_no,
                                                  "committed": committed})
            track = {"queue": queue_depth, "active": active}
            if free_blocks is not None:
                track["free_pages"] = free_blocks
            self.trace.counter("engine_load", now, track)
        self._maybe_report(now)

    def _maybe_report(self, now: float) -> None:
        if not self.report_every:
            return
        if (self._last_report is not None
                and now - self._last_report < self.report_every):
            return
        self._last_report = now
        self._report_fn(self.report_line())

    def report_line(self) -> str:
        """The periodic one-line stdout report.

        All values here are plain Python floats (this package never holds
        a device value); ``:.0f`` formatting keeps the jitlint host-sync
        rule's ``int()`` heuristic trivially quiet.
        """
        rate = self.tok_rate.median()
        lifecycle = {
            k: self._synced.get(
                ("repro_lifecycle_events_total", (("event", k),)), 0)
            for k in ("shed", "timeout", "cancelled")}
        parts = [
            f"ticks={self._ticks.value:.0f}",
            f"tok={self._tokens.value:.0f}",
            f"tok/s~{rate:.1f}" if rate is not None else "tok/s~n/a",
            f"queue={self._queue_depth.value:.0f}",
            f"active={self._active.value:.0f}/{self._slots.value:.0f}",
            f"shed={lifecycle['shed']:.0f}",
            f"timeout={lifecycle['timeout']:.0f}",
            f"cancelled={lifecycle['cancelled']:.0f}",
        ]
        if self._phys.value:
            parts.append(f"pages={self._free_pages.value:.0f}/"
                         f"{self._phys.value:.0f}")
        return "[obs] " + " ".join(parts)

    def snapshot(self) -> Dict[str, Any]:
        return self.registry.snapshot()

    def close(self) -> None:
        if self.trace is not None:
            self.trace.close()

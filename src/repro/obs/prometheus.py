"""Prometheus text exposition (format 0.0.4) + a background scrape server.

``render`` turns a :class:`~repro.obs.metrics.MetricsRegistry` into the
plaintext format; :class:`MetricsServer` serves it from a daemon thread
on ``GET /metrics`` so a live engine can be scraped (or curl'd) without
touching the tick loop.  Stdlib ``http.server`` only — no new deps.
"""
from __future__ import annotations

import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Tuple

from .metrics import MetricsRegistry

__all__ = ["render", "MetricsServer", "CONTENT_TYPE"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _fmt_num(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    body = ",".join(
        '{}="{}"'.format(k, v.replace("\\", r"\\").replace('"', r"\"")
                         .replace("\n", r"\n"))
        for k, v in key)
    return "{" + body + "}"


def render(registry: MetricsRegistry) -> str:
    """Registry -> Prometheus plaintext exposition."""
    lines = []
    for fam in registry.families():
        if fam.help:
            lines.append(f"# HELP {fam.name} {fam.help}")
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for key, series in sorted(fam.series.items()):
            lbl = _fmt_labels(key)
            if fam.kind == "histogram":
                for bound, cum in series.cumulative_buckets():
                    bkey = key + (("le", _fmt_num(bound)),)
                    lines.append(
                        f"{fam.name}_bucket{_fmt_labels(bkey)} {cum}")
                lines.append(f"{fam.name}_sum{lbl} {_fmt_num(series.sum)}")
                lines.append(f"{fam.name}_count{lbl} {series.count}")
            else:
                lines.append(f"{fam.name}{lbl} {_fmt_num(series.value)}")
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Daemon-thread HTTP server exposing ``/metrics``.

    ``port=0`` binds an ephemeral port; read it back from ``.port`` (the
    tests and ``serve --metrics-port 0`` both do).
    """

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "127.0.0.1") -> None:
        self.registry = registry

        outer = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 (http.server API)
                if self.path.split("?", 1)[0] not in ("/metrics", "/"):
                    self.send_error(404)
                    return
                body = render(outer.registry).encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args) -> None:
                pass  # scrapes must not spam the serving stdout

        self._server = ThreadingHTTPServer((host, port), _Handler)
        self.host = host
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="metrics-exporter",
            daemon=True)
        self._started = False

    def start(self) -> "MetricsServer":
        if not self._started:
            self._thread.start()
            self._started = True
        return self

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        if self._started:
            self._server.shutdown()
            self._thread.join(timeout=5.0)
            self._started = False
        self._server.server_close()

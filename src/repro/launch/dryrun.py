import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DEVICES", "512"))
# ^ MUST precede any jax import: jax locks the device count on first init.

__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  * builds abstract params (dense for train; paper-sparse for decode),
    abstract optimizer state / KV caches, and ShapeDtypeStruct inputs;
  * jit-lowers the step function with explicit in/out shardings over the
    production mesh (16x16 single pod / 2x16x16 multi-pod);
  * ``.compile()``s — proving the sharding/collective schedule is coherent;
  * records ``memory_analysis()`` (fits-or-not per device),
    ``cost_analysis()`` (FLOPs / bytes for §Roofline), and the collective
    operand bytes parsed from the optimized HLO.

Results land in ``experiments/dryrun/<cell>.json`` for the roofline tooling.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape decode_32k [--multipod] [--mode paper|dense] [--out DIR]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig,
                           applicable_shapes, get_config)
from repro.distributed import (ShardCtx, default_rules, tree_param_specs,
                               to_named)
from repro.distributed.convert_plan import convert_abstract
from repro.models import lm
from repro.models import module as mod
from repro.optim import OptConfig, abstract_opt_state
from repro.train import make_train_step
from .mesh import make_production_mesh

COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u32|s16|u16|s8|u8|pred)"
                      r"\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
               "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        return {"tokens": sds((b, 1), jnp.int32)}
    batch: Dict[str, Any] = {
        "tokens": sds((b, s), jnp.int32),
        "labels": sds((b, s), jnp.int32),
        "mask": sds((b, s), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["src_embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend:
        f = cfg.frontend_tokens
        batch["tokens"] = sds((b, s - f), jnp.int32)
        batch["labels"] = sds((b, s - f), jnp.int32)
        batch["mask"] = sds((b, s - f), jnp.float32)
        batch["frontend_embeds"] = sds((b, f, cfg.d_model), jnp.bfloat16)
    if shape.kind == "prefill":
        batch = {k: batch[k] for k in batch if k not in ("labels", "mask")}
    return batch


def batch_shardings(ctx: ShardCtx, batch: Dict[str, Any]) -> Dict[str, Any]:
    def one(leaf):
        axes = ["batch"] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(ctx.mesh, ctx.spec(axes, leaf.shape))
    return jax.tree_util.tree_map(one, batch)


def cache_shardings(ctx: ShardCtx, cache: Any, cfg) -> Any:
    """Shard the decode cache: sparse-prefix block axes over data ('ctx'),
    batch dims over dp, kv-head dims over model where divisible.

    Type-driven (custom pytree nodes don't expose field names in paths):
    all cache leaves carry a leading stacked-period dim.
    """
    from repro.core.sparse_format import BlockSparseWeight
    from repro.core.sparse_kv import SparseKVCache
    from repro.models.attention import DenseKVCache
    mesh = ctx.mesh
    N = lambda axes, shp: NamedSharding(mesh, ctx.spec(axes, shp))

    def sparse_w(sw: BlockSparseWeight) -> BlockSparseWeight:
        if sw.bitmap.ndim == 6:   # stacked structured [P,B,Hkv,Sb,1,W]
            axes = (None, "batch", "kv_heads", "ctx", None, None)
        else:                     # stacked flat [P,(B*Hkv*Sb),1,W]
            axes = (None, "ctx", None, None)
        s3 = N(axes, sw.bitmap.shape)
        return BlockSparseWeight(
            bitmap=s3, values=N(axes, sw.values.shape),
            scale=None if sw.scale is None else NamedSharding(mesh, P()),
            shape=sw.shape, block=sw.block, packed4=sw.packed4)

    def tail(t):
        return N((None, "batch", "kv_heads", None, None), t.shape)

    def one(leaf):
        if isinstance(leaf, SparseKVCache):
            return SparseKVCache(
                k_sp=sparse_w(leaf.k_sp), v_sp=sparse_w(leaf.v_sp),
                k_tail=tail(leaf.k_tail), v_tail=tail(leaf.v_tail),
                tail_len=NamedSharding(mesh, P()))
        if isinstance(leaf, DenseKVCache):
            kv = N((None, "batch", "kv_heads", "ctx", None), leaf.k.shape)
            return DenseKVCache(kv, kv, NamedSharding(mesh, P()))
        # plain array leaf (recurrent state, cross kv, pos counter)
        shp = leaf.shape
        if len(shp) == 0:
            return NamedSharding(mesh, P())
        if len(shp) == 5:     # stacked dense/cross KV [P,B,Hkv,S,hd]
            return N((None, "batch", "kv_heads", "ctx", None), shp)
        axes = (None, "batch") + (None,) * (len(shp) - 2)
        return N(axes[: len(shp)], shp)

    return jax.tree_util.tree_map(
        one, cache,
        is_leaf=lambda x: isinstance(x, (SparseKVCache, DenseKVCache)))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train(cfg, ctx, shape):
    specs = lm.model_specs(cfg)
    params = mod.abstract(specs)
    opt = abstract_opt_state(params)
    batch = input_specs(cfg, shape)
    pspecs = tree_param_specs(ctx, specs, params)
    p_shard = to_named(ctx, pspecs)
    from repro.distributed.sharding import zero1_specs
    o_shard = {
        "step": NamedSharding(ctx.mesh, P()),
        "master": to_named(ctx, zero1_specs(pspecs, params, cfg, ctx)),
        "m": to_named(ctx, zero1_specs(pspecs, params, cfg, ctx)),
        "v": to_named(ctx, zero1_specs(pspecs, params, cfg, ctx)),
    }
    b_shard = batch_shardings(ctx, batch)
    step = make_train_step(cfg, ctx, OptConfig())
    met = {"loss": NamedSharding(ctx.mesh, P()),
           "lr": NamedSharding(ctx.mesh, P()),
           "grad_norm": NamedSharding(ctx.mesh, P())}
    fn = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                 out_shardings=(p_shard, o_shard, met))
    return fn, (params, opt, batch)


def build_prefill(cfg, ctx, shape):
    specs = lm.model_specs(cfg)
    params = mod.abstract(specs)
    batch = input_specs(cfg, shape)
    p_shard = to_named(ctx, tree_param_specs(ctx, specs, params))
    b_shard = batch_shardings(ctx, batch)
    fn = jax.jit(lambda p, b: lm.forward_prefill(p, b, cfg, ctx),
                 in_shardings=(p_shard, b_shard))
    return fn, (params, batch)


def build_decode(cfg, ctx, shape, mode: str = "paper"):
    specs = lm.model_specs(cfg)
    params = mod.abstract(specs)
    if mode in ("paper", "int8"):
        params = convert_abstract(params, specs, cfg, ctx,
                                  mode="bf16" if mode == "paper" else "int8")
    cache = lm.init_cache(cfg, shape.global_batch, shape.seq_len,
                          mode="dense" if mode == "dense" else "sparse",
                          abstract=True)
    tokens = input_specs(cfg, shape)["tokens"]
    p_shard = to_named(ctx, tree_param_specs(ctx, specs, params))
    c_shard = cache_shardings(ctx, cache, cfg)
    t_shard = NamedSharding(ctx.mesh, ctx.spec(("batch", None),
                                               tokens.shape))
    logit_shard = NamedSharding(ctx.mesh, ctx.spec(
        ("batch", "vocab"), (shape.global_batch, cfg.vocab)))
    fn = jax.jit(lambda p, c, t: lm.forward_decode(p, c, t, cfg, ctx),
                 in_shardings=(p_shard, c_shard, t_shard),
                 out_shardings=(logit_shard, c_shard))
    return fn, (params, cache, tokens)


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def collective_bytes(hlo: str) -> Dict[str, float]:
    """Per-collective *result* bytes from the optimized (partitioned) HLO.

    Post-optimization operands are %refs without shapes, so we account each
    collective by its per-device result shape (LHS).  The roofline layer
    applies op-specific wire factors (all-reduce moves ~2x its result in a
    ring; all-gather's result ≈ bytes received).  `-start` async forms are
    counted once; `-done` carries the same shape and is skipped.
    """
    out: Dict[str, float] = {}
    for line in hlo.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "= " not in line or "-done" in line:
            continue
        op = m.group(1)
        lhs = line.split("= ", 1)[1] if " = " in line else line
        sm = SHAPE_RE.search(lhs)
        if not sm:
            continue
        dt, dims = sm.group(1), sm.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = n * DTYPE_BYTES[dt]
        out[op] = out.get(op, 0) + nbytes
        out["total"] = out.get("total", 0) + nbytes
        out[op + "_count"] = out.get(op + "_count", 0) + 1
    return out


OPTS = {
    # §Perf optimization knobs (see EXPERIMENTS.md §Perf):
    "cp": {"cp_decode": True},            # context-parallel decode attention
    "ep": {"ep_moe": True},               # expert-parallel MoE
    "tpweights": {"serve_fsdp": False},   # serving weights TP-resident
    "triangular": {"attn_impl": "triangular"},  # causal-optimal flash
    "flashtrain": {"full_attn_max": 2048,       # blocked flash at 4k train
                   "attn_impl": "triangular"},
    "nosp": {"seq_shard": False},
    "sp": {"seq_shard": True},
    "nofsdp": {"fsdp": False},
    "noremat": {"remat": False},
}


def apply_opts(cfg, opts: str):
    import dataclasses as _dc
    for o in [o for o in (opts or "").split(",") if o]:
        cfg = _dc.replace(cfg, **OPTS[o])
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             mode: str = "paper", out_dir: str = "experiments/dryrun",
             opts: str = "", tag: str = "") -> Dict[str, Any]:
    cfg = apply_opts(get_config(arch), opts)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod, cfg)
    if shape.kind == "decode" and not cfg.serve_fsdp:
        rules["embed"] = None               # weights stay TP-resident
    ctx = ShardCtx(mesh, rules)

    t0 = time.time()
    if shape.kind == "train":
        fn, args = build_train(cfg, ctx, shape)
    elif shape.kind == "prefill":
        fn, args = build_prefill(cfg, ctx, shape)
    else:
        fn, args = build_decode(cfg, ctx, shape, mode)

    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax 0.4.x: one dict per program
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_rec[attr] = int(v)

    rec = {
        "arch": arch, "shape": shape_name, "mode": mode, "opts": opts,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(mesh.devices.shape)),
        "flops": float(cost.get("flops", -1)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1))
        if cost else -1.0,
        "collective_bytes": coll,
        "memory": mem_rec,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_bytes": len(hlo),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}_{shape_name}_{rec['mesh']}_{mode}"
        if tag:
            name += f"_{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["llama3-8b"], default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mode", choices=["paper", "int8", "dense"],
                    default="paper")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", default="", help="comma list of OPTS keys")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh, args.multipod, args.mode))
    else:
        assert args.arch and args.shape
        cells.append((args.arch, args.shape, args.multipod, args.mode))

    failures = 0
    for arch, sh, mp, mode in cells:
        print(f"=== {arch} x {sh} mesh={'2x16x16' if mp else '16x16'} "
              f"mode={mode} opts={args.opt} ===", flush=True)
        try:
            rec = run_cell(arch, sh, mp, mode, args.out, opts=args.opt,
                           tag=args.tag)
            print(json.dumps(rec, indent=1), flush=True)
        except Exception as e:
            failures += 1
            import traceback
            print(f"CELL FAILED: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"{failures} cell(s) FAILED", flush=True)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

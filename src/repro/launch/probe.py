import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DEVICES", "512"))
# ^ MUST precede any jax import (same contract as dryrun.py).

__doc__ = """Per-period compiled probes for the roofline analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
EXPERIMENTS.md §Methodology), so whole-program numbers undercount scanned
layer stacks by ~n_periods.  The roofline therefore decomposes:

    total ≈ whole_program + (n_periods - 1) x period_probe + corrections

where ``period_probe`` lowers + compiles EXACTLY one period of the model
(fwd for prefill/decode, fwd+vjp for train) under the same mesh/shardings,
and ``corrections`` are closed-form terms for compute that hides inside
*inner* scans even in the probe (SSM recurrences over seq; blocked-flash
attention at 32k) — see benchmarks/roofline.py.

Outputs experiments/probes/<cell>.json.
"""

import argparse
import json
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, applicable_shapes, get_config
from repro.distributed import (ShardCtx, default_rules, tree_param_specs,
                               to_named)
from repro.distributed.convert_plan import convert_abstract
from repro.models import lm
from repro.models import module as mod
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import collective_bytes


def _period_specs(cfg):
    p = lm.period_len(cfg)
    kinds = [lm.layer_kind(cfg, j) for j in range(p)]
    cross = cfg.family == "encdec"
    return {f"l{j}": lm._block_specs(cfg, kinds[j], cross=cross)
            for j in range(p)}, kinds


def build_period_probe(cfg, ctx, shape, mode: str = "paper"):
    """One-period step function + abstract args + shardings."""
    specs, kinds = _period_specs(cfg)
    params = mod.abstract(specs)
    train = shape.kind == "train"
    decode = shape.kind == "decode"
    if decode and mode in ("paper", "int8"):
        params = convert_abstract(params, specs, cfg, ctx,
                                  mode="bf16" if mode == "paper" else "int8")
    p_shard = to_named(ctx, tree_param_specs(ctx, specs, params))

    b = shape.global_batch
    d = cfg.d_model
    sds = jax.ShapeDtypeStruct

    if decode:
        cache_full = lm.init_cache(cfg, b, shape.seq_len,
                                   mode="dense" if mode == "dense" else "sparse",
                                   abstract=True)
        cache = jax.tree_util.tree_map(
            lambda s: sds(s.shape[1:], s.dtype)
            if s.shape and s.shape[0] == cfg.n_layers // lm.period_len(cfg)
            else s,
            cache_full["layers"],
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        from repro.launch.dryrun import cache_shardings

        def strip(ns):
            spec = ns.spec
            return NamedSharding(ctx.mesh, P(*spec[1:])) \
                if len(spec) == len(ns.spec) and len(spec) > 0 else ns
        c_shard_full = cache_shardings(ctx, cache_full["layers"], cfg)
        c_shard = jax.tree_util.tree_map(
            lambda ns: NamedSharding(ctx.mesh, P(*ns.spec[1:]))
            if len(ns.spec) > 0 else ns, c_shard_full,
            is_leaf=lambda x: isinstance(x, NamedSharding))
        x_t = sds((b, d), cfg.cdtype)
        x_shard = NamedSharding(ctx.mesh, ctx.spec(("batch", None), (b, d)))
        pos = sds((), jnp.int32)
        cross_kv = None
        if cfg.family == "encdec":
            kv = sds((b, cfg.n_kv, shape.seq_len, cfg.hd), cfg.cdtype)
            cross_kv = {"k": kv, "v": kv}

        def fn(pp, cc, x, position):
            for j, kind in enumerate(kinds):
                ck = None
                x, cc[f"l{j}"] = lm._sublayer_decode(
                    x, pp[f"l{j}"], cc[f"l{j}"], kind, cfg, ctx, position,
                    ck)
            return x, cc

        jfn = jax.jit(fn, in_shardings=(p_shard, c_shard, x_shard, None))
        return jfn, (params, cache, x_t, pos), None

    s = shape.seq_len
    x = sds((b, s, d), cfg.cdtype)
    x_shard = NamedSharding(ctx.mesh,
                            ctx.spec(("batch", "seq", None), (b, s, d)))
    positions = jnp.arange(s)
    memory = None

    def fwd(pp, xx):
        for j, kind in enumerate(kinds):
            xx = lm._sublayer(xx, pp[f"l{j}"], kind, cfg, ctx, positions,
                              memory, "masked")
        return xx

    if train:
        def fn(pp, xx, dy):
            y, vjp = jax.vjp(fwd, pp, xx)
            return vjp(dy)
        jfn = jax.jit(fn, in_shardings=(p_shard, x_shard, x_shard))
        jfwd = jax.jit(fwd, in_shardings=(p_shard, x_shard))
        return jfn, (params, x, x), (jfwd, (params, x))
    jfn = jax.jit(fwd, in_shardings=(p_shard, x_shard))
    return jfn, (params, x), None


def run_probe(arch: str, shape_name: str, multi_pod: bool = False,
              mode: str = "paper", out_dir: str = "experiments/probes",
              tag: str = "", opts: str = "") -> Dict[str, Any]:
    from repro.launch.dryrun import apply_opts
    cfg = apply_opts(get_config(arch), opts)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(multi_pod, cfg)
    if shape.kind == "decode" and not cfg.serve_fsdp:
        rules["embed"] = None
    ctx = ShardCtx(mesh, rules)
    t0 = time.time()
    fn, args, fwd_probe = build_period_probe(cfg, ctx, shape, mode)
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    fwd_cost = None
    if fwd_probe is not None:
        jfwd, fargs = fwd_probe
        with mesh:
            fwd_cost = jfwd.lower(*fargs).compile().cost_analysis()
    rec = {
        "arch": arch, "shape": shape_name, "mode": mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": "period_probe",
        "n_periods": cfg.n_layers // lm.period_len(cfg),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "elapsed_s": round(time.time() - t0, 1),
    }
    if fwd_cost is not None:
        rec["flops_fwd"] = float(fwd_cost.get("flops", -1))
        rec["bytes_fwd"] = float(fwd_cost.get("bytes accessed", -1))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}_{shape_name}_{rec['mesh']}_{mode}"
        if tag:
            name += f"_{tag}"
        with open(os.path.join(out_dir, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mode", choices=["paper", "int8", "dense"],
                    default="paper")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/probes")
    ap.add_argument("--opt", default="")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sh in applicable_shapes(get_config(arch)):
                cells.append((arch, sh))
    else:
        cells.append((args.arch, args.shape))
    for arch, sh in cells:
        print(f"=== probe {arch} x {sh} mode={args.mode} "
              f"opts={args.opt} ===", flush=True)
        try:
            rec = run_probe(arch, sh, args.multipod, args.mode, args.out,
                            args.tag, opts=args.opt)
            print(json.dumps(rec), flush=True)
        except Exception as e:
            print(f"PROBE FAILED: {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()

"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization and only then calls these.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model) — the `pod` axis
    is the DCN-crossing outer data-parallel axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests, elastic restore onto different topologies)."""
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1) -> Optional[Mesh]:
    """Mesh over however many devices the test process has."""
    n = len(jax.devices())
    if data * model > n:
        return None
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))

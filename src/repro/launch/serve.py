"""Serving launcher: sparse-weight + sparse-KV decode with batched requests.

Demonstrates the paper's full inference path at CPU scale: init (or load) a
model, convert linear layers to the compressed sparse format, prefill a
batch of prompts, freeze the cache, and decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --batch 4 --prompt-len 64 --steps 16 --sparsity 0.5 [--int8]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, host_batch
from repro.distributed import ShardCtx, NULL_CTX, default_rules
from repro.distributed.convert_plan import convert_concrete
from repro.models import lm
from repro.serving import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--int8", action="store_true")
    ap.add_argument("--dense", action="store_true",
                    help="baseline: dense weights + dense KV")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, sparsity=args.sparsity)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if not args.dense:
        specs = lm.model_specs(cfg)
        params = convert_concrete(params, specs, cfg, NULL_CTX,
                                  mode="int8" if args.int8 else "bf16")
        from repro.core import sparsity_report
        rep = sparsity_report(params)
        tot_d = sum(r["dense_bytes"] for r in rep.values())
        tot_c = sum(r["compressed_bytes"] for r in rep.values())
        print(f"[serve] sparse-converted {len(rep)} weights: "
              f"{tot_d/1e6:.1f}MB -> {tot_c/1e6:.1f}MB "
              f"({tot_c/tot_d:.3f}x)")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                    global_batch=args.batch)
    prompts = jnp.asarray(host_batch(dc, 0)["tokens"])
    batch = {"tokens": prompts}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.zeros(
            (args.batch, args.prompt_len, cfg.d_model), jnp.float32)
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.zeros(
            (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)

    eng = Engine(params, cfg,
                 kv_mode="dense" if args.dense else "sparse")
    t0 = time.time()
    toks, _ = eng.generate(batch, steps=args.steps)
    dt = time.time() - t0
    print(f"[serve] generated {args.steps} tokens x {args.batch} reqs "
          f"in {dt:.2f}s ({args.steps*args.batch/dt:.1f} tok/s)")
    print("[serve] sample:", np.asarray(toks)[0][:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Serving launcher: sparse-weight + sparse-KV decode over streamed requests.

Demonstrates the paper's full inference path at serving scale: init (or
load) a model, convert linear layers to the compressed sparse format, then
either

* **stream mode (default)** — drive the continuous-batching
  ``ContinuousEngine``: a Poisson-ish stream of requests with mixed prompt
  and output lengths flows through the pooled sparse-KV cache (chunked
  prefill interleaved with decode, slot recycling, zero decode retraces);
* ``--one-shot`` — the legacy static-batch ``Engine`` (prefill the whole
  batch, decode lockstep), kept as the baseline.

Stream mode runs with **overlapped (double-buffered) ticks** by default:
tick t+1 is dispatched into JAX's async stream before tick t's tokens
are synced to host, hiding the host/dispatch gap behind device compute.
``--no-overlap`` restores the serial loop — it is the token-identity
oracle (greedy and seeded output are identical either way).  ``--server``
swaps the synthetic request wave for an asyncio HTTP frontend with
per-request NDJSON streaming (``repro.serving.frontend``).

``--mesh DP,TP`` serves the stream on a device mesh: the pooled state
shards slots over the data axis and KV heads over the model axis
(``repro.distributed.serving_sharding``) with token-identical greedy
output; ``--spec-k K`` adds draft–verify speculation (``--spec-adaptive``
for per-slot adaptive draft windows).

Fault-tolerant serving knobs: ``--max-queue`` (bounded admission with
load shedding), ``--deadline`` / ``--ttft-deadline`` (per-request
wall-clock budgets), ``--degrade-queue`` (drop spec drafting under
pressure), and ``--snapshot-dir`` (with ``--paged``: restore the prefix
cache on start, snapshot it when the stream drains — a restarted server
resumes at full cache-hit rate).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 8 --slots 4 --prompt-len 64 --steps 16 --sparsity 0.5
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 8 --slots 8 --mesh 4,2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, host_batch
from repro.distributed import ShardCtx, NULL_CTX, default_rules
from repro.distributed.convert_plan import convert_concrete
from repro.models import lm
from repro.serving import (Engine, ContinuousEngine, SamplingParams,
                           SpecConfig, stable_trace_counts)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16,
                    help="max_new_tokens per request")
    ap.add_argument("--sparsity", type=float, default=0.5)
    ap.add_argument("--int8", action="store_true")
    ap.add_argument("--dense", action="store_true",
                    help="baseline: dense weights + dense KV")
    ap.add_argument("--one-shot", action="store_true",
                    help="legacy static-batch engine instead of the "
                         "continuous-batching stream")
    ap.add_argument("--requests", type=int, default=0,
                    help="stream mode: number of requests (default: batch)")
    ap.add_argument("--slots", type=int, default=0,
                    help="stream mode: cache-pool slots (default: batch)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="stream mode: prompt tokens prefilled per tick")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="stream mode: speculative decoding — verify up "
                         "to K n-gram draft tokens per slot per tick "
                         "(0 = off; greedy output is token-identical "
                         "either way)")
    ap.add_argument("--spec-adaptive", action="store_true",
                    help="with --spec-k: per-slot adaptive draft windows "
                         "(each slot's acceptance rate scales its K)")
    ap.add_argument("--paged", action="store_true",
                    help="stream mode: paged shared-prefix pool — "
                         "compressed blocks live once in a pool-global "
                         "arena behind per-slot block tables; prompts "
                         "sharing a block-aligned prefix store and "
                         "prefill it once (needs --prefill-chunk for "
                         "prefix-cache hits)")
    ap.add_argument("--phys-blocks", type=int, default=0,
                    help="with --paged: physical blocks in the shared "
                         "arena (default: slots * max_blocks — the flat "
                         "pool's footprint)")
    ap.add_argument("--mesh", default="",
                    help="stream mode: serve the pooled engine on a "
                         "DPxTP device mesh, e.g. --mesh 4,2 — slots "
                         "shard over the data axis, KV heads over the "
                         "model axis; greedy output is token-identical "
                         "to the unsharded engine")
    ap.add_argument("--no-overlap", action="store_true",
                    help="stream mode: disable the double-buffered tick "
                         "pipeline (overlap is ON by default — tick t+1 "
                         "dispatches before tick t's tokens sync; "
                         "--no-overlap is the serial token-identity "
                         "oracle)")
    ap.add_argument("--server", action="store_true",
                    help="stream mode: instead of driving a synthetic "
                         "request stream, serve an asyncio HTTP frontend "
                         "— POST /v1/generate streams newline-delimited "
                         "JSON token frames, POST /v1/cancel aborts, "
                         "GET /healthz probes, POST /v1/shutdown drains "
                         "the pipeline and exits (snapshotting first "
                         "under --snapshot-dir)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="with --server: bind address")
    ap.add_argument("--port", type=int, default=8731,
                    help="with --server: port (0 = pick a free one)")
    ap.add_argument("--audit", action="store_true",
                    help="stream mode: retrace audit — serve one warmup "
                         "request, snapshot stable_trace_counts(), then "
                         "fail (nonzero exit) if any jitted entry point "
                         "retraces during the real stream")
    ap.add_argument("--snapshot-dir", default="",
                    help="stream mode, with --paged: warm-restart "
                         "snapshots — restore the prefix cache (arena + "
                         "trie + allocator) from the newest snapshot on "
                         "start, and snapshot once the stream drains, so "
                         "a restarted server resumes at full cache-hit "
                         "rate")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="stream mode: bounded admission queue — submits "
                         "past the bound are shed immediately "
                         "(finish_reason='shed'); 0 = unbounded")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="stream mode: per-request total wall-clock "
                         "deadline in seconds (finish_reason='timeout' "
                         "past it); 0 = none")
    ap.add_argument("--ttft-deadline", type=float, default=0.0,
                    help="stream mode: per-request first-token deadline "
                         "in seconds; 0 = none")
    ap.add_argument("--degrade-queue", type=int, default=0,
                    help="stream mode, with --spec-k: drop speculative "
                         "drafting to 0 while the queue holds at least "
                         "this many requests (pressure relief); 0 = off")
    # observability (stream mode): all host-side, all jit-invisible —
    # the engine feeds repro.obs at its tick-boundary sync point only
    ap.add_argument("--metrics-port", type=int, default=-1,
                    help="stream mode: serve Prometheus text exposition "
                         "on http://127.0.0.1:PORT/metrics from a "
                         "background thread (0 = pick a free port; "
                         "-1 = off)")
    ap.add_argument("--trace-file", default="",
                    help="stream mode: write request-lifecycle spans "
                         "(submit/queued/prefill/decode/finish, fault "
                         "firings, snapshot save/load) as a Chrome "
                         "trace-event JSON — load it in chrome://tracing "
                         "or https://ui.perfetto.dev")
    ap.add_argument("--log-json", action="store_true",
                    help="stream mode: one structured JSON line per "
                         "finished request (id, finish_reason, ttft, "
                         "tpot, queue/prefill/decode breakdown) instead "
                         "of the free-form result prints")
    ap.add_argument("--profile-dir", default="",
                    help="stream mode: wrap the serving stream in "
                         "jax.profiler.trace(DIR) — inspect the XLA/"
                         "device timeline in TensorBoard or Perfetto")
    ap.add_argument("--report-every", type=float, default=0.0,
                    help="stream mode: print a one-line metrics report "
                         "(ticks, tok/s rolling median, queue, shed/"
                         "timeout) every N seconds of serving; 0 = off")
    # sampling (0 temperature = greedy; each request gets its own seed)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.spec_adaptive and not args.spec_k:
        ap.error("--spec-adaptive requires --spec-k >= 1")
    if args.one_shot and (args.metrics_port >= 0 or args.trace_file
                          or args.log_json or args.profile_dir
                          or args.report_every):
        ap.error("observability flags (--metrics-port/--trace-file/"
                 "--log-json/--profile-dir/--report-every) are "
                 "stream-mode only")
    if args.audit and args.one_shot:
        ap.error("--audit is stream-mode only (the one-shot engine has no "
                 "warmup/steady-state split to audit)")
    if args.snapshot_dir and not args.paged:
        ap.error("--snapshot-dir needs --paged (only the shared-prefix "
                 "arena + trie persist across restarts)")
    if args.degrade_queue and not args.spec_k:
        ap.error("--degrade-queue needs --spec-k (it degrades by dropping "
                 "the draft window)")
    if args.server and args.one_shot:
        ap.error("--server is stream-mode only (the one-shot engine has "
                 "no scheduler to serve requests through)")
    if args.server and args.audit:
        ap.error("--server and --audit are mutually exclusive (--audit "
                 "drives its own synthetic warmup + stream; run the "
                 "retrace audit without --server)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, sparsity=args.sparsity)

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if not args.dense:
        specs = lm.model_specs(cfg)
        params = convert_concrete(params, specs, cfg, NULL_CTX,
                                  mode="int8" if args.int8 else "bf16")
        from repro.core import sparsity_report
        rep = sparsity_report(params)
        tot_d = sum(r["dense_bytes"] for r in rep.values())
        tot_c = sum(r["compressed_bytes"] for r in rep.values())
        print(f"[serve] sparse-converted {len(rep)} weights: "
              f"{tot_d/1e6:.1f}MB -> {tot_c/1e6:.1f}MB "
              f"({tot_c/tot_d:.3f}x)")

    n_req = args.requests or args.batch
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                    global_batch=max(n_req, args.batch))
    prompts = jnp.asarray(host_batch(dc, 0)["tokens"])

    one_shot = args.one_shot
    if not one_shot:
        try:
            lm._attn_kinds(cfg)
        except ValueError:
            print(f"[serve] {cfg.family}/frontend={bool(cfg.frontend)} has "
                  "no continuous-batching path yet; falling back to the "
                  "one-shot engine (see --one-shot)")
            if args.audit:
                raise SystemExit("[serve] --audit needs the "
                                 "continuous-batching path")
            one_shot = True
    if one_shot:
        batch = {"tokens": prompts[:args.batch]}
        if cfg.family == "encdec":
            batch["src_embeds"] = jnp.zeros(
                (args.batch, args.prompt_len, cfg.d_model), jnp.float32)
        if cfg.frontend:
            batch["frontend_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)
        eng = Engine(params, cfg,
                     kv_mode="dense" if args.dense else "sparse")
        sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed,
                            max_new_tokens=args.steps)
        t0 = time.time()
        toks, _ = eng.generate(batch, sp)
        dt = time.time() - t0
        print(f"[serve] one-shot: {args.steps} tokens x {args.batch} reqs "
              f"in {dt:.2f}s ({args.steps*args.batch/dt:.1f} tok/s)")
        print("[serve] sample:", np.asarray(toks)[0][:16])
        return 0

    # request-stream mode: mixed lengths through the pooled engine
    if args.dense:
        # dense-KV baseline: zero KV sparsity makes the pooled compression
        # a bit-exact round trip at full per-block capacity
        cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0)
    slots = args.slots or args.batch
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh
        try:
            dp, tp = (int(x) for x in args.mesh.split(","))
        except ValueError:
            raise SystemExit(
                f"[serve] --mesh wants DP,TP (e.g. --mesh 4,2), got "
                f"{args.mesh!r}")
        if dp * tp > len(jax.devices()):
            raise SystemExit(
                f"[serve] --mesh {args.mesh} needs {dp * tp} devices, have "
                f"{len(jax.devices())} (hint: "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={dp*tp})")
        mesh = make_mesh((dp, tp), ("data", "model"))
        print(f"[serve] mesh {dp}x{tp} (data x model): {slots} slots over "
              f"data, {cfg.n_kv} KV heads over model")
    obs = None
    metrics_server = None
    if args.metrics_port >= 0 or args.trace_file or args.report_every:
        from repro.obs import MetricsServer, Observability
        obs = Observability(trace_path=args.trace_file or None,
                            report_every=args.report_every)
        if args.metrics_port >= 0:
            metrics_server = MetricsServer(obs.registry,
                                           port=args.metrics_port).start()
            print(f"[serve] metrics: {metrics_server.url}")
    eng = ContinuousEngine(
        params, cfg, slots=slots,
        max_tokens=args.prompt_len + args.steps + cfg.kv_tail,
        prefill_chunk=args.prefill_chunk or None,
        spec=SpecConfig(k=args.spec_k, adaptive=args.spec_adaptive)
        if args.spec_k else None,
        mesh=mesh, paged=args.paged, phys_blocks=args.phys_blocks,
        max_queue=args.max_queue, degrade_queue=args.degrade_queue,
        obs=obs, overlap=not args.no_overlap)
    if args.paged:
        print(f"[serve] paged pool: {eng.pool.n_phys} physical blocks of "
              f"{eng.pool.bs} tokens behind {slots}x{eng.pool.max_blocks} "
              f"block tables")
    if args.snapshot_dir:
        try:
            n = eng.load_snapshot(args.snapshot_dir)
            print(f"[serve] warm restart: restored {n} prefix pages from "
                  f"{args.snapshot_dir} (trie holds {len(eng._trie)} "
                  f"blocks — matching prompts skip their prefill)")
        except ValueError as e:
            print(f"[serve] cold start: {e}")
    if mesh is not None:
        from repro.distributed import serving_sharding
        place = serving_sharding.describe(eng.ctx, eng.state, eng.state_axes)
        kv_key = next(k for k in place if k.endswith("k_values"))
        print(f"[serve] placement: pos={place['pos']} "
              f"kv={ {kv_key: place[kv_key]} }")
    if args.server:
        from repro.serving import ServerFrontend

        def on_shutdown():
            if args.snapshot_dir:
                step = eng.save_snapshot(args.snapshot_dir)
                print(f"[serve] snapshot: step {step} -> "
                      f"{args.snapshot_dir} ({len(eng._trie)} prefix "
                      "blocks persisted)")
            if obs is not None:
                obs.close()
            if metrics_server is not None:
                metrics_server.close()

        front = ServerFrontend(eng, host=args.host, port=args.port,
                               on_shutdown=on_shutdown)

        def ready(port):
            print(f"[serve] server: http://{args.host}:{port} — "
                  "POST /v1/generate {'prompt': [ids...]} streams NDJSON "
                  "token frames; GET /healthz; POST /v1/cancel; "
                  "POST /v1/shutdown", flush=True)

        try:
            front.run(ready)
        except KeyboardInterrupt:
            pass
        print(f"[serve] server drained after {front.loop_thread.ticks} "
              f"ticks, {front.requests_served} requests; jit traces: "
              f"{eng.trace_counts()}")
        return 0

    baseline = None
    if args.audit:
        # warmup: one request touches every entry point (submit/prefill/
        # decode/refreeze/release; verify when --spec-k), populating the
        # jit caches — steady-state serving must not add a single trace
        sp0 = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                             top_p=args.top_p, seed=args.seed,
                             max_new_tokens=max(args.steps, 2))
        eng.submit(np.asarray(prompts[0][:args.prompt_len]), sp0)
        eng.run()
        baseline = stable_trace_counts(eng.trace_counts())
        print(f"[serve] audit: warmup traces {baseline}")
    on_token = None
    if args.log_json:
        import json as _json

        def on_token(o):
            """One structured line per *finished* request (streaming
            snapshots pass through silently)."""
            if not o.finished:
                return
            m = o.metrics
            print(_json.dumps({
                "event": "request", "id": o.request_id,
                "finish_reason": o.finish_reason,
                "prompt_tokens": len(o.prompt_token_ids),
                "tokens": len(o.token_ids),
                "ttft_s": m.ttft, "tpot_s": m.tpot,
                "queue_s": m.queue_time, "prefill_s": m.prefill_time,
                "decode_s": m.decode_time, "e2e_s": m.e2e_latency,
            }))

    import contextlib
    profile_ctx = (jax.profiler.trace(args.profile_dir)
                   if args.profile_dir else contextlib.nullcontext())
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    with profile_ctx:
        for i in range(n_req):
            plen = int(rng.integers(max(args.prompt_len // 2, 1),
                                    args.prompt_len + 1))
            steps = int(rng.integers(max(args.steps // 2, 1),
                                     args.steps + 1))
            sp = SamplingParams(
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, seed=args.seed + i,
                max_new_tokens=steps,
                deadline_s=args.deadline or None,
                ttft_deadline_s=args.ttft_deadline or None)
            rids.append(eng.submit(np.asarray(prompts[i][:plen]), sp,
                                   on_token=on_token))
        out = eng.run()
    dt = time.time() - t0
    total = sum(len(o.token_ids) for o in out.values())
    reasons = [o.finish_reason for o in out.values()]
    abnormal = {r: reasons.count(r) for r in ("shed", "timeout", "cancelled")
                if reasons.count(r)}
    if args.log_json:
        import json as _json
        print(_json.dumps({
            "event": "summary", "requests": n_req, "tokens": total,
            "wall_s": dt, "tok_s": total / dt if dt > 0 else None,
            "slots": slots,
            "finish_reasons": {r: reasons.count(r) for r in set(reasons)},
        }))
    else:
        print(f"[serve] stream: {n_req} requests, {total} tokens in "
              f"{dt:.2f}s ({total/dt:.1f} tok/s) on {slots} slots")
        print(f"[serve] jit traces: {eng.trace_counts()}")
        ttfts = [o.metrics.ttft for o in out.values()
                 if o.metrics.ttft is not None]
        lats = [o.metrics.e2e_latency for o in out.values()
                if o.metrics.e2e_latency is not None]
        if ttfts:
            print(f"[serve] ttft p50={np.median(ttfts)*1e3:.0f}ms "
                  f"max={max(ttfts)*1e3:.0f}ms; "
                  f"e2e p50={np.median(lats)*1e3:.0f}ms; "
                  f"finish: { {o.finish_reason for o in out.values()} }")
        fc = {k: v for k, v in eng.fault_counters.items() if v}
        if abnormal or fc:
            print(f"[serve] lifecycle: {abnormal or 'all normal'}; "
                  f"counters {fc}")
        if args.paged:
            print(f"[serve] paged: prefix trie holds {len(eng._trie)} "
                  f"blocks; {eng._alloc.free_blocks()}/{eng.pool.n_phys} "
                  "reclaimable")
        print("[serve] sample:", list(out[rids[0]].token_ids[:16]))
        lps = [lp for o in out.values() for lp in o.logprobs
               if lp is not None]
        print(f"[serve] mean chosen-token logprob: {np.mean(lps):.3f} "
              f"({len(lps)} tokens)")
    if obs is not None:
        if not args.log_json:
            print(obs.report_line())
        obs.close()
        if args.trace_file:
            print(f"[serve] trace: {args.trace_file} "
                  f"({obs.trace.events_written} events — load in "
                  "chrome://tracing or ui.perfetto.dev)")
    if metrics_server is not None:
        metrics_server.close()
    if args.profile_dir:
        print(f"[serve] profile: {args.profile_dir} (tensorboard "
              "--logdir or Perfetto)")
    if args.spec_k:
        apt = [o.metrics.accepted_per_tick for o in out.values()
               if o.metrics.accepted_per_tick is not None]
        mean = f"{np.mean(apt):.2f}" if apt else "n/a (no decode ticks)"
        print(f"[serve] spec: accepted-draft histogram "
              f"{eng.spec_hist.tolist()} (index = drafts accepted/tick); "
              f"mean tokens/tick {mean}")
        if eng.adaptive_hist is not None:
            print(f"[serve] spec: adaptive proposal histogram "
                  f"{eng.adaptive_hist.tolist()} "
                  f"(index = drafts proposed/tick)")
    if args.snapshot_dir:
        step = eng.save_snapshot(args.snapshot_dir)
        print(f"[serve] snapshot: step {step} -> {args.snapshot_dir} "
              f"({len(eng._trie)} prefix blocks persisted)")
    if args.audit:
        final = stable_trace_counts(eng.trace_counts())
        drift = {k: (baseline.get(k, 0), v) for k, v in final.items()
                 if v != baseline.get(k, 0)}
        if drift:
            print(f"[serve] audit: RETRACE DRIFT (warmup -> exit): {drift}")
            return 1
        print(f"[serve] audit: zero retraces after warmup ({final})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Training launcher: data -> train_step -> checkpoint, with restart/elastic
recovery and a failure-injection harness for the fault-tolerance tests.

Single-process layout (multi-host launch is the same code under
``jax.distributed.initialize`` — every construct here is SPMD-global).

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 20 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 5
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, host_batch
from repro.distributed import ShardCtx, default_rules, tree_param_specs, \
    to_named
from repro.launch.mesh import make_test_mesh
from repro.models import lm
from repro.models import module as mod
from repro.optim import OptConfig, init_opt_state
from repro.train import make_train_step


def train_loop(cfg, steps: int, data_cfg: DataConfig,
               ckpt: CheckpointManager = None, ckpt_every: int = 0,
               mesh=None, start_step: int = None, log_every: int = 1,
               fail_at: int = None, optc: OptConfig = None):
    """Returns (params, opt_state, losses).  Restartable: picks up from the
    latest checkpoint when ``ckpt`` has one."""
    ctx = ShardCtx(mesh, default_rules(False, cfg)) if mesh else \
        ShardCtx(None, {})
    params = lm.init_params(cfg, jax.random.PRNGKey(cfg.n_layers))
    opt_state = init_opt_state(params)
    step0 = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        s = ckpt.latest_step()
        state, manifest = ckpt.restore(
            s, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        step0 = s
        print(f"[train] resumed from step {step0}", flush=True)

    if optc is None:
        optc = OptConfig(peak_lr=1e-3, warmup_steps=max(steps // 10, 1),
                         decay_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, ctx, optc))
    losses = []
    for i in range(step0, steps):
        if fail_at is not None and i == fail_at:
            raise RuntimeError(f"injected failure at step {i}")
        batch = {k: jnp.asarray(v) for k, v in host_batch(data_cfg, i).items()}
        t0 = time.time()
        params, opt_state, mets = step_fn(params, opt_state, batch)
        loss = float(mets["loss"])
        losses.append(loss)
        if i % log_every == 0:
            print(f"[train] step {i} loss {loss:.4f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
        if ckpt is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt_state},
                      meta={"loss": loss})
    if ckpt is not None:
        ckpt.wait()
    return params, opt_state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    ap.add_argument("--retries", type=int, default=0,
                    help="auto-restart-from-checkpoint attempts on failure")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_test_mesh(args.data, args.model) \
        if args.data * args.model > 1 else None
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    attempts = args.retries + 1
    for attempt in range(attempts):
        try:
            _, _, losses = train_loop(
                cfg, args.steps, dc, ckpt, args.ckpt_every, mesh,
                fail_at=args.fail_at if attempt == 0 else None)
            print(f"[train] done; first loss {losses[0]:.4f} "
                  f"last {losses[-1]:.4f}")
            return 0
        except RuntimeError as e:
            print(f"[train] FAILURE ({e}); "
                  f"{'restarting from checkpoint' if attempt + 1 < attempts else 'giving up'}",
                  flush=True)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Quickstart: the paper's mechanism in 60 seconds.

1. prune + pack a weight into the bitmap+values format,
2. run the sparse Pallas kernel (interpret mode) against the dense result,
3. auto-convert a whole model and decode with a compressed KV cache.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pack, unpack, make_mask, sparsity_report
from repro.kernels import ops

# --- 1. pack a weight --------------------------------------------------
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(size=(1024, 4096)).astype(np.float32))
mask = make_mask(w, sparsity=0.5, policy="balanced")     # per-block top-k
sw = pack(w, mask)
print(f"dense {sw.nbytes_dense()/1e6:.1f}MB -> compressed "
      f"{sw.nbytes_compressed()/1e6:.1f}MB "
      f"({sw.compression_ratio():.3f}x, capacity={sw.capacity})")

# --- 2. sparse kernel vs dense ------------------------------------------
x = jnp.asarray(rng.normal(size=(16, 1024)).astype(np.float32))
expect = x @ jnp.where(mask, w, 0)
with ops.backend("interpret"):        # Pallas kernel body runs on CPU
    got = ops.sparse_matmul(x, sw)
err = float(jnp.abs(got - expect).max())
print(f"sparse Pallas kernel max|err| vs dense = {err:.2e}")
assert err < 1e-3

# --- 3. convert a model + decode ----------------------------------------
from repro.configs import get_config
from repro.models import lm
from repro.distributed import NULL_CTX
from repro.distributed.convert_plan import convert_concrete
from repro.serving import Engine, SamplingParams

cfg = get_config("llama3-8b").reduced()
params = lm.init_params(cfg, jax.random.PRNGKey(0))
sparse_params = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX)
rep = sparsity_report(sparse_params)
tot_d = sum(r["dense_bytes"] for r in rep.values())
tot_c = sum(r["compressed_bytes"] for r in rep.values())
print(f"converted {len(rep)} linear weights: "
      f"{tot_d/1e6:.1f}MB -> {tot_c/1e6:.1f}MB")

eng = Engine(sparse_params, cfg, kv_mode="sparse")
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
tokens, _ = eng.generate({"tokens": prompts},
                         SamplingParams(max_new_tokens=9))
print("decoded tokens:", np.asarray(tokens)[0])
print("OK")

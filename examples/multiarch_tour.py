"""Tour: the paper's technique on every assigned architecture family.

SparAMX's claim — "can speed up any PyTorch model by automatically
replacing all linear layers" — translated: one conversion call covers a
dense GQA transformer, an MoE, an encoder-decoder, an attention-free RWKV,
and a hybrid Mamba+MoE model, with family-specific caches (sparse KV vs
recurrent state).

  PYTHONPATH=src python examples/multiarch_tour.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import sparsity_report
from repro.distributed import NULL_CTX
from repro.distributed.convert_plan import convert_concrete
from repro.models import lm
from repro.serving import Engine, SamplingParams

ARCHS = ["qwen3-0.6b", "phi3.5-moe-42b-a6.6b", "seamless-m4t-medium",
         "rwkv6-7b", "jamba-1.5-large-398b"]

for arch in ARCHS:
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    sp = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX)
    rep = sparsity_report(sp)
    d = sum(r["dense_bytes"] for r in rep.values())
    c = sum(r["compressed_bytes"] for r in rep.values())

    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.zeros((2, 32, cfg.d_model), jnp.float32)
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.zeros(
            (2, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    eng = Engine(sp, cfg, kv_mode="sparse")
    toks, cache = eng.generate(batch, SamplingParams(max_new_tokens=5))
    kinds = {lm.layer_kind(cfg, j)[0] for j in range(lm.period_len(cfg))}
    print(f"{arch:<26} [{cfg.family:>6}] mixers={sorted(kinds)} "
          f"{len(rep):>2} sparse weights {d/1e6:6.1f}->{c/1e6:6.1f}MB "
          f"decoded={np.asarray(toks)[0].tolist()}")
print("OK — one technique, five architecture families")

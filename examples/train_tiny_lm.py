"""Training driver: train a small qwen3-family LM for a few hundred steps
with checkpointing + fault-tolerant resume, then sparse-serve the result —
demonstrating the train -> compress -> deploy lifecycle.

  PYTHONPATH=src python examples/train_tiny_lm.py [--steps 200]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, host_batch
from repro.distributed import NULL_CTX
from repro.distributed.convert_plan import convert_concrete
from repro.launch.train import train_loop
from repro.models import lm
from repro.optim import OptConfig
from repro.serving import Engine, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              n_layers=4, d_model=256, d_ff=512, vocab=2048)
    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    ck = CheckpointManager(args.ckpt_dir, keep=2)
    params, _, losses = train_loop(
        cfg, args.steps, dc, ckpt=ck, ckpt_every=max(args.steps // 4, 1),
        log_every=max(args.steps // 10, 1),
        optc=OptConfig(peak_lr=3e-3, warmup_steps=args.steps // 10,
                       decay_steps=args.steps))
    print(f"[train] loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # compress + serve the trained model
    sp = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX)
    eng = Engine(sp, cfg, kv_mode="sparse")
    prompts = jnp.asarray(host_batch(dc, 10_000)["tokens"][:2, :32])
    toks, _ = eng.generate({"tokens": prompts},
                           SamplingParams(max_new_tokens=9))
    print("[serve] sparse-weight decode of the trained model:",
          np.asarray(toks)[0])


if __name__ == "__main__":
    main()

"""End-to-end serving driver (the paper is an inference paper, so this is
the primary e2e example): a *stream* of requests against a sparse-weight,
sparse-KV model — the full SparAMX pipeline on the JAX stack, served by the
continuous-batching engine.

  PYTHONPATH=src python examples/serve_sparse_batch.py [--int8] [--dense]

Flow: init model -> offline preprocessing (prune+pack weights, the paper's
"few minutes for 8B models" step) -> submit a request stream with mixed
prompt/output lengths AND mixed per-request SamplingParams (greedy and
seeded temperature/top-k/top-p lanes share one batched decode step) -> the
scheduler interleaves chunked prefill with decode ticks over the pooled
compressed cache (refreeze folds tails into each slot's frozen prefix in
place; slots recycle as requests finish) -> stream RequestOutputs as
tokens land -> report throughput, per-request latency, retrace counts.
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import sparsity_report
from repro.data import DataConfig, host_batch
from repro.distributed import NULL_CTX
from repro.distributed.convert_plan import convert_concrete
from repro.models import lm
from repro.serving import ContinuousEngine, SamplingParams, SpecConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--int8", action="store_true")
    ap.add_argument("--dense", action="store_true",
                    help="dense weights + dense-capacity KV pool")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: verify up to K n-gram "
                         "draft tokens per slot per tick (0 = off)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.dense:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    if not args.dense:
        t0 = time.time()
        params = convert_concrete(params, lm.model_specs(cfg), cfg,
                                  NULL_CTX,
                                  mode="int8" if args.int8 else "bf16")
        rep = sparsity_report(params)
        tot_d = sum(r["dense_bytes"] for r in rep.values())
        tot_c = sum(r["compressed_bytes"] for r in rep.values())
        print(f"[offline pack] {len(rep)} weights "
              f"{tot_d/1e6:.1f}->{tot_c/1e6:.1f}MB in {time.time()-t0:.1f}s")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                    global_batch=args.requests)
    prompts = np.asarray(host_batch(dc, 0)["tokens"])

    eng = ContinuousEngine(
        params, cfg, slots=args.slots,
        max_tokens=args.prompt_len + args.steps + cfg.kv_tail,
        prefill_chunk=args.prefill_chunk or None,
        spec=SpecConfig(k=args.spec_k) if args.spec_k else None)
    print(f"[pool] {args.slots} slots x {eng.pool.capacity_tokens} tokens, "
          f"block {eng.pool.bs}, caps k={eng.pool.cap_k} v={eng.pool.cap_v}")

    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = []
    for i in range(args.requests):
        plen = int(rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1))
        steps = int(rng.integers(max(args.steps // 2, 1), args.steps + 1))
        # heterogeneous per-request sampling in one pool: even requests
        # decode greedily, odd ones with seeded temperature/top-k/top-p —
        # all lanes share the single compiled decode step
        sp = (SamplingParams(max_new_tokens=steps) if i % 2 == 0 else
              SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                             seed=i, max_new_tokens=steps))
        rids.append(eng.submit(prompts[i][:plen], sp))

    # stream: one RequestOutput snapshot per emitted token
    done = {}
    for snap in eng.stream():
        if snap.finished:
            done[snap.request_id] = snap
            lps = [lp for lp in snap.logprobs if lp is not None]
            print(f"[done] req {snap.request_id}: "
                  f"{len(snap.token_ids)} toks ({snap.finish_reason}), "
                  f"ttft {snap.metrics.ttft*1e3:.0f}ms, "
                  f"e2e {snap.metrics.e2e_latency*1e3:.0f}ms, "
                  f"mean logprob {sum(lps)/max(len(lps),1):.2f}")
    dt = time.time() - t0
    total = sum(len(o.token_ids) for o in done.values())
    print(f"[stream] {args.requests} requests -> {total} tokens in "
          f"{dt:.2f}s ({total/dt:.1f} tok/s) on {args.slots} slots")
    print(f"[jit] traces: {eng.trace_counts()} (decode compiled once)")
    if args.spec_k:
        apt = [o.metrics.accepted_per_tick for o in done.values()
               if o.metrics.accepted_per_tick is not None]
        mean = f"{sum(apt) / len(apt):.2f}" if apt else "n/a (no decode ticks)"
        print(f"[spec] accepted-draft histogram {eng.spec_hist.tolist()}; "
              f"mean tokens committed/tick {mean}")
    print("[sample]", list(done[rids[0]].token_ids[:16]))


if __name__ == "__main__":
    main()

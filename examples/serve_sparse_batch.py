"""End-to-end serving driver (the paper is an inference paper, so this is
the primary e2e example): batched requests against a sparse-weight,
sparse-KV model — the full SparAMX pipeline on the JAX stack.

  PYTHONPATH=src python examples/serve_sparse_batch.py [--int8] [--dense]

Flow: init model -> offline preprocessing (prune+pack weights, the paper's
"few minutes for 8B models" step) -> prefill batch of prompts -> freeze +
compress the KV cache -> batched decode -> report throughput + bytes.
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import sparsity_report
from repro.data import DataConfig, host_batch
from repro.distributed import NULL_CTX
from repro.distributed.convert_plan import convert_concrete
from repro.models import lm
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--int8", action="store_true")
    ap.add_argument("--dense", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    if not args.dense:
        t0 = time.time()
        params = convert_concrete(params, lm.model_specs(cfg), cfg,
                                  NULL_CTX,
                                  mode="int8" if args.int8 else "bf16")
        rep = sparsity_report(params)
        tot_d = sum(r["dense_bytes"] for r in rep.values())
        tot_c = sum(r["compressed_bytes"] for r in rep.values())
        print(f"[offline pack] {len(rep)} weights "
              f"{tot_d/1e6:.1f}->{tot_c/1e6:.1f}MB in {time.time()-t0:.1f}s")

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.prompt_len,
                    global_batch=args.batch)
    prompts = jnp.asarray(host_batch(dc, 0)["tokens"])
    eng = Engine(params, cfg, kv_mode="dense" if args.dense else "sparse")

    t0 = time.time()
    cache, _ = eng.prefill({"tokens": prompts})
    t_prefill = time.time() - t0
    print(f"[prefill] {args.batch} x {args.prompt_len} tokens "
          f"in {t_prefill:.2f}s (cache frozen+compressed)")

    t0 = time.time()
    toks, _ = eng.generate({"tokens": prompts}, steps=args.steps)
    t_dec = time.time() - t0
    print(f"[decode] {args.steps} steps x {args.batch} requests: "
          f"{args.steps*args.batch/t_dec:.1f} tok/s")
    print("[sample]", np.asarray(toks)[0][:16])


if __name__ == "__main__":
    main()

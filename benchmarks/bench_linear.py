"""Paper Table 2: per-projection speedup of sparse vs dense linear layers.

Llama-3-8B layer-5 projections at decode (batch=1).  Two views:

* TPU-roofline-predicted speedup: each projection is memory-bound at
  batch 1, so predicted speedup = dense bytes / compressed bytes (0.5625x
  at 50% bf16) — the byte-reduction mechanism the paper exploits (their
  measured 1.22–2.03x sits below/around this ceiling because of AMX/AVX
  decompression overheads; our TPU kernel avoids their AVX->mem->AMX
  round-trip, see DESIGN.md §2).
* CPU-measured wall time of the XLA fallback path (directional only).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pack, make_mask
from repro.kernels import ops, ref
from .common import emit, time_jax, tpu_latency_model

# (name, K, N) — Llama-3-8B projections (paper Table 2)
PROJECTIONS = [
    ("q_proj", 4096, 4096),
    ("k_proj", 4096, 1024),
    ("v_proj", 4096, 1024),
    ("o_proj", 4096, 4096),
    ("gate_proj", 4096, 14336),
    ("up_proj", 4096, 14336),
    ("down_proj", 14336, 4096),
]


def run(sparsity: float = 0.5, batch: int = 1, measure: bool = True):
    rows = []
    for name, k, n in PROJECTIONS:
        dense_bytes = k * n * 2 + batch * k * 2 + batch * n * 4
        comp_bytes = (k * n * (1 - sparsity) * 2 + k * n / 8
                      + batch * k * 2 + batch * n * 4)
        flops = 2 * batch * k * n
        t_dense = tpu_latency_model(flops, dense_bytes)
        t_sparse = tpu_latency_model(flops, comp_bytes)
        pred = t_dense / t_sparse

        measured = ""
        if measure:
            w = jnp.asarray(np.random.default_rng(0).normal(
                size=(k, n)).astype(np.float32), jnp.bfloat16)
            x = jnp.ones((batch, k), jnp.bfloat16)
            mask = make_mask(w.astype(jnp.float32), sparsity, "balanced")
            sw = pack(w, mask)
            with ops.backend("xla"):
                f_d = jax.jit(lambda x: ops.dense_matmul(x, w))
                f_s = jax.jit(lambda x: ops.sparse_matmul(x, sw))
                us_d = time_jax(f_d, x, iters=5)
                us_s = time_jax(f_s, x, iters=5)
            measured = f"cpu_xla_dense_us={us_d:.0f};cpu_xla_sparse_us={us_s:.0f}"
        emit(f"table2/{name}", t_sparse * 1e6,
             f"pred_speedup={pred:.2f}x;paper_range=1.22-2.03x;{measured}")
        rows.append((name, pred))
    return rows


if __name__ == "__main__":
    run()

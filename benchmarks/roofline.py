"""Roofline analysis: combine whole-program dry-run costs, per-period probe
costs, and closed-form corrections into the three roofline terms.

Methodology (EXPERIMENTS.md §Roofline):

  total ≈ whole_program + (n_periods - 1) x period_probe + corrections

* whole_program: compiled train/serve step (scan bodies counted once — the
  XLA cost model does not multiply while-loop trip counts; verified).
* period_probe: one scan period lowered+compiled standalone under the same
  mesh/shardings (launch/probe.py).  For train, fwd and vjp are probed
  separately and both added (the production scan body is remat'd: fwd +
  recompute + bwd).
* corrections: compute hidden inside *inner* scans even in the probe —
  SSM recurrences over sequence, blocked-flash attention block loops.
  These are closed forms from the architecture config.

Terms (hardware: TPU v5e-class):
  compute    = flops_per_chip / 197e12
  memory     = bytes_per_chip / 819e9
  collective = wire_bytes_per_chip / 50e9
  (wire factors: all-reduce 2x result, reduce-scatter/all-gather/all-to-all
   1x, collective-permute 1x)

MODEL_FLOPS = 6 N D (train; N = non-embedding params, active for MoE) or
2 N B + 4 B S_cache H hd (decode, per step).  The useful-fraction ratio
MODEL_FLOPS / HLO_FLOPS exposes remat/partitioning waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


# ---------------------------------------------------------------------------
# analytic architecture math
# ---------------------------------------------------------------------------

def _layer_linear_params(cfg) -> Dict[str, float]:
    """Per-layer-kind linear parameter counts (matmul weights only)."""
    d, hd = cfg.d_model, cfg.hd
    hq, hkv = cfg.padded_heads, cfg.n_kv
    attn = d * hq * hd * 2 + d * hkv * hd * 2
    mlp = 3 * d * cfg.d_ff
    moe_total = cfg.n_experts * mlp
    moe_active = cfg.top_k * mlp + (mlp if cfg.shared_expert else 0) \
        + d * cfg.n_experts
    di = cfg.d_inner
    rank = max(d // 16, 8)
    mamba = d * 2 * di + di * (rank + 2 * cfg.d_state) + rank * di + di * d
    rwkv_t = 5 * d * d + 2 * d * 64
    rwkv_c = 2 * d * cfg.d_ff + d * d
    return {"attn": attn, "mlp": mlp, "moe_total": moe_total,
            "moe_active": moe_active, "mamba": mamba,
            "rwkv": rwkv_t + rwkv_c}


def arch_params(cfg) -> Dict[str, float]:
    """(total, active) non-embedding params + embedding params."""
    import repro.models.lm as lm
    pl = _layer_linear_params(cfg)
    total = active = 0.0
    for i in range(cfg.n_layers):
        mixer, ffn = lm.layer_kind(cfg, i)
        m = {"attn": pl["attn"], "mamba": pl["mamba"],
             "rwkv": pl["rwkv"]}[mixer]
        total += m
        active += m
        if mixer != "rwkv":
            if ffn == "moe":
                total += pl["moe_total"]
                active += pl["moe_active"]
            else:
                total += pl["mlp"]
                active += pl["mlp"]
    if cfg.family == "encdec":
        total += cfg.enc_layers * (pl["attn"] * 2 + pl["mlp"])
        active += cfg.enc_layers * (pl["attn"] * 2 + pl["mlp"])
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return {"total": total, "active": active, "embed": embed}


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS for one step of this cell (the 'useful' flops)."""
    p = arch_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        flops = 6.0 * p["active"] * b * s
        # useful causal attention: 2(QK)+2(PV) x S^2/2, fwd+bwd(2x) = x3
        attn_layers = sum(1 for i in range(cfg.n_layers)
                          if cfg.is_attn_layer(i))
        if cfg.family == "encdec":
            attn_layers += cfg.enc_layers * 2
        flops += 3 * 2 * b * s * s * cfg.padded_heads * cfg.hd * attn_layers
        return flops
    if shape.kind == "prefill":
        flops = 2.0 * p["active"] * b * s
        attn_layers = sum(1 for i in range(cfg.n_layers)
                          if cfg.is_attn_layer(i))
        if cfg.family == "encdec":
            attn_layers += cfg.enc_layers * 2
        flops += 2 * b * s * s * cfg.padded_heads * cfg.hd * attn_layers
        return flops
    # decode: one token over a seq_len cache
    flops = 2.0 * (p["active"] + p["embed"] / (1 if cfg.tie_embeddings
                                               else 2) * 2) * b
    attn_layers = sum(1 for i in range(cfg.n_layers)
                      if cfg.is_attn_layer(i))
    flops += 4.0 * b * s * cfg.padded_heads * cfg.hd * attn_layers
    return flops


def decode_hbm_bytes(cfg, shape, mode: str) -> Dict[str, float]:
    """Ideal per-step global HBM traffic for decode (the paper's accounting):
    weights once + cache once + small activations."""
    p = arch_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    wbytes = (p["active"] + p["embed"]) * 2.0
    if mode == "paper":
        wbytes = (p["active"] * (1 - cfg.sparsity + 1 / 16) * 2.0
                  + p["embed"] * 2.0)      # embed stays dense bf16
    elif mode == "int8":
        wbytes = (p["active"] * (1 - cfg.sparsity + 1 / 8) * 1.0
                  + p["embed"] * 2.0)
    attn_layers = sum(1 for i in range(cfg.n_layers)
                      if cfg.is_attn_layer(i))
    cache = 2.0 * b * s * cfg.n_kv * cfg.hd * 2 * attn_layers
    if mode in ("paper", "int8"):
        k_keep = 1 - cfg.kv_k_sparsity + 1 / 16
        v_keep = 1 - cfg.kv_v_sparsity + 1 / 16
        cache = cache / 2 * k_keep + cache / 2 * v_keep
    return {"weights": wbytes, "cache": cache, "total": wbytes + cache}


def corrections(cfg, shape) -> Dict[str, float]:
    """Closed-form GLOBAL flops/bytes hidden inside inner scans (per step).

    Keys prefixed ``flops_``/``bytes_`` are added to the respective totals.
    """
    b, s = shape.global_batch, shape.seq_len
    out = {"flops_recurrence": 0.0, "flops_blocked_attn": 0.0,
           "bytes_recurrence": 0.0, "bytes_blocked_attn": 0.0}
    if shape.kind == "decode":
        return out
    fb = 4 if shape.kind == "train" else 1   # fwd+recompute+2bwd : fwd
    # SSM recurrences: counted once in the probe; add the other S-1 steps
    mamba_layers = sum(1 for i in range(cfg.n_layers)
                       if cfg.family in ("hybrid",)
                       and not cfg.is_attn_layer(i))
    if mamba_layers:
        per_tok = 9.0 * cfg.d_inner * cfg.d_state
        out["flops_recurrence"] += fb * per_tok * (s - 1) * b * mamba_layers
        out["bytes_recurrence"] += (fb * 2 * 4.0 * cfg.d_inner * cfg.d_state
                                    * (s - 1) * b * mamba_layers)
    if cfg.family == "ssm":
        dh = cfg.rwkv_head_dim
        per_tok = 6.0 * cfg.d_model * dh
        out["flops_recurrence"] += fb * per_tok * (s - 1) * b * cfg.n_layers
        out["bytes_recurrence"] += (fb * 2 * 4.0 * cfg.d_model * dh
                                    * (s - 1) * b * cfg.n_layers)
    # blocked flash attention: the probe counts ~one (q,kv) block pair
    thr = getattr(cfg, "full_attn_max", 4096)
    if s > thr:
        attn_layers = sum(1 for i in range(cfg.n_layers)
                          if cfg.is_attn_layer(i))
        if cfg.family == "encdec":
            attn_layers += cfg.enc_layers * 2
        tri = getattr(cfg, "attn_impl", "masked") == "triangular"
        pair_frac = 0.5 if tri else 1.0       # causal-optimal vs masked
        mult = 3 if shape.kind == "train" else 1
        full = (2 * 2 * b * s * s * cfg.padded_heads * cfg.hd
                * attn_layers * pair_frac)
        out["flops_blocked_attn"] += mult * full
        # bytes: score panels (f32, written+read) + q/k/v block reads (bf16)
        bq = bkv = 512
        pairs = (s // bq) * (s // bkv) * pair_frac
        h = cfg.padded_heads
        per_pair = (b * h * bq * bkv * 4 * 2
                    + b * h * (bq + 2 * bkv) * cfg.hd * 2)
        out["bytes_blocked_attn"] += mult * pairs * per_pair * attn_layers
    return out


# ---------------------------------------------------------------------------
# combining measured artifacts
# ---------------------------------------------------------------------------

def wire_bytes(coll: Dict[str, float]) -> float:
    total = 0.0
    for op, f in WIRE_FACTOR.items():
        total += f * coll.get(op, 0)
    return total


def load_cell(dryrun_dir: str, probe_dir: str, arch: str, shape: str,
              mesh: str = "16x16", mode: str = "paper",
              tag: str = "") -> Optional[Dict[str, Any]]:
    suffix = f"_{tag}" if tag else ""
    wp = os.path.join(dryrun_dir, f"{arch}_{shape}_{mesh}_{mode}{suffix}.json")
    pp = os.path.join(probe_dir, f"{arch}_{shape}_{mesh}_{mode}{suffix}.json")
    if not os.path.exists(wp):
        return None
    whole = json.load(open(wp))
    probe = json.load(open(pp)) if os.path.exists(pp) else None
    return combine(arch, shape, whole, probe, mode)


def combine(arch: str, shape_name: str, whole: Dict, probe: Optional[Dict],
            mode: str = "paper") -> Dict[str, Any]:
    from repro.configs import get_config, SHAPES
    from repro.launch.dryrun import apply_opts
    cfg = apply_opts(get_config(arch), whole.get("opts", ""))
    shape = SHAPES[shape_name]
    n_dev = whole["n_devices"]

    flops = whole["flops"]
    nbytes = whole["bytes_accessed"]
    cwire = wire_bytes(whole["collective_bytes"])
    n_periods = 0
    if probe:
        n_periods = probe["n_periods"]
        flops += (n_periods - 1) * probe["flops"]
        nbytes += (n_periods - 1) * probe["bytes_accessed"]
        cwire += (n_periods - 1) * wire_bytes(probe["collective_bytes"])
        if "flops_fwd" in probe and shape.kind == "train":
            flops += n_periods * probe["flops_fwd"]      # remat recompute
            nbytes += n_periods * probe.get("bytes_fwd", 0)
    corr = corrections(cfg, shape)
    flops += sum(v for k, v in corr.items() if k.startswith("flops")) / n_dev
    nbytes += sum(v for k, v in corr.items() if k.startswith("bytes")) / n_dev

    mf = model_flops(cfg, shape)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": nbytes / HBM_BW,
        "collective_s": cwire / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    rec = {
        "arch": arch, "shape": shape_name, "mode": mode,
        "mesh": whole["mesh"], "n_devices": n_dev,
        "flops_per_dev": flops, "bytes_per_dev": nbytes,
        "wire_bytes_per_dev": cwire,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_global": mf,
        "useful_ratio": mf / max(flops * n_dev, 1.0),
        "memory_fits": whole.get("memory", {}).get(
            "argument_size_in_bytes", 0) < 16e9,
        "corrections": corr,
        "n_periods": n_periods,
    }
    step_time = max(terms.values())
    rec["roofline_step_s"] = step_time
    if shape.kind == "decode":
        # decode is memory-bound by design: the roofline-optimal step time
        # is the *ideal byte* term (weights-compressed + cache-compressed,
        # each read exactly once), not an MFU
        ideal = decode_hbm_bytes(cfg, shape, mode)
        rec["ideal_decode_bytes_per_dev"] = ideal["total"] / n_dev
        ideal_t = max(ideal["total"] / n_dev / HBM_BW,
                      mf / n_dev / PEAK_FLOPS)
        rec["ideal_memory_s"] = ideal["total"] / n_dev / HBM_BW
        rec["memory_overhead_x"] = nbytes / max(ideal["total"] / n_dev, 1.0)
        rec["roofline_fraction"] = ideal_t / max(step_time, 1e-12)
        # kernel-adjusted: the Pallas sparse kernels read compressed bytes
        # only (no dense materialization, no CPU-backend f32 upcasts —
        # validated in interpret mode); the collective schedule stays
        kern_step = max(ideal_t, terms["collective_s"],
                        terms["compute_s"])
        rec["kernel_adjusted_step_s"] = kern_step
        rec["kernel_adjusted_fraction"] = ideal_t / max(kern_step, 1e-12)
    else:
        rec["roofline_fraction"] = (mf / n_dev / PEAK_FLOPS) \
            / max(step_time, 1e-12)
    return rec


def table(dryrun_dir="experiments/dryrun", probe_dir="experiments/probes",
          mesh="16x16", mode="paper", tag="") -> str:
    from repro.configs import ARCH_IDS, applicable_shapes, get_config
    rows = []
    hdr = (f"{'arch':<24} {'shape':<12} {'compute_s':>10} {'memory_s':>10} "
           f"{'coll_s':>9} {'dom':>7} {'useful':>7} {'roofl%':>7} "
           f"{'kern%':>6}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for arch in ARCH_IDS:
        for sh in applicable_shapes(get_config(arch)):
            r = load_cell(dryrun_dir, probe_dir, arch, sh, mesh, mode, tag)
            if r is None:
                rows.append(f"{arch:<24} {sh:<12} (missing)")
                continue
            kern = (f"{100*r['kernel_adjusted_fraction']:>5.1f}%"
                    if "kernel_adjusted_fraction" in r else "     -")
            rows.append(
                f"{arch:<24} {sh:<12} {r['compute_s']:>10.4f} "
                f"{r['memory_s']:>10.4f} {r['collective_s']:>9.4f} "
                f"{r['dominant']:>7} {r['useful_ratio']:>7.3f} "
                f"{100*r['roofline_fraction']:>6.1f}% {kern}")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    print(table(mesh=mesh))

"""Capacity-policy benchmark: the cost of the pool's static per-block
capacity (ROADMAP "Capacity policy" open item).

The pooled serving cache packs every (bs,)-token block at a *static* value
capacity — nominal density x ``capacity_slack``, lane-rounded — and blocks
denser than that drop their smallest-magnitude overflow (consistently from
bitmap and values).  The legacy one-shot engine instead packs at the
data-dependent capacity (whatever the magnitude rule kept), which is
drop-free but re-traces on every refreeze.  This bench measures what the
static policy costs at the paper's 30% K / 50% V setting:

* **overflow-drop rate** — fraction of magnitude-kept K/V values the
  static capacity drops, per slack, measured on real prefill-collected
  K/V from a reduced model;
* **logit drift** — mean |Δ chosen-token logprob| of a pooled
  ``ContinuousEngine`` at each slack vs the drop-free pooled baseline
  (slack so large no block overflows — the static-shape twin of the
  legacy data-dependent capacity), over the same greedy request wave;
* **prefix agreement** — mean fraction of the greedy stream that matches
  that baseline before first divergence, plus the baseline's own
  agreement vs the legacy ``Engine`` (expected < 1 at nonzero sparsity:
  legacy prunes refreezes over the whole prefix+tail, the pool per
  chunk/fold — a policy difference, not a capacity effect);
* **perplexity delta** — the model is first *trained* (``bench_kv``'s
  ``train_loop``, ``--train-steps``) so teacher-forced next-token CE is
  meaningful: the held-out continuation is scored through the pooled
  cache in ONE pass per slack (``lm.forward_panel_pooled`` — prefill the
  prompt, then a ``[B, STEPS]`` panel), and the drop policy's cost lands
  as ``ppl_ratio_vs_dropfree = exp(ce(slack) - ce(drop-free))``.

  PYTHONPATH=src python -m benchmarks.bench_capacity [--train-steps N]
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pruning import prune_kv
from repro.data import DataConfig, host_batch
from repro.models import lm
from repro.distributed import NULL_CTX
from repro.serving import CachePool, ContinuousEngine, Engine, SamplingParams

from .common import emit

SLACKS = (1.0, 1.1, 1.25, 1.5)
NO_DROP_SLACK = 1e9          # cap clamps to the full block size: drop-free
PROMPT, STEPS, REQS, KV_TAIL, BS = 32, 24, 2, 32, 16


def _panel_fns(cfg):
    """One pair of jitted closures for every ``panel_ce`` call: distinct
    slacks with equal packed capacities then share a trace instead of
    recompiling the full forward per slack."""
    prefill = jax.jit(lambda p, st, t, s: lm.forward_prefill_chunk(
        p, st, t, s, cfg, NULL_CTX, BS))
    panel = jax.jit(lambda p, st, t, m: lm.forward_panel_pooled(
        p, st, t, m, cfg, NULL_CTX, BS))
    return prefill, panel


def panel_ce(params, cfg, slack: float, prompts, cont, max_tokens: int,
             fns) -> float:
    """Teacher-forced next-token CE of ``cont`` through the pooled cache
    at one ``capacity_slack``.

    Prompts prefill one slot each (chunk path: whole blocks freeze at the
    pool's static capacity — the policy under test), then the WHOLE
    continuation is scored as one ``[B, STEPS]`` panel through the
    unified serving forward: panel logits ``j`` predict ``cont[:, j+1]``
    and the prefill's last-token logits predict ``cont[:, 0]``, so one
    forward yields every CE term — no per-token decode loop.
    """
    b, q = cont.shape
    pool = CachePool.build(cfg, b, max_tokens, bs=BS, capacity_slack=slack)
    state = pool.init_state()
    prefill, panel = fns
    first = []
    for i in range(b):
        lg, state = prefill(params, state, prompts[i:i + 1], jnp.int32(i))
        first.append(lg[0])
    panel_logits, _ = panel(params, state, cont, jnp.ones((b,), bool))
    logits = jnp.concatenate([jnp.stack(first)[:, None],
                              panel_logits[:, :-1]], axis=1)     # [B, Q, V]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ce = -jnp.take_along_axis(logp, cont[..., None], axis=-1).mean()
    return float(ce)


def drop_rate(k, sparsity, cap, bs):
    """Fraction of magnitude-kept values a static per-block capacity drops.

    k: [B, Hkv, S, D] prefill-collected cache tensor."""
    b, hkv, s, d = k.shape
    mask = jax.vmap(lambda a: prune_kv(a, sparsity))(k)
    nnz = np.asarray(mask.reshape(b, hkv, s // bs, bs * d).sum(-1))
    kept = nnz.sum()
    return float(np.clip(nnz - cap, 0, None).sum() / max(kept, 1))


def run(out_json: str = "BENCH_capacity.json", train_steps: int = 24):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    if train_steps:
        # a trained model gives teacher-forced CE real structure — logprob
        # drift becomes a perplexity delta instead of random-init noise
        from repro.launch.train import train_loop
        from repro.optim import OptConfig
        dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
        params, _, losses = train_loop(
            cfg, train_steps, dc, log_every=1000,
            optc=OptConfig(peak_lr=2e-3, warmup_steps=4,
                           decay_steps=train_steps))
        print(f"[capacity] trained {train_steps} steps: "
              f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    else:
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (REQS, PROMPT)), jnp.int32)
    # in-distribution held-out eval split for the teacher-forced CE
    ev = jnp.asarray(host_batch(DataConfig(
        vocab=cfg.vocab, seq_len=PROMPT + STEPS, global_batch=REQS),
        999)["tokens"])
    ev_prompt, ev_cont = ev[:, :PROMPT], ev[:, PROMPT:]
    sp = SamplingParams(max_new_tokens=STEPS)
    max_tokens = PROMPT + STEPS + KV_TAIL

    # real prefill K/V (period 0) for the drop-rate measurement
    _, collected = jax.jit(
        lambda p, b: lm.forward_prefill(p, b, cfg, NULL_CTX))(
            params, {"tokens": toks})
    k_pref = collected["layers"]["l0"]["k"][0]
    v_pref = collected["layers"]["l0"]["v"][0]

    def logprob_wave(eng):
        rids = [eng.submit(row, sp) for row in np.asarray(toks)]
        res = eng.run()
        toks_out = [list(res[r].token_ids) for r in rids]
        lps = [list(res[r].logprobs) for r in rids]
        return toks_out, np.asarray(lps, np.float64)

    def prefix_match(a, b):
        """Mean fraction of the generation that agrees before the first
        divergence (greedy streams shift wholesale after one differing
        token, so whole-sequence equality is all-or-nothing)."""
        fracs = []
        for x, y in zip(a, b):
            n = next((i for i, (p, q) in enumerate(zip(x, y)) if p != q),
                     len(x))
            fracs.append(n / max(len(x), 1))
        return float(np.mean(fracs))

    # drop-free pooled baseline = static-shape twin of the legacy
    # data-dependent capacity (every kept value stored)
    base_eng = ContinuousEngine(params, cfg, slots=REQS, bs=BS,
                                max_tokens=max_tokens,
                                capacity_slack=NO_DROP_SLACK)
    base_toks, base_lps = logprob_wave(base_eng)
    legacy = Engine(params, cfg, kv_mode="sparse")
    leg_toks, _ = legacy.generate({"tokens": toks}, sp)
    # caveat: legacy prunes at refreeze over the WHOLE prefix+tail while
    # the pool prunes per chunk/fold, so kept sets (and hence greedy
    # streams) legitimately diverge at nonzero sparsity — the slack sweep
    # below (vs the drop-free pooled baseline) is the controlled
    # capacity-only measurement
    legacy_match = prefix_match(base_toks,
                                [list(r) for r in np.asarray(leg_toks)])

    panel_fns = _panel_fns(cfg)
    base_ce = panel_ce(params, cfg, NO_DROP_SLACK, ev_prompt, ev_cont,
                       max_tokens, panel_fns)
    results = {"sparsity": [cfg.kv_k_sparsity, cfg.kv_v_sparsity],
               "train_steps": train_steps,
               "baseline_vs_legacy_prefix_match": legacy_match,
               "dropfree_ce": base_ce,
               "dropfree_ppl": float(np.exp(base_ce)),
               "slacks": {}}
    for slack in SLACKS:
        pool = CachePool.build(cfg, REQS, max_tokens, bs=BS,
                               capacity_slack=slack)
        eng = ContinuousEngine(params, cfg, slots=REQS, bs=BS,
                               max_tokens=max_tokens, capacity_slack=slack)
        s_toks, s_lps = logprob_wave(eng)
        drift = float(np.mean(np.abs(s_lps - base_lps)))
        agree = prefix_match(s_toks, base_toks)
        ce = panel_ce(params, cfg, slack, ev_prompt, ev_cont, max_tokens,
                      panel_fns)
        row = {
            "cap_k": pool.cap_k, "cap_v": pool.cap_v,
            "drop_rate_k": drop_rate(k_pref, cfg.kv_k_sparsity,
                                     pool.cap_k, BS),
            "drop_rate_v": drop_rate(v_pref, cfg.kv_v_sparsity,
                                     pool.cap_v, BS),
            "logprob_drift": drift,
            "prefix_match_vs_dropfree": agree,
            "ce": ce,
            "ppl": float(np.exp(ce)),
            "ppl_ratio_vs_dropfree": float(np.exp(ce - base_ce)),
        }
        results["slacks"][str(slack)] = row
        emit(f"capacity/slack={slack}", drift * 1e6,
             f"cap_k={row['cap_k']};drop_k={row['drop_rate_k']:.4f};"
             f"drop_v={row['drop_rate_v']:.4f};"
             f"logprob_drift={drift:.5f};match={agree:.2f};"
             f"ppl_ratio={row['ppl_ratio_vs_dropfree']:.4f}")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json} (baseline-vs-legacy match {legacy_match:.2f}; "
          f"drop-free ppl {results['dropfree_ppl']:.2f})")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=24,
                    help="train_loop steps before the sweep (0 = "
                         "random-init params, CE/ppl still reported but "
                         "not meaningful)")
    args = ap.parse_args()
    run(train_steps=args.train_steps)

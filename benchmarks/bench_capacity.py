"""Capacity-policy benchmark: the cost of the pool's static per-block
capacity (ROADMAP "Capacity policy" open item).

The pooled serving cache packs every (bs,)-token block at a *static* value
capacity — nominal density x ``capacity_slack``, lane-rounded — and blocks
denser than that drop their smallest-magnitude overflow (consistently from
bitmap and values).  The legacy one-shot engine instead packs at the
data-dependent capacity (whatever the magnitude rule kept), which is
drop-free but re-traces on every refreeze.  This bench measures what the
static policy costs at the paper's 30% K / 50% V setting:

* **overflow-drop rate** — fraction of magnitude-kept K/V values the
  static capacity drops, per slack, measured on real prefill-collected
  K/V from a reduced model;
* **logit drift** — mean |Δ chosen-token logprob| of a pooled
  ``ContinuousEngine`` at each slack vs the drop-free pooled baseline
  (slack so large no block overflows — the static-shape twin of the
  legacy data-dependent capacity), over the same greedy request wave;
* **prefix agreement** — mean fraction of the greedy stream that matches
  that baseline before first divergence, plus the baseline's own
  agreement vs the legacy ``Engine`` (expected < 1 at nonzero sparsity:
  legacy prunes refreezes over the whole prefix+tail, the pool per
  chunk/fold — a policy difference, not a capacity effect).

  PYTHONPATH=src python -m benchmarks.bench_capacity
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pruning import prune_kv
from repro.models import lm
from repro.distributed import NULL_CTX
from repro.serving import CachePool, ContinuousEngine, Engine, SamplingParams

from .common import emit

SLACKS = (1.0, 1.1, 1.25, 1.5)
NO_DROP_SLACK = 1e9          # cap clamps to the full block size: drop-free
PROMPT, STEPS, REQS, KV_TAIL, BS = 32, 24, 2, 32, 16


def drop_rate(k, sparsity, cap, bs):
    """Fraction of magnitude-kept values a static per-block capacity drops.

    k: [B, Hkv, S, D] prefill-collected cache tensor."""
    b, hkv, s, d = k.shape
    mask = jax.vmap(lambda a: prune_kv(a, sparsity))(k)
    nnz = np.asarray(mask.reshape(b, hkv, s // bs, bs * d).sum(-1))
    kept = nnz.sum()
    return float(np.clip(nnz - cap, 0, None).sum() / max(kept, 1))


def run(out_json: str = "BENCH_capacity.json"):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (REQS, PROMPT)), jnp.int32)
    sp = SamplingParams(max_new_tokens=STEPS)
    max_tokens = PROMPT + STEPS + KV_TAIL

    # real prefill K/V (period 0) for the drop-rate measurement
    _, collected = jax.jit(
        lambda p, b: lm.forward_prefill(p, b, cfg, NULL_CTX))(
            params, {"tokens": toks})
    k_pref = collected["layers"]["l0"]["k"][0]
    v_pref = collected["layers"]["l0"]["v"][0]

    def logprob_wave(eng):
        rids = [eng.submit(row, sp) for row in np.asarray(toks)]
        res = eng.run()
        toks_out = [list(res[r].token_ids) for r in rids]
        lps = [list(res[r].logprobs) for r in rids]
        return toks_out, np.asarray(lps, np.float64)

    def prefix_match(a, b):
        """Mean fraction of the generation that agrees before the first
        divergence (greedy streams shift wholesale after one differing
        token, so whole-sequence equality is all-or-nothing)."""
        fracs = []
        for x, y in zip(a, b):
            n = next((i for i, (p, q) in enumerate(zip(x, y)) if p != q),
                     len(x))
            fracs.append(n / max(len(x), 1))
        return float(np.mean(fracs))

    # drop-free pooled baseline = static-shape twin of the legacy
    # data-dependent capacity (every kept value stored)
    base_eng = ContinuousEngine(params, cfg, slots=REQS, bs=BS,
                                max_tokens=max_tokens,
                                capacity_slack=NO_DROP_SLACK)
    base_toks, base_lps = logprob_wave(base_eng)
    legacy = Engine(params, cfg, kv_mode="sparse")
    leg_toks, _ = legacy.generate({"tokens": toks}, sp)
    # caveat: legacy prunes at refreeze over the WHOLE prefix+tail while
    # the pool prunes per chunk/fold, so kept sets (and hence greedy
    # streams) legitimately diverge at nonzero sparsity — the slack sweep
    # below (vs the drop-free pooled baseline) is the controlled
    # capacity-only measurement
    legacy_match = prefix_match(base_toks,
                                [list(r) for r in np.asarray(leg_toks)])

    results = {"sparsity": [cfg.kv_k_sparsity, cfg.kv_v_sparsity],
               "baseline_vs_legacy_prefix_match": legacy_match,
               "slacks": {}}
    for slack in SLACKS:
        pool = CachePool.build(cfg, REQS, max_tokens, bs=BS,
                               capacity_slack=slack)
        eng = ContinuousEngine(params, cfg, slots=REQS, bs=BS,
                               max_tokens=max_tokens, capacity_slack=slack)
        s_toks, s_lps = logprob_wave(eng)
        drift = float(np.mean(np.abs(s_lps - base_lps)))
        agree = prefix_match(s_toks, base_toks)
        row = {
            "cap_k": pool.cap_k, "cap_v": pool.cap_v,
            "drop_rate_k": drop_rate(k_pref, cfg.kv_k_sparsity,
                                     pool.cap_k, BS),
            "drop_rate_v": drop_rate(v_pref, cfg.kv_v_sparsity,
                                     pool.cap_v, BS),
            "logprob_drift": drift,
            "prefix_match_vs_dropfree": agree,
        }
        results["slacks"][str(slack)] = row
        emit(f"capacity/slack={slack}", drift * 1e6,
             f"cap_k={row['cap_k']};drop_k={row['drop_rate_k']:.4f};"
             f"drop_v={row['drop_rate_v']:.4f};"
             f"logprob_drift={drift:.5f};match={agree:.2f}")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json} (baseline-vs-legacy match {legacy_match:.2f})")


if __name__ == "__main__":
    run()

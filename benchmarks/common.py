"""Shared benchmark utilities: timing, TPU-roofline latency predictor, CSV."""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np

from .roofline import PEAK_FLOPS, HBM_BW

INT8_PEAK = 394e12    # v5e int8 peak (2x bf16)


def time_jax(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (CPU-measured)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def tpu_latency_model(flops: float, hbm_bytes: float,
                      int8: bool = False) -> float:
    """Predicted per-chip latency (s) = max(compute, memory) roofline terms."""
    peak = INT8_PEAK if int8 else PEAK_FLOPS
    return max(flops / peak, hbm_bytes / HBM_BW)


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")

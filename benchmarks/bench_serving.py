"""Serving-engine benchmark: legacy static batch vs continuous batching,
and the cost of the per-slot sampling lanes.

Measures, at batch/slot counts 1/4/8 on ``qwen3-0.6b --reduced``:

* decode throughput (tokens/s) of the legacy one-shot ``Engine`` (static
  batch, host loop, re-traces its jitted decode on every refreeze) vs the
  pooled ``ContinuousEngine`` (chunked prefill interleaved with decode,
  in-place refreeze, decode compiled exactly once);
* the decode-step retrace count of each across the run — the compile-time
  tax the pooled redesign removes;
* **sampled vs greedy decode ticks** on one engine: the on-device
  temperature/top-k/top-p lanes ride inside the same compiled decode step,
  so switching every request from greedy to seeded sampling must add no
  traces and <5% tick time (reported as ``overhead``).

``--spec`` instead benchmarks speculative decoding: the same request wave
through a spec-off engine, a draft–verify engine (``SpecConfig(k)``), and
an adaptive-K engine (``SpecConfig(k, adaptive=True)``), on
drafter-friendly (looping) and drafter-hostile (random) prompts.  Reports
tok/s each way, the accepted-length histogram, the adaptive proposal
histogram, and mean tokens committed per verify tick; written to
``BENCH_spec.json``.

``--mesh`` instead sweeps the mesh-sharded pooled engine over (dp, tp)
shapes on the available devices (force a host-device count with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``): tok/s per mesh,
decode trace counts (must stay 1), and greedy-token agreement with the
1-device engine; written to ``BENCH_mesh.json``.

``--overload`` drives a bounded-queue paged engine with deliberately more
offered load than capacity (tight deadlines + ``max_queue``) and reports
*goodput* (tokens from normally-finished requests per second) alongside
shed/timeout rates, then replays a seeded :class:`FaultPlan` across every
engine fault site and asserts the run is crash-free with flat steady-state
traces; written to ``BENCH_faults.json``.

``--restart`` measures what a warm restart is worth: a shared-prefix wave
freezes pages, ``save_snapshot`` persists them, and a follow-up wave's
TTFT is compared between a cold fresh engine and a fresh engine that
``load_snapshot``-ed first (greedy tokens must agree); written to
``BENCH_restart.json``.

``--traffic`` is the SLO benchmark: an open-loop traffic generator with
Poisson and bursty arrivals and mixed prompt/output lengths sweeps
offered load (0.5x/1x/2x an estimated closed-loop capacity) against a
bounded-queue paged engine with per-request deadlines, reporting p50/p99
TTFT, p50/p99 TPOT (time per output token), goodput
(normally-finished tokens per second), and shed/timeout rates at every
operating point; written to ``BENCH_traffic.json``.

  PYTHONPATH=src python -m benchmarks.bench_serving \
      [--spec] [--spec-k K] [--mesh] [--shared-prefix] \
      [--overload] [--restart] [--traffic [--traffic-requests N]]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.obs import percentile_summary
from repro.serving import (Engine, ContinuousEngine, FaultPlan,
                           SamplingParams, SpecConfig, retrace_count,
                           stable_trace_counts)

from .common import emit

BATCHES = (1, 4, 8)
PROMPT = 64
STEPS = 96          # > 1 tail fill -> exercises refreeze on both engines
KV_TAIL = 64


def run():
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    for b in BATCHES:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, PROMPT)),
                           jnp.int32)

        legacy = Engine(params, cfg, kv_mode="sparse")
        legacy.generate({"tokens": toks},
                        SamplingParams(max_new_tokens=3))       # compile
        t0 = time.perf_counter()
        legacy.generate({"tokens": toks},
                        SamplingParams(max_new_tokens=STEPS))
        dt = time.perf_counter() - t0
        legacy_traces = retrace_count(legacy._decode)
        emit(f"serving/legacy/batch={b}", dt * 1e6,
             f"tok_s={b * STEPS / dt:.1f};decode_traces={legacy_traces}")

        eng = ContinuousEngine(params, cfg, slots=b,
                               max_tokens=PROMPT + STEPS + KV_TAIL)
        eng.generate_batch(toks[:, :PROMPT],
                           SamplingParams(max_new_tokens=3))    # compile
        t0 = time.perf_counter()
        rids = [eng.submit(row, SamplingParams(max_new_tokens=STEPS))
                for row in np.asarray(toks)]
        out = eng.run()
        dt = time.perf_counter() - t0
        ttft = percentile_summary([out[r].metrics.ttft for r in rids],
                                  qs=(50, 99), scale=1e3)
        reasons = Counter(out[r].finish_reason for r in rids)
        n = max(len(rids), 1)
        emit(f"serving/continuous/batch={b}", dt * 1e6,
             f"tok_s={b * STEPS / dt:.1f};"
             f"decode_traces={eng.trace_counts()['decode']};"
             f"ttft_p50={ttft['p50']:.1f}ms;"
             f"ttft_p99={ttft['p99']:.1f}ms;"
             f"shed={reasons['shed'] / n:.2f};"
             f"timeout={reasons['timeout'] / n:.2f};"
             f"cancelled={reasons['cancelled'] / n:.2f}")

    # -- sampled vs greedy decode ticks (one engine, same compiled step) ----
    b = 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, PROMPT)), jnp.int32)
    eng = ContinuousEngine(params, cfg, slots=b,
                           max_tokens=PROMPT + STEPS + KV_TAIL)
    grid = {
        "greedy": SamplingParams(max_new_tokens=STEPS),
        "sampled": SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                                  seed=0, max_new_tokens=STEPS),
    }
    for sp in grid.values():                                    # compile
        eng.generate_batch(toks, dataclasses.replace(sp, max_new_tokens=3))
    times = {}
    for label, sp in grid.items():
        t0 = time.perf_counter()
        eng.generate_batch(toks, sp)
        times[label] = time.perf_counter() - t0
    overhead = times["sampled"] / times["greedy"] - 1.0
    for label, dt in times.items():
        emit(f"serving/decode_{label}/batch={b}", dt * 1e6,
             f"tok_s={b * STEPS / dt:.1f};"
             f"decode_traces={eng.trace_counts()['decode']};"
             f"overhead={overhead * 100:+.1f}%")


def run_spec(k: int = 4, slots: int = 4, steps: int = 64,
             out_json: str = "BENCH_spec.json"):
    """Spec-on vs spec-off throughput + accepted-length histogram.

    Two prompt regimes: a short repeating token loop (the n-gram drafter's
    best case — generation revisits its own history) and uniform random
    tokens (its worst case — speculation must cost ~nothing and stay
    token-identical)."""
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    loop = np.tile(rng.integers(0, cfg.vocab, (slots, 8)), (1, PROMPT // 8))
    rand = rng.integers(0, cfg.vocab, (slots, PROMPT))
    results = {"k": k, "slots": slots, "steps": steps, "regimes": {}}
    grid = (("off", None), ("on", SpecConfig(k=k)),
            ("adaptive", SpecConfig(k=k, adaptive=True)))
    for regime, prompts in (("loop", loop), ("random", rand)):
        row = {}
        for label, spec in grid:
            eng = ContinuousEngine(params, cfg, slots=slots,
                                   max_tokens=PROMPT + steps + KV_TAIL,
                                   spec=spec)
            eng.generate_batch(jnp.asarray(prompts, jnp.int32),
                               SamplingParams(max_new_tokens=3))  # compile
            if spec is not None:
                eng.spec_hist[:] = 0          # drop the warmup run's ticks
                if eng.adaptive_hist is not None:
                    eng.adaptive_hist[:] = 0
                    eng._adaptive._rate.clear()
            t0 = time.perf_counter()
            rids = [eng.submit(p, SamplingParams(max_new_tokens=steps))
                    for p in prompts]
            out = eng.run()
            dt = time.perf_counter() - t0
            toks = {r: list(out[r].token_ids) for r in rids}
            apt = [out[r].metrics.accepted_per_tick for r in rids]
            row[label] = {
                "tok_s": slots * steps / dt,
                "wall_s": dt,
                "tokens": toks,
                "accepted_hist": (eng.spec_hist.tolist()
                                  if spec is not None else None),
                "adaptive_hist": (eng.adaptive_hist.tolist()
                                  if eng.adaptive_hist is not None
                                  else None),
                "accepted_per_tick": (float(np.mean(apt))
                                      if spec is not None else 1.0),
            }
            emit(f"serving/spec_{label}/{regime}", dt * 1e6,
                 f"tok_s={row[label]['tok_s']:.1f};"
                 f"tokens_per_tick={row[label]['accepted_per_tick']:.2f}")
        # token agreement (1.0 in exact arithmetic; bf16 near-ties between
        # the [B,1] decode and [B,K+1] verify panels may drift)
        match = np.mean([row["on"]["tokens"][r] == row["off"]["tokens"][r]
                         for r in row["on"]["tokens"]])
        adapt_match = np.mean(
            [row["adaptive"]["tokens"][r] == row["off"]["tokens"][r]
             for r in row["adaptive"]["tokens"]])
        for r in row.values():
            del r["tokens"]
        row["greedy_match"] = float(match)
        row["greedy_match_adaptive"] = float(adapt_match)
        row["speedup"] = row["on"]["tok_s"] / row["off"]["tok_s"]
        emit(f"serving/spec_speedup/{regime}", 0.0,
             f"x{row['speedup']:.2f};hist={row['on']['accepted_hist']};"
             f"adaptive_hist={row['adaptive']['adaptive_hist']}")
        results["regimes"][regime] = row
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")


def run_mesh(slots: int = 8, steps: int = 48,
             out_json: str = "BENCH_mesh.json"):
    """Mesh-sharded serving sweep: the same request wave through
    ``ContinuousEngine(mesh=...)`` at every (dp, tp) shape the available
    devices support (plus the unsharded engine as the reference).

    On a forced host-device platform the numbers measure *overhead* (one
    physical CPU pretending to be N devices — partition/collective cost
    with no extra FLOPs), so the bar is greedy-token agreement and flat
    decode traces, with tok/s reported for shape-relative comparison.
    dp-only meshes are exactly token-identical; tp > 1 at bf16 can flip
    near-tie argmaxes (the attention out-projection's contraction is
    sharded over heads, so partial-sum order differs) — the f32 parity
    suite (tests/test_serving_sharded.py) is exact on both.
    """
    from repro.launch.mesh import make_mesh

    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (slots, PROMPT)),
                       jnp.int32)
    sp = SamplingParams(max_new_tokens=steps)
    n_dev = len(jax.devices())
    shapes = [(1, 1)] + [(dp, tp)
                         for dp in (2, 4, 8) for tp in (1, 2)
                         if dp * tp <= n_dev and slots % dp == 0]

    results = {"slots": slots, "steps": steps, "devices": n_dev,
               "meshes": {}}
    base_eng = ContinuousEngine(params, cfg, slots=slots,
                                max_tokens=PROMPT + steps + KV_TAIL)
    base_eng.generate_batch(toks, SamplingParams(max_new_tokens=3))
    t0 = time.perf_counter()
    base_toks = np.asarray(base_eng.generate_batch(toks, sp))
    base_dt = time.perf_counter() - t0
    results["unsharded_tok_s"] = slots * steps / base_dt
    emit("serving/mesh=none", base_dt * 1e6,
         f"tok_s={results['unsharded_tok_s']:.1f}")
    for dp, tp in shapes:
        mesh = make_mesh((dp, tp), ("data", "model"))
        eng = ContinuousEngine(params, cfg, slots=slots,
                               max_tokens=PROMPT + steps + KV_TAIL,
                               mesh=mesh)
        eng.generate_batch(toks, SamplingParams(max_new_tokens=3))
        t0 = time.perf_counter()
        out = np.asarray(eng.generate_batch(toks, sp))
        dt = time.perf_counter() - t0
        row = {
            "tok_s": slots * steps / dt,
            "wall_s": dt,
            "greedy_match": float(np.mean(out == base_toks)),
            "decode_traces": eng.trace_counts()["decode"],
        }
        results["meshes"][f"{dp}x{tp}"] = row
        emit(f"serving/mesh={dp}x{tp}", dt * 1e6,
             f"tok_s={row['tok_s']:.1f};match={row['greedy_match']:.3f};"
             f"decode_traces={row['decode_traces']}")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")


def run_shared_prefix(n_req: int = 16, steps: int = 32,
                      out_json: str = "BENCH_paged.json"):
    """Shared-prefix serving: flat pool vs paged pool at EQUAL pool bytes.

    The wave is ``n_req`` requests sharing one long prompt prefix (a system
    prompt) with short unique suffixes.  The flat pool stores the prefix
    once per slot, so equal arena bytes buy it ``flat_slots`` concurrent
    requests; the paged pool stores it ONCE globally, so the same bytes
    buy ``2 * flat_slots`` slots — and every request after the first skips
    the shared prefill entirely (prefix-trie hit).  Reports tok/s, admitted
    concurrency, TTFT p50/p99, and the cache-hit TTFT reduction; greedy
    tokens must agree request-for-request.
    """
    bs, chunk, prefix_len, suffix = 16, 64, 448, 8
    flat_slots = 4
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, (prefix_len,)).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab, (suffix,)).tolist()
               for _ in range(n_req)]
    max_tokens = prefix_len + suffix + steps + KV_TAIL
    sp = SamplingParams(max_new_tokens=steps)
    warm_sp = SamplingParams(max_new_tokens=3)
    # warmup uses a DISJOINT prefix so the timed paged wave still pays its
    # one cold prefill (trie misses) while every jit is already compiled
    warm = [rng.integers(0, cfg.vocab, (prefix_len + suffix,)).tolist()
            for _ in range(2)]

    # the cache-hit TTFT case: requests arriving once the shared prefix is
    # already resident (the steady state of a system-prompt workload) —
    # a fresh 4-request wave against each drained-but-warm engine
    followup = [shared + rng.integers(0, cfg.vocab, (suffix,)).tolist()
                for _ in range(4)]

    def drive(eng):
        for p in warm:
            eng.submit(p, warm_sp)
        eng.run()
        t0 = time.perf_counter()
        rids = [eng.submit(p, sp) for p in prompts]
        conc = 0
        while not eng.scheduler.done():
            eng.step()
            conc = max(conc, len(eng.scheduler.active))
        dt = time.perf_counter() - t0
        out = {r: eng.scheduler.finished[r].output() for r in rids}
        ttft = percentile_summary([out[r].metrics.ttft for r in rids],
                                  qs=(50, 99), scale=1e3)
        r2 = [eng.submit(p, sp) for p in followup]
        out2 = eng.run()
        hit = percentile_summary([out2[r].metrics.ttft for r in r2],
                                 qs=(50,), scale=1e3)
        return {"tok_s": n_req * steps / dt, "wall_s": dt,
                "concurrency": conc,
                "ttft_p50_ms": ttft["p50"],
                "ttft_p99_ms": ttft["p99"],
                "hit_ttft_ms": hit["p50"],
                "tokens": [list(out[r].token_ids) for r in rids]}

    flat_eng = ContinuousEngine(params, cfg, slots=flat_slots,
                                max_tokens=max_tokens, bs=bs,
                                prefill_chunk=chunk)
    flat = drive(flat_eng)
    # paged: the SAME arena bytes (flat_slots * max_blocks physical pages)
    # spread over twice the slots — sharing is what makes them usable
    paged_eng = ContinuousEngine(
        params, cfg, slots=2 * flat_slots, max_tokens=max_tokens, bs=bs,
        prefill_chunk=chunk, paged=True,
        phys_blocks=flat_slots * flat_eng.pool.max_blocks)
    paged = drive(paged_eng)

    match = float(np.mean([a == b for a, b in
                           zip(flat["tokens"], paged["tokens"])]))
    for row in (flat, paged):
        del row["tokens"]
    results = {
        "n_req": n_req, "steps": steps, "prefix_len": prefix_len,
        "suffix": suffix, "bs": bs, "chunk": chunk,
        "pool_bytes": {"flat": flat_eng.pool.nbytes(),
                       "paged": paged_eng.pool.nbytes()},
        "flat": {**flat, "slots": flat_slots},
        "paged": {**paged, "slots": 2 * flat_slots,
                  "phys_blocks": paged_eng.pool.n_phys,
                  "trie_blocks": len(paged_eng._trie)},
        "greedy_match": match,
        "speedup_tok_s": paged["tok_s"] / flat["tok_s"],
        "concurrency_ratio": paged["concurrency"] / flat["concurrency"],
        "hit_ttft_reduction": flat["hit_ttft_ms"] / paged["hit_ttft_ms"],
    }
    emit("serving/shared_prefix/flat", flat["wall_s"] * 1e6,
         f"tok_s={flat['tok_s']:.1f};conc={flat['concurrency']};"
         f"ttft_p50={flat['ttft_p50_ms']:.1f}ms;"
         f"ttft_p99={flat['ttft_p99_ms']:.1f}ms")
    emit("serving/shared_prefix/paged", paged["wall_s"] * 1e6,
         f"tok_s={paged['tok_s']:.1f};conc={paged['concurrency']};"
         f"ttft_p50={paged['ttft_p50_ms']:.1f}ms;"
         f"ttft_p99={paged['ttft_p99_ms']:.1f}ms")
    emit("serving/shared_prefix/ratio", 0.0,
         f"tok_s=x{results['speedup_tok_s']:.2f};"
         f"conc=x{results['concurrency_ratio']:.2f};"
         f"hit_ttft=x{results['hit_ttft_reduction']:.1f};"
         f"match={match:.3f}")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")


def run_overload(n_req: int = 24, steps: int = 24,
                 out_json: str = "BENCH_faults.json"):
    """Overload shedding + seeded fault-injection benchmark.

    Phase 1 (overload): ``n_req`` requests are thrown at a 4-slot paged
    engine whose admission queue is capped at 6 and whose requests carry
    tight wall-clock deadlines — offered load deliberately exceeds
    capacity, so the engine must shed at submit time and expire queued or
    slow requests at tick boundaries.  The number that matters is
    *goodput*: tokens from requests that finished normally, per second —
    a fault-tolerant engine degrades by rejecting work, not by slowing
    every accepted request.  The absolute shed/timeout split is
    machine-speed-dependent; the invariants are (a) every submitted
    request reaches a terminal finish reason and (b) decode never
    retraces while the lifecycle churns.

    Phase 2 (fault matrix): a fresh engine replays a seeded
    :class:`FaultPlan` covering every engine fault site (page exhaustion,
    drafter failure, cancels mid-prefill and mid-spec-window, double
    release), with traffic resubmitted until the plan is exhausted.  The
    run must be crash-free: plan fully fired, queue drained, steady-state
    traces flat, allocator refcounts back to zero.
    """
    slots, bs, chunk = 4, 16, 32
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (PROMPT,)).tolist()
               for _ in range(n_req)]
    max_tokens = PROMPT + steps + KV_TAIL

    def fresh(**kw):
        return ContinuousEngine(params, cfg, slots=slots,
                                max_tokens=max_tokens, bs=bs,
                                prefill_chunk=chunk, paged=True, **kw)

    # -- phase 1: overload --------------------------------------------------
    eng = fresh(max_queue=6)
    for p in prompts[:2]:                                       # compile
        eng.submit(p, SamplingParams(max_new_tokens=3))
    eng.run()
    sp = SamplingParams(max_new_tokens=steps, deadline_s=3.0,
                        ttft_deadline_s=1.5)
    t0 = time.perf_counter()
    rids = [eng.submit(p, sp) for p in prompts]
    out = eng.run()
    dt = time.perf_counter() - t0
    reasons = Counter(out[r].finish_reason for r in rids)
    assert sum(reasons.values()) == n_req, "a request vanished"
    good = [r for r in rids if out[r].finish_reason in ("length", "stop")]
    goodput = sum(len(out[r].token_ids) for r in good) / dt
    traces = stable_trace_counts(eng.trace_counts())
    assert all(v <= 1 for v in traces.values()), traces
    overload = {
        "n_req": n_req, "steps": steps, "slots": slots, "max_queue": 6,
        "wall_s": dt,
        "goodput_tok_s": goodput,
        "ttft_ms": percentile_summary(
            [out[r].metrics.ttft for r in good], qs=(50, 99), scale=1e3),
        "finish_reasons": dict(reasons),
        "shed_rate": reasons["shed"] / n_req,
        "timeout_rate": reasons["timeout"] / n_req,
        "stable_traces": traces,
    }
    emit("serving/overload", dt * 1e6,
         f"goodput={goodput:.1f}tok_s;shed={overload['shed_rate']:.2f};"
         f"timeout={overload['timeout_rate']:.2f};"
         f"decode_traces={traces['decode']}")

    # -- phase 2: seeded fault matrix ---------------------------------------
    plan = FaultPlan.generate(seed=0, ticks=30)
    # speculation on: the cancel-spec and drafter-error sites only become
    # applicable while a spec window is in flight
    feng = fresh(faults=plan, max_queue=8, spec=SpecConfig(k=3))
    t0 = time.perf_counter()
    guard = 0
    while (not plan.exhausted() or not feng.scheduler.done()) and guard < 600:
        guard += 1
        if feng.scheduler.done():
            for p in prompts[:4]:
                feng.submit(p, SamplingParams(max_new_tokens=steps))
        if feng.scheduler.queue and not feng.scheduler.active:
            # whole queue backing off after an injected exhaustion:
            # idle-wait like a real server tick instead of spinning
            time.sleep(0.005)
        feng.step()
    dt = time.perf_counter() - t0
    crash_free = plan.exhausted() and feng.scheduler.done()
    assert crash_free, (f"fault plan not drained: pending={plan.pending()} "
                        f"done={feng.scheduler.done()} guard={guard}")
    ftraces = stable_trace_counts(feng.trace_counts())
    assert all(v <= 1 for v in ftraces.values()), ftraces
    assert not feng._blocks and int(feng._alloc._ref.sum()) == 0
    faults = {
        "plan": [list(f) for f in plan.fired],       # (tick, site) pairs
        "ticks": guard, "wall_s": dt,
        "fault_counters": {k: v for k, v in feng.fault_counters.items()
                           if v},
        "finish_reasons": dict(Counter(
            r.finish_reason for r in feng.scheduler.finished.values())),
        "crash_free": crash_free,
        "stable_traces": ftraces,
    }
    emit("serving/fault_matrix", dt * 1e6,
         f"sites={len(plan.fired)};ticks={guard};crash_free={crash_free};"
         f"decode_traces={ftraces['decode']}")

    results = {"overload": overload, "fault_matrix": faults}
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")


def run_restart(n_req: int = 8, steps: int = 16,
                out_json: str = "BENCH_restart.json"):
    """Cold vs warm-restart TTFT on a shared-prefix workload.

    A first engine serves a wave sharing one long prompt prefix, freezing
    the prefix into the paged arena, then ``save_snapshot``-s.  The same
    follow-up wave is then timed on (a) a cold fresh engine — full prefill
    from token 0 — and (b) a fresh engine that ``load_snapshot``-ed first,
    whose admissions revive the frozen prefix from the trie and prefill
    only the unique suffix.  Greedy tokens must agree between the two; the
    headline is the TTFT ratio (how much of the crash-recovery prefill
    tax the snapshot removes).
    """
    bs, chunk, prefix_len, suffix = 16, 64, 192, 8
    slots = 4
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, (prefix_len,)).tolist()
    wave = [shared + rng.integers(0, cfg.vocab, (suffix,)).tolist()
            for _ in range(n_req)]
    followup = [shared + rng.integers(0, cfg.vocab, (suffix,)).tolist()
                for _ in range(slots)]
    max_tokens = prefix_len + suffix + steps + KV_TAIL
    sp = SamplingParams(max_new_tokens=steps)

    def fresh():
        return ContinuousEngine(params, cfg, slots=slots,
                                max_tokens=max_tokens, bs=bs,
                                prefill_chunk=chunk, paged=True)

    def timed_wave(eng, prompts):
        rids = [eng.submit(p, sp) for p in prompts]
        out = eng.run()
        ttft = percentile_summary([out[r].metrics.ttft for r in rids],
                                  qs=(50,), scale=1e3)
        return ([list(out[r].token_ids) for r in rids], ttft["p50"])

    snap_dir = tempfile.mkdtemp(prefix="bench_restart_")
    first = fresh()
    timed_wave(first, wave)                 # freeze the shared prefix
    step = first.save_snapshot(snap_dir)

    # warm each engine's jits on a DISJOINT prompt so the timed follow-up
    # wave pays real prefill (cold) or real trie revival (warm), not XLA
    warm_prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (1, prefix_len + suffix)), jnp.int32)

    cold_eng = fresh()
    cold_eng.generate_batch(warm_prompt, SamplingParams(max_new_tokens=3))
    t0 = time.perf_counter()
    cold_toks, cold_ttft = timed_wave(cold_eng, followup)
    cold_dt = time.perf_counter() - t0

    warm_eng = fresh()
    warm_eng.generate_batch(warm_prompt, SamplingParams(max_new_tokens=3))
    restored = warm_eng.load_snapshot(snap_dir)
    t0 = time.perf_counter()
    warm_toks, warm_ttft = timed_wave(warm_eng, followup)
    warm_dt = time.perf_counter() - t0

    match = float(np.mean([a == b for a, b in zip(cold_toks, warm_toks)]))
    results = {
        "n_req": n_req, "steps": steps, "prefix_len": prefix_len,
        "suffix": suffix, "snapshot_step": step,
        "restored_pages": restored,
        "cold": {"ttft_p50_ms": cold_ttft, "wall_s": cold_dt},
        "warm": {"ttft_p50_ms": warm_ttft, "wall_s": warm_dt},
        "ttft_reduction": cold_ttft / warm_ttft if warm_ttft else None,
        "greedy_match": match,
    }
    emit("serving/restart/cold", cold_dt * 1e6,
         f"ttft_p50={cold_ttft:.1f}ms")
    emit("serving/restart/warm", warm_dt * 1e6,
         f"ttft_p50={warm_ttft:.1f}ms;pages={restored};"
         f"ttft=x{results['ttft_reduction']:.1f};match={match:.3f}")
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")


def run_traffic(n_req: int = 32, out_json: str = "BENCH_traffic.json"):
    """Open-loop SLO traffic benchmark: goodput vs offered load.

    A closed-loop wave first estimates the engine's capacity (tok/s and
    the request rate that saturates it).  Then, for each arrival pattern
    (``poisson``: i.i.d. exponential gaps; ``bursty``: bursts of 4
    back-to-back arrivals at Poisson burst times) and each offered load
    (0.5x/1x/2x capacity), an open-loop generator submits ``n_req``
    requests with mixed prompt lengths (PROMPT/2..PROMPT) and output
    lengths at the scheduled wall-clock instants — it never waits for the
    engine, which is what makes overload real.  The engine carries PR 8's
    protections (bounded queue, TTFT + total deadlines), so past the knee
    it degrades by shedding and expiring, not by stretching every
    request.  Per operating point: p50/p99 TTFT, p50/p99 TPOT, goodput
    (tokens from normally-finished requests per second), and
    shed/timeout rates.

    Every operating point runs TWICE on the same precomputed arrival
    schedule: once with the overlapped (double-buffered) tick pipeline
    and once with the serial oracle (``overlap=False``).  The serial
    numbers land in each row's ``overlap_off`` sub-dict and
    ``goodput_speedup`` is the overlapped/serial goodput ratio — the
    headline claim is >= 1.15x at 1x offered load.  The pipeline hides
    host-side tick work (scheduling, commit, callbacks) behind device
    compute, so the speedup needs at least one core for the host thread
    plus cores for XLA; on a single-core host the two serialize and the
    honest expectation is parity (``host_cores`` is recorded so readers
    can tell which regime a result came from).
    """
    slots, bs, chunk, steps_max = 4, 16, 32, 24
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_tokens = PROMPT + steps_max + KV_TAIL
    # one fixed mixed-length workload, reused at every operating point so
    # the sweep varies arrival times only
    plens = rng.integers(PROMPT // 2, PROMPT + 1, n_req)
    steps = rng.integers(steps_max // 2, steps_max + 1, n_req)
    prompts = [rng.integers(0, cfg.vocab, (int(p),)).tolist()
               for p in plens]

    def fresh(overlap=False):
        return ContinuousEngine(params, cfg, slots=slots,
                                max_tokens=max_tokens, bs=bs,
                                prefill_chunk=chunk, paged=True,
                                max_queue=2 * slots, overlap=overlap)

    # -- capacity estimate: closed-loop (everything offered at t=0) ---------
    # serial engine on purpose: arrival schedules derive from this number,
    # and keeping it pipeline-independent keeps the on/off comparison on
    # identical offered traffic
    eng = fresh()
    for p in prompts[:2]:                                       # compile
        eng.submit(p, SamplingParams(max_new_tokens=3))
    eng.run()
    n_cal = min(n_req, 2 * slots)
    t0 = time.perf_counter()
    rids = [eng.submit(prompts[i],
                       SamplingParams(max_new_tokens=int(steps[i])))
            for i in range(n_cal)]
    out = eng.run()
    cal_dt = time.perf_counter() - t0
    cal_toks = sum(len(out[r].token_ids) for r in rids)
    capacity_tok_s = cal_toks / cal_dt
    mean_out = float(np.mean(steps[:n_cal]))
    capacity_rps = capacity_tok_s / mean_out

    def arrivals(pattern, rate, rng):
        if pattern == "poisson":
            return np.cumsum(rng.exponential(1.0 / rate, n_req))
        burst = 4                       # bursty: B back-to-back arrivals
        n_bursts = -(-n_req // burst)   # at Poisson burst times, same
        t = np.cumsum(rng.exponential(burst / rate, n_bursts))  # mean rate
        return np.repeat(t, burst)[:n_req]

    def drive(sched, overlap):
        eng = fresh(overlap)
        for p in prompts[:2]:                                   # compile
            eng.submit(p, SamplingParams(max_new_tokens=3))
        eng.run()
        rids = [None] * n_req
        i = 0
        t_start = time.perf_counter()
        while i < n_req or not eng.scheduler.done():
            now = time.perf_counter() - t_start
            while i < n_req and sched[i] <= now:
                sp = SamplingParams(max_new_tokens=int(steps[i]),
                                    deadline_s=8.0, ttft_deadline_s=4.0)
                rids[i] = eng.submit(prompts[i], sp)
                i += 1
            if eng.scheduler.done():
                # open loop gone idle: sleep until the next arrival
                time.sleep(min(max(sched[i] - now, 0.0), 0.05))
                continue
            eng.step()
        dt = time.perf_counter() - t_start
        out = {r: eng.scheduler.finished[r].output() for r in rids}
        reasons = Counter(out[r].finish_reason for r in rids)
        good = [r for r in rids
                if out[r].finish_reason in ("length", "stop")]
        return {
            "wall_s": dt,
            "ttft_ms": percentile_summary(
                [out[r].metrics.ttft for r in good],
                qs=(50, 99), scale=1e3),
            "tpot_ms": percentile_summary(
                [out[r].metrics.tpot for r in good],
                qs=(50, 99), scale=1e3),
            "goodput_tok_s": sum(len(out[r].token_ids)
                                 for r in good) / dt,
            "finish_reasons": dict(reasons),
            "shed_rate": reasons["shed"] / n_req,
            "timeout_rate": reasons["timeout"] / n_req,
            "decode_traces": eng.trace_counts()["decode"],
        }

    loads = (0.5, 1.0, 2.0)
    results = {
        "n_req": n_req, "slots": slots, "steps_max": steps_max,
        "prompt_max": PROMPT, "max_queue": 2 * slots,
        "deadline_s": 8.0, "ttft_deadline_s": 4.0,
        "capacity_tok_s": capacity_tok_s, "capacity_rps": capacity_rps,
        "host_cores": os.cpu_count(),
        "loads": list(loads), "patterns": {},
    }
    for pattern in ("poisson", "bursty"):
        rows = {}
        for load in loads:
            rate = capacity_rps * load
            # one schedule per operating point, replayed for both engines
            sched = arrivals(pattern, rate, np.random.default_rng(1))
            row = drive(sched, overlap=True)
            off = drive(sched, overlap=False)
            row["offered_rps"] = rate
            row["offered_load"] = load
            row["overlap_off"] = off
            row["goodput_speedup"] = (
                row["goodput_tok_s"] / off["goodput_tok_s"]
                if off["goodput_tok_s"] else None)
            rows[str(load)] = row
            ttft, tpot = row["ttft_ms"], row["tpot_ms"]
            spd = row["goodput_speedup"]
            spd_note = (f";overlap_speedup={spd:.2f}x"
                        if spd is not None else ";overlap_speedup=n/a")
            emit(f"serving/traffic/{pattern}/load={load}",
                 row["wall_s"] * 1e6,
                 (f"goodput={row['goodput_tok_s']:.1f}tok_s;"
                  f"ttft_p50={ttft['p50']:.0f}ms;ttft_p99={ttft['p99']:.0f}ms;"
                  f"tpot_p50={tpot['p50']:.0f}ms;tpot_p99={tpot['p99']:.0f}ms;"
                  f"shed={row['shed_rate']:.2f};"
                  f"timeout={row['timeout_rate']:.2f}"
                  if ttft["count"] else
                  f"goodput=0;shed={row['shed_rate']:.2f};"
                  f"timeout={row['timeout_rate']:.2f}") + spd_note)
        results["patterns"][pattern] = rows
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {out_json}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decoding benchmark (BENCH_spec.json)")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--mesh", action="store_true",
                    help="mesh-sharded serving sweep (BENCH_mesh.json); "
                         "force devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="flat vs paged pool on a shared-prefix request "
                         "wave at equal pool bytes (BENCH_paged.json)")
    ap.add_argument("--overload", action="store_true",
                    help="overload shedding goodput + seeded fault-matrix "
                         "crash-free run (BENCH_faults.json)")
    ap.add_argument("--restart", action="store_true",
                    help="cold vs warm-restart TTFT via snapshot "
                         "save/load (BENCH_restart.json)")
    ap.add_argument("--traffic", action="store_true",
                    help="open-loop SLO traffic sweep: Poisson + bursty "
                         "arrivals at 0.5x/1x/2x capacity, p50/p99 "
                         "TTFT/TPOT + goodput per operating point "
                         "(BENCH_traffic.json)")
    ap.add_argument("--traffic-requests", type=int, default=32,
                    help="with --traffic: requests per operating point "
                         "(smaller = faster smoke run)")
    args = ap.parse_args()
    modes = (args.spec, args.mesh, args.shared_prefix, args.overload,
             args.restart, args.traffic)
    if sum(modes) > 1:
        ap.error("--spec / --mesh / --shared-prefix / --overload / "
                 "--restart / --traffic are separate modes")
    if args.spec:
        if args.spec_k <= 0:
            ap.error("--spec requires --spec-k >= 1")
        run_spec(k=args.spec_k)
    elif args.mesh:
        run_mesh()
    elif args.shared_prefix:
        run_shared_prefix()
    elif args.overload:
        run_overload()
    elif args.restart:
        run_restart()
    elif args.traffic:
        run_traffic(n_req=args.traffic_requests)
    else:
        run()

"""Serving-engine benchmark: legacy static batch vs continuous batching,
and the cost of the per-slot sampling lanes.

Measures, at batch/slot counts 1/4/8 on ``qwen3-0.6b --reduced``:

* decode throughput (tokens/s) of the legacy one-shot ``Engine`` (static
  batch, host loop, re-traces its jitted decode on every refreeze) vs the
  pooled ``ContinuousEngine`` (chunked prefill interleaved with decode,
  in-place refreeze, decode compiled exactly once);
* the decode-step retrace count of each across the run — the compile-time
  tax the pooled redesign removes;
* **sampled vs greedy decode ticks** on one engine: the on-device
  temperature/top-k/top-p lanes ride inside the same compiled decode step,
  so switching every request from greedy to seeded sampling must add no
  traces and <5% tick time (reported as ``overhead``).

  PYTHONPATH=src python -m benchmarks.bench_serving
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import (Engine, ContinuousEngine, SamplingParams,
                           retrace_count)

from .common import emit

BATCHES = (1, 4, 8)
PROMPT = 64
STEPS = 96          # > 1 tail fill -> exercises refreeze on both engines
KV_TAIL = 64


def run():
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=KV_TAIL)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    for b in BATCHES:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, PROMPT)),
                           jnp.int32)

        legacy = Engine(params, cfg, kv_mode="sparse")
        legacy.generate({"tokens": toks},
                        SamplingParams(max_new_tokens=3))       # compile
        t0 = time.perf_counter()
        legacy.generate({"tokens": toks},
                        SamplingParams(max_new_tokens=STEPS))
        dt = time.perf_counter() - t0
        legacy_traces = retrace_count(legacy._decode)
        emit(f"serving/legacy/batch={b}", dt * 1e6,
             f"tok_s={b * STEPS / dt:.1f};decode_traces={legacy_traces}")

        eng = ContinuousEngine(params, cfg, slots=b,
                               max_tokens=PROMPT + STEPS + KV_TAIL)
        eng.generate_batch(toks[:, :PROMPT],
                           SamplingParams(max_new_tokens=3))    # compile
        t0 = time.perf_counter()
        eng.generate_batch(toks, SamplingParams(max_new_tokens=STEPS))
        dt = time.perf_counter() - t0
        emit(f"serving/continuous/batch={b}", dt * 1e6,
             f"tok_s={b * STEPS / dt:.1f};"
             f"decode_traces={eng.trace_counts()['decode']}")

    # -- sampled vs greedy decode ticks (one engine, same compiled step) ----
    b = 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, PROMPT)), jnp.int32)
    eng = ContinuousEngine(params, cfg, slots=b,
                           max_tokens=PROMPT + STEPS + KV_TAIL)
    grid = {
        "greedy": SamplingParams(max_new_tokens=STEPS),
        "sampled": SamplingParams(temperature=0.8, top_k=40, top_p=0.95,
                                  seed=0, max_new_tokens=STEPS),
    }
    for sp in grid.values():                                    # compile
        eng.generate_batch(toks, dataclasses.replace(sp, max_new_tokens=3))
    times = {}
    for label, sp in grid.items():
        t0 = time.perf_counter()
        eng.generate_batch(toks, sp)
        times[label] = time.perf_counter() - t0
    overhead = times["sampled"] / times["greedy"] - 1.0
    for label, dt in times.items():
        emit(f"serving/decode_{label}/batch={b}", dt * 1e6,
             f"tok_s={b * STEPS / dt:.1f};"
             f"decode_traces={eng.trace_counts()['decode']};"
             f"overhead={overhead * 100:+.1f}%")


if __name__ == "__main__":
    run()

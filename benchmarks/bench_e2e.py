"""Paper Figs 1 & 11: end-to-end decode speedup vs sparsity across models.

For each model: roofline-predicted per-token decode latency (memory-bound
byte model: weights + KV cache + logits head) dense vs sparse at a sweep of
sparsity levels, context 512 (Fig 1/11 setting) — and the paper's own
models for the figure-1 comparison.  The paper's measured 1.42x at 50%
on Llama-3-8B maps to the byte-reduction ceiling shown here.
"""
from __future__ import annotations

from repro.configs import SHAPES, get_config
from .roofline import arch_params, HBM_BW
from .common import emit

MODELS = ["llama3-8b", "llama3.2-3b", "qwen3-0.6b", "deepseek-67b",
          "rwkv6-7b"]
SPARSITIES = [0.0, 0.2, 0.4, 0.5, 0.6, 0.8]


def decode_bytes(cfg, sparsity: float, context: int = 512,
                 batch: int = 1, kv_sparse: bool = False) -> float:
    p = arch_params(cfg)
    w = p["active"] * ((1 - sparsity) + 1 / 16 if sparsity > 0 else 1) * 2
    w += p["embed"] * 2
    attn_layers = sum(1 for i in range(cfg.n_layers) if cfg.is_attn_layer(i))
    cache = 2.0 * batch * context * cfg.n_kv * cfg.hd * 2 * attn_layers
    if kv_sparse:
        cache *= (1 - 0.4 + 1 / 16)     # 30%K/50%V average
    if cfg.family == "ssm":
        dh = cfg.rwkv_head_dim
        cache = cfg.n_layers * batch * (cfg.d_model // dh) * dh * dh * 4
    return w + cache


def run():
    for m in MODELS:
        cfg = get_config(m)
        base = decode_bytes(cfg, 0.0)
        for s in SPARSITIES:
            b = decode_bytes(cfg, s)
            t_us = b / HBM_BW * 1e6
            emit(f"fig11/{m}/sparsity={s:.1f}", t_us,
                 f"pred_speedup={base/b:.3f}x")
        # the paper's headline: 1.42x at 50% on llama3-8b
        if m == "llama3-8b":
            b50 = decode_bytes(cfg, 0.5)
            emit("fig1/llama3-8b@0.5", b50 / HBM_BW * 1e6,
                 f"pred_speedup={base/b50:.3f}x;paper=1.42x")


if __name__ == "__main__":
    run()

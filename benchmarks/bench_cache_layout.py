"""Paper §6.2: frozen-prefix + ring-tail cache vs realloc-per-token.

The paper reports PyTorch's cache path (reallocate + repeat_kv per token)
is >6x slower than freezing the prefill cache in model state and appending
to a small dynamic buffer.  Measured here directly (CPU wall time of the
two update strategies on a 16k-context cache)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, time_jax


def run(ctx: int = 16384, hkv: int = 8, hd: int = 128, batch: int = 1):
    k = jnp.zeros((batch, hkv, ctx, hd), jnp.bfloat16)
    new = jnp.ones((batch, hkv, 1, hd), jnp.bfloat16)

    # naive: realloc + copy the whole cache every token (PyTorch-style),
    # plus repeat_kv materializing the GQA-expanded cache
    @jax.jit
    def realloc(k, new):
        k2 = jnp.concatenate([k, new], axis=2)
        rep = jnp.repeat(k2, 4, axis=1)          # repeat_kv (g=4)
        return k2, rep.sum()                      # force materialization

    # frozen + ring: O(1) in-place tail update, no repeat materialization
    tail = jnp.zeros((batch, hkv, 128, hd), jnp.bfloat16)

    @jax.jit
    def ring(tail, new, idx):
        return jax.lax.dynamic_update_slice_in_dim(tail, new, idx, axis=2)

    us_realloc = time_jax(realloc, k, new, iters=8)
    us_ring = time_jax(ring, tail, new, jnp.asarray(5), iters=8)
    emit(f"sec6.2/realloc_ctx={ctx}", us_realloc, "")
    emit(f"sec6.2/frozen_ring_ctx={ctx}", us_ring,
         f"speedup={us_realloc/max(us_ring,1e-9):.1f}x;paper=>6x")


if __name__ == "__main__":
    run()

"""Paper Fig 16 (Appendix B): AVX ``num_neuron_groups`` — processing more
output columns per input load improves the vector path, sometimes past AMX.

TPU analogue: the GEMV kernel's output-block width ``bn`` controls how many
output lanes each decompressed input sliver amortizes over.  We sweep the
roofline input-reload factor (each column group re-reads the input vector:
K bytes per group) — the exact effect the paper measures — plus interpret-
mode wall times on a reduced shape as a directional check."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pack, make_mask
from .roofline import HBM_BW
from .common import emit

K, N = 4096, 14336          # up_proj, the paper's Fig 16 workload shape


def run(sparsity: float = 0.5):
    w_bytes = K * N * (1 - sparsity) * 2 + K * N / 8
    for groups in (1, 2, 4, 8, 16, 32):
        bn_total = 128 * groups          # lanes covered per input load
        reloads = -(-N // bn_total)      # times the input vector is re-read
        in_bytes = reloads * K * 2
        t = (w_bytes + in_bytes + N * 4) / HBM_BW
        emit(f"fig16/groups={groups}", t * 1e6,
             f"input_reloads={reloads};paper=more_groups_better")


if __name__ == "__main__":
    run()

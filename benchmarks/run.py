"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  The roofline table (§Roofline of
EXPERIMENTS.md) additionally needs the dry-run artifacts; run
``python -m repro.launch.dryrun --all`` / ``repro.launch.probe --all``
first, then ``python -m benchmarks.roofline``.
"""
import sys
import time


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    from . import (bench_linear, bench_e2e, bench_batch, bench_table1,
                   bench_cache_layout, bench_column_groups, bench_kv,
                   bench_serving)
    bench_linear.run(measure=("--fast" not in sys.argv))
    bench_e2e.run()
    bench_batch.run()
    bench_table1.run()
    bench_cache_layout.run()
    bench_column_groups.run()
    bench_kv.run(train_steps=8 if "--fast" in sys.argv else 40)
    if "--fast" not in sys.argv:
        bench_serving.run()
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()

"""Paper Figs 12 & 13: batched decoding throughput — matrix path (MXU/AMX)
vs vector path (VPU/AVX), bf16 and int8.

The paper's observation: the vector path wins only at batch ~1 (the matrix
unit's input tile is mostly wasted rows); the matrix path pulls ahead as
batch grows; in the compute-bound regime (high batch) sparse loses to dense
(decompression overhead with no byte savings on the critical path).

TPU mapping: MXU macro-tiles process 128 input rows/pass, so batch<128
wastes (128-B)/128 of the MXU (paper: 15/16 of the AMX tile at batch 1).
The VPU path has no such waste but 8x lower peak.  Crossovers below.
"""
from __future__ import annotations

from repro.configs import get_config
from .roofline import arch_params, HBM_BW, PEAK_FLOPS
from .common import emit, INT8_PEAK

BATCHES = [1, 2, 4, 8, 16, 32, 64, 128, 256]
VPU_PEAK = PEAK_FLOPS / 8      # VPU vs MXU throughput ratio on v5e-class


def step_time(cfg, batch, sparsity, path: str, int8: bool = False):
    p = arch_params(cfg)
    bpe = 1 if int8 else 2
    w_bytes = p["active"] * ((1 - sparsity) + (1 / 16 / bpe)
                             if sparsity > 0 else 1) * bpe \
        + p["embed"] * 2
    flops = 2 * p["active"] * batch
    if path == "mxu":
        eff_batch = max(batch, 128)      # macro-tile row occupancy
        peak = INT8_PEAK if int8 else PEAK_FLOPS
        t_c = flops * (eff_batch / batch) / peak
    else:
        t_c = flops / (VPU_PEAK * (2 if int8 else 1))
    return max(t_c, w_bytes / HBM_BW)


def run():
    cfg = get_config("llama3-8b")
    for b in BATCHES:
        t_mxu_d = step_time(cfg, b, 0.0, "mxu")
        t_mxu_s = step_time(cfg, b, 0.5, "mxu")
        t_vpu_s = step_time(cfg, b, 0.5, "vpu")
        tput = lambda t: b / t
        emit(f"fig12/batch={b}", t_mxu_s * 1e6,
             f"tput_mxu_sparse={tput(t_mxu_s):.0f}tok/s;"
             f"tput_mxu_dense={tput(t_mxu_d):.0f};"
             f"tput_vpu_sparse={tput(t_vpu_s):.0f};"
             f"mxu_over_vpu={t_vpu_s/t_mxu_s:.2f}x")
    # Fig 13: int8, Llama-2-7B-ish (paper uses the largest DeepSparse model)
    cfg7 = get_config("llama3-8b")
    for b in (1, 8, 32, 128):
        t_d = step_time(cfg7, b, 0.0, "mxu", int8=True)
        t_s = step_time(cfg7, b, 0.5, "mxu", int8=True)
        emit(f"fig13/int8/batch={b}", t_s * 1e6,
             f"sparse_over_dense={t_d/t_s:.2f}x"
             f"{';compute_bound' if t_d/t_s < 1.01 and b >= 128 else ''}")


if __name__ == "__main__":
    run()

"""Paper Table 1: memory-bound analysis of the up_proj workload.

The paper's VTune profile of 32 consecutive 4096->14336 linears (the
Llama-3-8B up_proj shape, batch 1): dense = 100% memory-bound / 87.5%
DRAM-bound; sparse = 21.1% / 5.7%.  The TPU analogue: the fraction of the
roofline step time attributable to HBM vs MXU for the same workload, from
``compiled.cost_analysis()`` of the two kernels' XLA-fallback programs plus
the analytic byte model."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pack, make_mask
from repro.kernels import ops
from .roofline import PEAK_FLOPS, HBM_BW
from .common import emit


def run(k: int = 4096, n: int = 14336, layers: int = 32, batch: int = 1):
    # analytic (per layer, batch=1): dense vs compressed bytes, same flops
    flops = 2 * batch * k * n
    d_bytes = k * n * 2
    s_bytes = k * n * 2 * (0.5 + 1 / 16)
    for name, b in (("dense", d_bytes), ("sparse", s_bytes)):
        t_mem = b / HBM_BW
        t_cmp = flops / PEAK_FLOPS
        frac = t_mem / (t_mem + t_cmp)
        emit(f"table1/{name}", (t_mem + t_cmp) * layers * 1e6,
             f"memory_bound_frac={100*frac:.1f}%;paper_dense=100/87.5%;"
             f"paper_sparse=21.1/5.7%")

    # measured: HLO bytes-accessed of the two paths (CPU cost model)
    w = jnp.asarray(np.random.default_rng(0).normal(size=(k, n)),
                    jnp.bfloat16)
    x = jnp.ones((batch, k), jnp.bfloat16)
    mask = make_mask(w.astype(jnp.float32), 0.5, "balanced")
    sw = pack(w, mask)
    with ops.backend("xla"):
        cd = jax.jit(lambda x: ops.dense_matmul(x, w)).lower(x).compile() \
            .cost_analysis()
        cs = jax.jit(lambda x: ops.sparse_matmul(x, sw)).lower(x).compile() \
            .cost_analysis()
    emit("table1/hlo_bytes_dense", cd.get("bytes accessed", -1) / 1e6,
         "unit=MB")
    emit("table1/hlo_bytes_sparse", cs.get("bytes accessed", -1) / 1e6,
         f"unit=MB;note=XLA fallback materializes the decompressed tile "
         f"(the Pallas kernel keeps it in VMEM)")


if __name__ == "__main__":
    run()

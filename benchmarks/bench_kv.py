"""Paper §6 (Figs 14, 15, 17, 18): KV-cache unstructured sparsity —
accuracy vs sparsity on a *trained* model + decode speedup at long context.

Accuracy: train a reduced llama3-8b on the synthetic pipeline until it has
real structure, then measure teacher-forced next-token CE through the
frozen-compressed cache at the paper's sparsity grid.  Paper claim: <1%
downstream-accuracy drop at 30% K / 50% V (Fig 14); perplexity +~0.6
(Fig 17).  Speedup: decode-byte model at 16k context (paper: 1.14x).

``--breakdown`` instead profiles one decode tick's attention at the ops
layer: the fused prefix+tail flash-decode (one kernel, zero XLA-side tail
merge) vs the legacy two-pass split (prefix partial + XLA tail attention +
lse merge), plus the per-tick sampler cost, written to ``BENCH_decode.json``.

  PYTHONPATH=src python -m benchmarks.bench_kv --breakdown
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig
from repro.launch.train import train_loop
from repro.optim import OptConfig
from repro.serving import Engine
from .roofline import HBM_BW
from .common import emit

GRID = [(0.0, 0.0), (0.3, 0.5), (0.5, 0.5), (0.7, 0.7), (0.9, 0.9)]


def eval_ce_through_cache(params, cfg, toks, decode_steps=16):
    """Teacher-forced CE of the next `decode_steps` tokens, decoded through
    the frozen compressed cache."""
    prompt, cont = toks[:, :-decode_steps], toks[:, -decode_steps:]
    eng = Engine(params, cfg, kv_mode="sparse")
    cache, logits = eng.prefill({"tokens": prompt})
    ce = []
    for t in range(decode_steps):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ce.append(-jnp.take_along_axis(
            logp, cont[:, t][:, None], axis=1).mean())
        logits, cache = eng._decode(params, cache, cont[:, t][:, None])
    return float(jnp.stack(ce).mean())


def run(train_steps: int = 40):
    cfg = get_config("llama3-8b").reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    params, _, losses = train_loop(
        cfg, train_steps, dc, log_every=1000,
        optc=OptConfig(peak_lr=2e-3, warmup_steps=4, decay_steps=train_steps))
    toks = jnp.asarray(
        np.random.default_rng(123).integers(0, cfg.vocab, (4, 80)), jnp.int32)
    # use in-distribution eval data
    from repro.data import host_batch
    toks = jnp.asarray(host_batch(
        DataConfig(vocab=cfg.vocab, seq_len=80, global_batch=4), 999)["tokens"])

    base_ce = None
    for ks, vs in GRID:
        c = dataclasses.replace(cfg, kv_k_sparsity=ks, kv_v_sparsity=vs)
        ce = eval_ce_through_cache(params, c, toks)
        if base_ce is None:
            base_ce = ce
        emit(f"fig14/K={ks:.1f}_V={vs:.1f}", ce * 1e6,
             f"ce={ce:.4f};delta={(ce-base_ce):.4f};"
             f"ppl_ratio={np.exp(ce-base_ce):.4f}")

    # Fig 15: decode speedup at 16k context from KV byte reduction
    full = get_config("llama3-8b")
    attn_layers = full.n_layers
    for ctx in (2048, 16384):
        cache_b = 2.0 * ctx * full.n_kv * full.hd * 2 * attn_layers
        from .roofline import arch_params
        w = (arch_params(full)["active"] + arch_params(full)["embed"]) * 2
        dense_t = (w + cache_b) / HBM_BW
        sparse_cache = cache_b / 2 * (0.7 + 1 / 16) + \
            cache_b / 2 * (0.5 + 1 / 16)
        sparse_t = (w + sparse_cache) / HBM_BW
        emit(f"fig15/ctx={ctx}", sparse_t * 1e6,
             f"pred_speedup={dense_t/sparse_t:.3f}x;paper@16k=1.14x")
    return losses


def breakdown(slots: int = 8, sb: int = 16, bs: int = 64, tail: int = 64,
              hkv: int = 8, g: int = 4, d: int = 128, vocab: int = 32768,
              backend: str = "xla", out_json: str = "BENCH_decode.json"):
    """Per-tick decode-attention breakdown: fused vs two-pass.

    Builds one pool-layout layer (``slots`` requests, ``sb`` compressed
    blocks of ``bs`` tokens each, a ``tail``-token ring, mixed per-slot
    lengths) and times, per tick:

    * ``fused``      — ``ops.sparse_decode_attention`` with tails: ONE
                       kernel, final output; its ``xla_tail_merge_us`` is
                       structurally 0.0 (there is nothing left to run).
    * ``unfused``    — the legacy split: prefix partial, then the XLA-side
                       grouped tail attention + lse merge that used to run
                       per token per layer.
    * ``sampler_us`` — one ``sample_step`` over ``[slots, vocab]`` logits
                       (sort-free top-k/top-p bucket + logprob lane).
    """
    import json

    from repro.core.sparse_kv import freeze_chunk_blocks, pooled_view
    from repro.kernels import ops, ref
    from repro.serving import sampling
    from .common import time_jax

    rng = np.random.default_rng(0)
    rnd = lambda *s: jnp.asarray(rng.normal(size=s).astype(np.float32))
    k = rnd(slots, hkv, sb * bs, d)
    v = rnd(slots, hkv, sb * bs, d)
    cap = bs * d
    k_bm, k_vl, v_bm, v_vl = freeze_chunk_blocks(k, v, 0.3, 0.5, bs,
                                                 cap, cap)
    k_sp = pooled_view(k_bm, k_vl, bs, d)
    v_sp = pooled_view(v_bm, v_vl, bs, d)
    k_tail = rnd(slots, hkv, tail, d)
    v_tail = rnd(slots, hkv, tail, d)
    q = rnd(slots, hkv * g, d)
    tl = jnp.asarray(rng.integers(0, tail + 1, slots), jnp.int32)
    pl_ = jnp.asarray(rng.integers(0, sb + 1, slots), jnp.int32) * bs
    sm = 1.0 / d ** 0.5

    with ops.backend(backend):
        fused = jax.jit(lambda qq: ops.sparse_decode_attention(
            qq, k_sp, v_sp, hkv, sm, k_tail, v_tail, tl, prefix_len=pl_))
        prefix_only = jax.jit(lambda qq: ops.sparse_decode_attention(
            qq, k_sp, v_sp, hkv, sm, prefix_len=pl_))
        fused_us = time_jax(fused, q)
        prefix_us = time_jax(prefix_only, q)

    # the legacy two-pass tail: grouped tail partial + lse merge, exactly
    # what the fused kernel absorbed off the per-token hot loop
    def two_pass_tail(qq, o1, lse1):
        qg = qq.reshape(slots, hkv, g, d)
        valid = ref._len_valid(tail, tl, slots)
        o2, lse2 = ref.gqa_partial_ref(qg, k_tail, v_tail, sm, valid)
        empty = ~jnp.any(valid, axis=-1)
        lse2 = jnp.where(empty[:, None, None], lse1 - 60.0, lse2)
        o, _ = ref._merge_attn(o1, lse1, o2, lse2)
        return o.reshape(slots, hkv * g, d)

    qg = q.reshape(slots, hkv, g, d)
    kp, vp = ref._unpack_prefix(q, k_sp, v_sp, hkv)
    o1, lse1 = ref.gqa_partial_ref(qg, kp, vp, sm,
                                   ref._len_valid(sb * bs, pl_, slots))
    merge_us = time_jax(jax.jit(two_pass_tail), q, o1, lse1)

    logits = rnd(slots, vocab)
    lanes = sampling.init_lanes(slots)
    lanes["temperature"] = jnp.full((slots,), 0.8, jnp.float32)
    lanes["top_k"] = jnp.full((slots,), 40, jnp.int32)
    lanes["top_p"] = jnp.full((slots,), 0.95, jnp.float32)
    sampler_us = time_jax(jax.jit(sampling.sample_step), logits, lanes,
                          jnp.ones((slots,), bool))

    result = {
        "backend": backend,
        "geometry": {"slots": slots, "prefix_blocks": sb, "bs": bs,
                     "tail": tail, "hkv": hkv, "g": g, "d": d,
                     "vocab": vocab},
        "fused": {"attention_us": fused_us, "xla_tail_merge_us": 0.0},
        "unfused": {"prefix_kernel_us": prefix_us,
                    "xla_tail_merge_us": merge_us,
                    "attention_us": prefix_us + merge_us},
        "sampler_us": sampler_us,
    }
    with open(out_json, "w") as f:
        json.dump(result, f, indent=2)
    emit("decode_breakdown/fused_attention", fused_us,
         "xla_tail_merge_us=0.00")
    emit("decode_breakdown/unfused_prefix", prefix_us, "")
    emit("decode_breakdown/unfused_tail_merge", merge_us,
         f"fused_saves={merge_us:.2f}us_per_layer_per_tick")
    emit("decode_breakdown/sampler", sampler_us, f"vocab={vocab}")
    print(f"[bench_kv] wrote {out_json}")
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--breakdown", action="store_true",
                    help="per-tick decode-attention breakdown (fused vs "
                         "two-pass) instead of the accuracy/speedup sweep")
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "interpret"),
                    help="breakdown: kernel backend to profile")
    ap.add_argument("--train-steps", type=int, default=40)
    args = ap.parse_args()
    if args.breakdown:
        breakdown(backend=args.backend)
    else:
        run(args.train_steps)

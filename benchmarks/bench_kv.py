"""Paper §6 (Figs 14, 15, 17, 18): KV-cache unstructured sparsity —
accuracy vs sparsity on a *trained* model + decode speedup at long context.

Accuracy: train a reduced llama3-8b on the synthetic pipeline until it has
real structure, then measure teacher-forced next-token CE through the
frozen-compressed cache at the paper's sparsity grid.  Paper claim: <1%
downstream-accuracy drop at 30% K / 50% V (Fig 14); perplexity +~0.6
(Fig 17).  Speedup: decode-byte model at 16k context (paper: 1.14x).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig
from repro.launch.train import train_loop
from repro.optim import OptConfig
from repro.serving import Engine
from .roofline import HBM_BW
from .common import emit

GRID = [(0.0, 0.0), (0.3, 0.5), (0.5, 0.5), (0.7, 0.7), (0.9, 0.9)]


def eval_ce_through_cache(params, cfg, toks, decode_steps=16):
    """Teacher-forced CE of the next `decode_steps` tokens, decoded through
    the frozen compressed cache."""
    prompt, cont = toks[:, :-decode_steps], toks[:, -decode_steps:]
    eng = Engine(params, cfg, kv_mode="sparse")
    cache, logits = eng.prefill({"tokens": prompt})
    ce = []
    for t in range(decode_steps):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ce.append(-jnp.take_along_axis(
            logp, cont[:, t][:, None], axis=1).mean())
        logits, cache = eng._decode(params, cache, cont[:, t][:, None])
    return float(jnp.stack(ce).mean())


def run(train_steps: int = 40):
    cfg = get_config("llama3-8b").reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    params, _, losses = train_loop(
        cfg, train_steps, dc, log_every=1000,
        optc=OptConfig(peak_lr=2e-3, warmup_steps=4, decay_steps=train_steps))
    toks = jnp.asarray(
        np.random.default_rng(123).integers(0, cfg.vocab, (4, 80)), jnp.int32)
    # use in-distribution eval data
    from repro.data import host_batch
    toks = jnp.asarray(host_batch(
        DataConfig(vocab=cfg.vocab, seq_len=80, global_batch=4), 999)["tokens"])

    base_ce = None
    for ks, vs in GRID:
        c = dataclasses.replace(cfg, kv_k_sparsity=ks, kv_v_sparsity=vs)
        ce = eval_ce_through_cache(params, c, toks)
        if base_ce is None:
            base_ce = ce
        emit(f"fig14/K={ks:.1f}_V={vs:.1f}", ce * 1e6,
             f"ce={ce:.4f};delta={(ce-base_ce):.4f};"
             f"ppl_ratio={np.exp(ce-base_ce):.4f}")

    # Fig 15: decode speedup at 16k context from KV byte reduction
    full = get_config("llama3-8b")
    attn_layers = full.n_layers
    for ctx in (2048, 16384):
        cache_b = 2.0 * ctx * full.n_kv * full.hd * 2 * attn_layers
        from .roofline import arch_params
        w = (arch_params(full)["active"] + arch_params(full)["embed"]) * 2
        dense_t = (w + cache_b) / HBM_BW
        sparse_cache = cache_b / 2 * (0.7 + 1 / 16) + \
            cache_b / 2 * (0.5 + 1 / 16)
        sparse_t = (w + sparse_cache) / HBM_BW
        emit(f"fig15/ctx={ctx}", sparse_t * 1e6,
             f"pred_speedup={dense_t/sparse_t:.3f}x;paper@16k=1.14x")
    return losses


if __name__ == "__main__":
    run()

"""Continuous-batching serving: pooled cache, scheduler, and the zero-
retrace invariant.

The acceptance bar for the pooled redesign:
* continuous-batching greedy tokens == legacy one-shot engine tokens,
  token for token, across a refreeze and with chunked prefill;
* >=3 refreezes and >=2 admissions/evictions add ZERO jax.jit retraces
  (the decode step compiles exactly once per pool geometry).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.sparse_kv import freeze_chunk_blocks, pooled_view
from repro.core.sparse_format import unpack
from repro.models import lm
from repro.serving import (Engine, ContinuousEngine, CachePool,
                           SamplingParams, Scheduler, retrace_count)


def _sp(max_new_tokens, **kw):
    return SamplingParams(max_new_tokens=max_new_tokens, **kw)


def _setup(seed=0, b=2, s=32, kv_tail=32, **cfg_kw):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=kv_tail, **cfg_kw)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jnp.asarray(np.random.default_rng(seed).integers(
        0, cfg.vocab, (b, s)), jnp.int32)
    return cfg, params, toks


# ---------------------------------------------------------------------------
# pooled primitives
# ---------------------------------------------------------------------------

def test_freeze_chunk_blocks_exact_at_zero_sparsity():
    rng = np.random.default_rng(0)
    b, hkv, c, d, bs = 2, 2, 32, 16, 16
    k = jnp.asarray(rng.normal(size=(b, hkv, c, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, c, d)).astype(np.float32))
    cap = bs * d
    k_bm, k_vl, v_bm, v_vl = freeze_chunk_blocks(k, v, 0.0, 0.0, bs,
                                                 cap, cap)
    back = unpack(pooled_view(k_bm, k_vl, bs, d))      # [B, Hkv, C, D]
    np.testing.assert_array_equal(np.asarray(back), np.asarray(k))
    back_v = unpack(pooled_view(v_bm, v_vl, bs, d))
    np.testing.assert_array_equal(np.asarray(back_v), np.asarray(v))


def test_freeze_chunk_blocks_capped_capacity_is_consistent():
    """With a capacity below the pruned density, the bitmap must describe
    exactly the stored values (the legacy repack bug dropped values but
    kept their bits)."""
    rng = np.random.default_rng(1)
    b, hkv, c, d, bs = 1, 2, 32, 16, 16
    k = jnp.asarray(rng.normal(size=(b, hkv, c, d)).astype(np.float32))
    cap = 128                                          # < bs*d*0.7
    k_bm, k_vl, _, _ = freeze_chunk_blocks(k, k, 0.3, 0.3, bs, cap, cap)
    nnz = int(np.unpackbits(np.asarray(k_bm).view(np.uint8)).sum())
    assert nnz <= k_bm.shape[2] * b * hkv * cap
    back = unpack(pooled_view(k_bm, k_vl, bs, d))
    # every bitmap-claimed entry round-trips its true value
    mask = np.asarray(back) != 0
    np.testing.assert_array_equal(np.asarray(back)[mask],
                                  np.asarray(k)[mask])


def test_pool_refreeze_in_place_static_shapes():
    cfg, params, _ = _setup(kv_tail=16)
    pool = CachePool.build(cfg, slots=2, max_tokens=64, bs=16)
    state = pool.init_state()
    shapes = jax.tree_util.tree_map(lambda a: a.shape, state)
    rng = np.random.default_rng(2)
    # slot 0: full tail; slot 1: half-full (must come back bit-identical)
    for name, leaf in state["layers"].items():
        kv = leaf["kv"]
        kv["k_tail"] = jnp.asarray(rng.normal(
            size=kv["k_tail"].shape)).astype(kv["k_tail"].dtype)
        kv["v_tail"] = kv["k_tail"] * 0.5
    state["tail_len"] = jnp.asarray([16, 8], jnp.int32)
    state["pos"] = jnp.asarray([16, 8], jnp.int32)
    out = jax.jit(pool.refreeze)(state)
    assert jax.tree_util.tree_map(lambda a: a.shape, out) == shapes
    assert out["prefix_blocks"].tolist() == [1, 0]
    assert out["tail_len"].tolist() == [0, 8]
    kv = out["layers"]["l0"]["kv"]
    src = state["layers"]["l0"]["kv"]
    # slot 0 block 0 holds the folded tail exactly (zero sparsity)
    back = unpack(pooled_view(kv["k_bitmap"][0], kv["k_values"][0],
                              pool.bs, cfg.hd))
    np.testing.assert_array_equal(
        np.asarray(back[0, :, :16]),
        np.asarray(src["k_tail"][0, 0].astype(back.dtype)))
    # slot 1 prefix untouched (still empty)
    assert not np.asarray(kv["k_bitmap"])[:, 1].any()


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_admission_when_pool_full():
    sch = Scheduler(slots=2, capacity_tokens=128, bs=16)
    rids = [sch.submit([1, 2, 3], _sp(4)) for _ in range(3)]
    assert sch.admit().rid == rids[0]
    assert sch.admit().rid == rids[1]
    assert sch.admit() is None                    # pool full
    assert len(sch.queue) == 1
    # finishing one frees its slot for the queued request
    slot = sch.active[0].slot
    for t in (7, 8, 9, 10):
        done = sch.record_token(slot, t)
    assert done == "length" and slot in sch.free_slots()
    assert sch.admit().rid == rids[2]
    assert sch.active[slot].rid == rids[2]        # slot recycled


def test_pool_rejects_unsupported_families():
    """Families the pooled path cannot serve must fail loudly at build
    time, not silently drop cross-attention / frontend / recurrent state."""
    for arch in ("rwkv6-7b", "jamba-1.5-large-398b", "seamless-m4t-medium",
                 "internvl2-1b"):
        with pytest.raises(ValueError, match="cannot serve arch"):
            CachePool.build(get_config(arch).reduced(), 2, 64)


def test_scheduler_eos_and_capacity():
    sch = Scheduler(slots=1, capacity_tokens=64, bs=16)
    with pytest.raises(ValueError):
        sch.submit(list(range(60)), _sp(10))      # can never fit
    with pytest.raises(ValueError):
        sch.submit([], _sp(4))                    # empty prompt
    with pytest.raises(ValueError):
        sch.submit([1], _sp(0))                   # nothing to generate
    rid = sch.submit([1, 2], _sp(40, eos_id=42))
    req = sch.admit()
    assert sch.record_token(req.slot, 7) is None
    assert sch.record_token(req.slot, 42) == "stop"   # EOS finishes early
    assert sch.finished[rid].generated == [7, 42]
    assert sch.finished[rid].finish_reason == "stop"


def test_scheduler_chunking_block_aligned():
    sch = Scheduler(slots=1, capacity_tokens=256, bs=16, chunk=40)
    assert sch.chunk == 32                        # rounded down to blocks
    rid = sch.submit(list(range(70)), _sp(1))
    req = sch.admit()
    sizes = []
    while req.prefill_done < len(req.prompt):
        sizes.append(len(sch.prefill_chunk(req)))
    assert sizes == [32, 32, 6]                   # remainder only at the end
    assert rid == req.rid


# ---------------------------------------------------------------------------
# engine equivalence + the zero-retrace acceptance bar
# ---------------------------------------------------------------------------

def test_continuous_matches_legacy_tokens():
    """Interleaved chunked prefill + decode + refreeze must be greedily
    token-identical to the legacy one-shot engine."""
    cfg, params, toks = _setup(b=2, s=32, kv_tail=32)
    legacy = Engine(params, cfg, kv_mode="sparse")
    out_leg, _ = legacy.generate({"tokens": toks}, _sp(41))   # 1+ refreeze

    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16,
                           prefill_chunk=16)
    out = eng.generate_batch(toks, _sp(41))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_leg))


def test_zero_retraces_across_refreezes_and_evictions():
    """>=3 refreezes and >=2 admissions/evictions after warmup add zero
    jax.jit traces; the decode step compiles exactly once."""
    cfg, params, toks = _setup(b=2, s=16, kv_tail=16)
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16)

    # warmup wave: touches every compiled path once (prefill len 16,
    # decode, >=1 refreeze at tail=16, release on completion)
    eng.generate_batch(toks, _sp(21))
    warm = eng.trace_counts()
    assert warm["decode"] == 1

    # second + third waves: 4 more requests through 2 slots -> >=2
    # admissions and evictions; 56 decode steps -> >=3 refreezes per slot
    prompts = np.random.default_rng(3).integers(0, cfg.vocab, (4, 16))
    rids = [eng.submit(row, _sp(56)) for row in prompts]
    res = eng.run()
    assert [len(res[r].token_ids) for r in rids] == [56] * 4
    assert {res[r].finish_reason for r in rids} == {"length"}
    after = eng.trace_counts()
    assert after == warm, f"retraced: {warm} -> {after}"


def test_uneven_prompt_lengths_and_tail_remainders():
    """Prompts that are not block multiples park a remainder in the dense
    tail; decode + refreeze must still match a fresh engine run exactly."""
    cfg, params, _ = _setup(kv_tail=16)
    toks = jnp.asarray(np.random.default_rng(5).integers(
        0, cfg.vocab, (2, 21)), jnp.int32)          # 21 = 16 + 5 remainder
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16)
    out1 = eng.generate_batch(toks, _sp(31))
    # same prompts again through the (recycled) pool -> same tokens
    out2 = eng.generate_batch(toks, _sp(31))
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 31)

"""Subprocess worker: train a reduced model on a (data x model) mesh and
print the loss trajectory — compared against single-device by the parent.
Also exercises: sparse-converted decode under the mesh, ZeRO-1 opt sharding,
and compressed-DP gradients."""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import DataConfig, host_batch
from repro.distributed import (ShardCtx, default_rules, tree_param_specs,
                               to_named)
from repro.distributed.convert_plan import convert_concrete
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.models import module as mod
from repro.optim import OptConfig, init_opt_state
from repro.train import (make_train_step, make_compressed_grads,
                         init_dp_error_state)


def main():
    which = sys.argv[1]
    import dataclasses
    cfg = get_config("qwen3-0.6b").reduced()
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)

    if which == "train":
        # f32 so single-vs-sharded comparison isolates math from bf16
        # reduction-order noise (verified identical to ~1e-6)
        cfg = dataclasses.replace(cfg, param_dtype="float32",
                                  compute_dtype="float32")
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = ShardCtx(mesh, default_rules(False, cfg))
        params = lm.init_params(cfg, jax.random.PRNGKey(cfg.n_layers))
        opt = init_opt_state(params)
        step = jax.jit(make_train_step(
            cfg, ctx, OptConfig(peak_lr=1e-3, warmup_steps=1,
                                decay_steps=4)))
        losses = []
        for i in range(4):
            batch = {k: jnp.asarray(v) for k, v in host_batch(dc, i).items()}
            params, opt, mets = step(params, opt, batch)
            losses.append(float(mets["loss"]))
        print(json.dumps({"losses": losses}))

    elif which == "decode_sparse":
        mesh = make_mesh((2, 4), ("data", "model"))
        ctx = ShardCtx(mesh, default_rules(False, cfg))
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        sp = convert_concrete(params, lm.model_specs(cfg), cfg, ctx)
        cache = lm.init_cache(cfg, 2, 128, mode="sparse")
        cache["pos"] = jnp.asarray(128, jnp.int32)
        with mesh:
            logits, cache2 = jax.jit(
                lambda p, c, t: lm.forward_decode(p, c, t, cfg, ctx))(
                    sp, cache, jnp.ones((2, 1), jnp.int32))
        ok = bool(np.all(np.isfinite(np.asarray(logits))))
        print(json.dumps({"ok": ok, "shape": list(logits.shape)}))

    elif which == "compressed":
        mesh = make_mesh((8, 1), ("data", "model"))
        ctx = ShardCtx(mesh, default_rules(False, cfg))
        params = lm.init_params(cfg, jax.random.PRNGKey(1))
        err = init_dp_error_state(params, 8)
        batch = {k: jnp.asarray(v) for k, v in host_batch(dc, 0).items()}
        gfn = jax.jit(make_compressed_grads(cfg, ctx, scheme="bf16"))
        with mesh:
            loss_c, g_c, err2 = gfn(params, err, batch)
        # reference: plain grads
        from repro.train import loss_fn
        loss_r, g_r = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, ShardCtx(None, {})))(params)
        gl_c = np.asarray(jax.tree_util.tree_leaves(g_c)[0], np.float32)
        gl_r = np.asarray(jax.tree_util.tree_leaves(g_r)[0], np.float32)
        rel = float(np.abs(gl_c - gl_r).mean() / (np.abs(gl_r).mean() + 1e-12))
        err_mag = float(max(np.abs(np.asarray(l)).max()
                            for l in jax.tree_util.tree_leaves(err2)))
        print(json.dumps({"loss_c": float(loss_c), "loss_r": float(loss_r),
                          "rel": rel, "err_mag": err_mag}))

    elif which == "elastic":
        # train 2 steps on (2,4) mesh, checkpoint, restore onto (4,2) mesh
        from repro.checkpoint import CheckpointManager
        import tempfile
        d = tempfile.mkdtemp()
        mesh1 = make_mesh((2, 4), ("data", "model"))
        ctx1 = ShardCtx(mesh1, default_rules(False, cfg))
        params = lm.init_params(cfg, jax.random.PRNGKey(2))
        opt = init_opt_state(params)
        step1 = jax.jit(make_train_step(cfg, ctx1, OptConfig(peak_lr=1e-3)))
        for i in range(2):
            batch = {k: jnp.asarray(v) for k, v in host_batch(dc, i).items()}
            params, opt, m1 = step1(params, opt, batch)
        ck = CheckpointManager(d)
        ck.save(2, {"params": params, "opt": opt}, blocking=True)

        mesh2 = make_mesh((4, 2), ("data", "model"))
        ctx2 = ShardCtx(mesh2, default_rules(False, cfg))
        specs = lm.model_specs(cfg)
        pspecs = tree_param_specs(ctx2, specs, mod.abstract(specs))
        shardings = to_named(ctx2, pspecs)
        state, _ = ck.restore(2, {"params": params, "opt": opt},
                              shardings={"params": shardings,
                                         "opt": None} if False else None)
        params2, opt2 = state["params"], state["opt"]
        params2 = jax.device_put(params2, shardings)
        step2 = jax.jit(make_train_step(cfg, ctx2, OptConfig(peak_lr=1e-3)))
        batch = {k: jnp.asarray(v) for k, v in host_batch(dc, 2).items()}
        _, _, m2 = step2(params2, opt2, batch)
        print(json.dumps({"loss_before": float(m1["loss"]),
                          "loss_after": float(m2["loss"])}))


if __name__ == "__main__":
    main()

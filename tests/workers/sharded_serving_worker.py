"""Subprocess worker: the mesh-sharded serving parity suite.

Runs on a forced 8-host-device platform (set before jax import, so the
parent test process can stay single-device) and prints one JSON record:

* ``engine`` — greedy token streams of the unsharded ``ContinuousEngine``
  vs ``ContinuousEngine(mesh=...)`` on dp-only (8x1) and dp x tp (4x2)
  meshes, across a lockstep wave AND a staggered admissions/evictions
  wave (refreezes included), plus each sharded engine's trace counts
  before/after the second wave (the zero-retrace bar);
* ``spec`` — the same parity bar for the draft–verify engine under the
  4x2 mesh (one jitted verify panel + on-device rollback, sharded);
* ``pool`` — a refreeze + rollback round-trip on mesh-sharded pool state
  vs the same transitions unsharded (observable state equality).
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import dataclasses
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.distributed import serving_sharding
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.serving import (CachePool, ContinuousEngine, SamplingParams,
                           SpecConfig, stable_trace_counts)


def _setup():
    cfg = get_config("qwen3-0.6b").reduced()
    # f32 so sharded-vs-unsharded token identity isolates placement from
    # bf16 reduction-order noise (like the sharded-train worker)
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=16, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (4, 16))
    return cfg, params, jnp.asarray(toks, jnp.int32)


def _waves(eng, toks):
    """Lockstep wave + staggered wave (admissions, evictions, unaligned
    prompts, > kv_tail generations -> refreezes)."""
    out1 = np.asarray(eng.generate_batch(toks, SamplingParams(
        max_new_tokens=24))).tolist()
    rids = [eng.submit(np.asarray(toks[i % 4][:7 + 3 * i]),
                       SamplingParams(max_new_tokens=20 - 2 * i))
            for i in range(6)]
    res = eng.run()
    out2 = [list(res[r].token_ids) for r in rids]
    return out1, out2


def run_engine(cfg, params, toks):
    base = ContinuousEngine(params, cfg, slots=4, max_tokens=96, bs=16)
    b1, b2 = _waves(base, toks)
    rec = {"meshes": {}}
    for label, shape in (("dp8", (8, 1)), ("dp4tp2", (4, 2))):
        mesh = make_mesh(shape, ("data", "model"))
        eng = ContinuousEngine(params, cfg, slots=4, max_tokens=96, bs=16,
                               mesh=mesh)
        o1, o2 = _waves(eng, toks)
        warm = eng.trace_counts()
        o1b, o2b = _waves(eng, toks)    # repeat both waves: must not trace
        after = eng.trace_counts()
        rec["meshes"][label] = {
            "tokens_match": (o1 == b1 and o2 == b2
                             and o1b == b1 and o2b == b2),
            "warm": warm, "after": after,
            "stable": stable_trace_counts(after) == stable_trace_counts(warm),
            "decode_traces": after["decode"],
        }
    return rec


def run_spec(cfg, params, toks):
    base = ContinuousEngine(params, cfg, slots=4, max_tokens=96, bs=16)
    b1, b2 = _waves(base, toks)
    mesh = make_mesh((4, 2), ("data", "model"))
    eng = ContinuousEngine(params, cfg, slots=4, max_tokens=96, bs=16,
                           mesh=mesh, spec=SpecConfig(k=3))
    o1, o2 = _waves(eng, toks)
    warm = eng.trace_counts()
    o1b, _ = _waves(eng, toks)
    after = eng.trace_counts()
    return {
        "tokens_match": o1 == b1 and o2 == b2 and o1b == b1,
        "verify_traces": after.get("verify"),
        "stable": stable_trace_counts(after) == stable_trace_counts(warm),
        "hist_tail": int(eng.spec_hist[1:].sum()),
    }


def _visible(state, pool):
    """Observable (length-gated) pool state, JSON-comparable digest."""
    out = {"pos": np.asarray(state["pos"]).tolist(),
           "prefix_blocks": np.asarray(state["prefix_blocks"]).tolist(),
           "tail_len": np.asarray(state["tail_len"]).tolist()}
    tl = np.asarray(state["tail_len"])
    for name, leaf in state["layers"].items():
        kv = leaf["kv"]
        live = (np.arange(pool.tail)[None, None, None, :, None]
                < tl[None, :, None, None, None])
        for key in ("k_tail", "v_tail"):
            out[f"{name}/{key}"] = float(
                np.abs(np.where(live, np.asarray(kv[key], np.float64), 0.0)
                       ).sum())
        for key in ("k_bitmap", "k_values", "v_bitmap", "v_values"):
            out[f"{name}/{key}"] = float(
                np.abs(np.asarray(kv[key], np.float64)).sum())
    return out


def run_pool(cfg, params, toks):
    """append -> rollback -> re-append -> refreeze, sharded vs unsharded."""
    pool = CachePool.build(cfg, slots=4, max_tokens=64, bs=16)
    mesh = make_mesh((4, 2), ("data", "model"))
    ctx = serving_sharding.serving_ctx(mesh, cfg)
    axes = pool.state_axes()
    rng = np.random.default_rng(3)
    p = lm.period_len(cfg)
    t = pool.tail
    shape = (cfg.n_layers // p, pool.slots, cfg.n_kv, t, cfg.hd)
    panels = {f"l{j}": {"k": jnp.asarray(rng.normal(size=shape), cfg.cdtype),
                        "v": jnp.asarray(rng.normal(size=shape), cfg.cdtype)}
              for j in range(p)}

    def transitions(state, shardings=None):
        kw = lambda in_s: ({} if shardings is None else
                           {"in_shardings": in_s,
                            "out_shardings": shardings[0]})
        if shardings is None:
            st_sh = pan_sh = vec_sh = None
        else:
            st_sh, pan_sh, vec_sh = shardings
        append = jax.jit(pool.append_many, **kw((st_sh, pan_sh, vec_sh)))
        roll = jax.jit(pool.rollback, **kw((st_sh, vec_sh)))
        refreeze = jax.jit(pool.refreeze, **kw((st_sh,)))
        st = append(state, panels, jnp.asarray([t, t, t, t], jnp.int32))
        st = roll(st, jnp.asarray([5, 0, 2, t], jnp.int32))
        st = append(st, panels, jnp.asarray([5, 0, 2, t], jnp.int32))
        return refreeze(st)

    plain = transitions(pool.init_state())

    st0 = serving_sharding.shard_state(ctx, pool.init_state(), axes)
    st_sh = serving_sharding.state_shardings(ctx, st0, axes)
    sharded = transitions(st0)
    # and once more with pinned in/out shardings (the engine's jit mode)
    rep = serving_sharding.replicated(ctx)
    pan_sh = jax.tree_util.tree_map(lambda _: rep, panels)
    vec_sh = serving_sharding.vec_sharding(ctx, pool.slots)
    pinned = transitions(st0, (st_sh, pan_sh, vec_sh))

    va, vb, vc = (_visible(s, pool) for s in (plain, sharded, pinned))
    return {"roundtrip_match": va == vb == vc,
            "prefix_blocks": va["prefix_blocks"],
            "tail_len": va["tail_len"]}


def main():
    cfg, params, toks = _setup()
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    rec = {"devices": jax.device_count()}
    if which in ("all", "engine"):
        rec["engine"] = run_engine(cfg, params, toks)
    if which in ("all", "spec"):
        rec["spec"] = run_spec(cfg, params, toks)
    if which in ("all", "pool"):
        rec["pool"] = run_pool(cfg, params, toks)
    print(json.dumps(rec))


if __name__ == "__main__":
    main()

"""Subprocess worker: crash-safe warm-restart parity.

Two phases, each its own process (argv: ``phase snapshot_dir``), printing
one JSON line on stdout:

* ``save`` — serve a shared-prefix wave on a paged engine (freezing the
  prefix pages), ``save_snapshot``, then run the follow-up wave on the
  SAME never-restarted engine (the parity reference) and **hard-exit via
  ``os._exit(0)``** — no atexit hooks, no interpreter teardown, the
  closest a test can get to dying right after the snapshot rename.
* ``restore`` — a fresh process builds a fresh engine, ``load_snapshot``s,
  serves the same follow-up wave, and reports its tokens plus whether the
  restored trie let admission skip the shared prefill.

The parent test asserts restore's follow-up tokens are identical to
save's, the restored page count matches, and a prefix hit actually
happened.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import dataclasses
import json

import numpy as np
import jax

from repro.configs import get_config
from repro.models import lm
from repro.serving import ContinuousEngine, SamplingParams


def _setup():
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=16, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, (48,)).tolist()
    wave = [shared + rng.integers(0, cfg.vocab, (4,)).tolist()
            for _ in range(2)]
    followup = [shared + rng.integers(0, cfg.vocab, (6,)).tolist()
                for _ in range(2)]
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           prefill_chunk=32, paged=True)
    return eng, wave, followup


def _serve(eng, prompts):
    sp = SamplingParams(max_new_tokens=6)
    rids = [eng.submit(p, sp) for p in prompts]
    res = eng.run()
    return [list(res[r].token_ids) for r in rids]


def main():
    phase, snap = sys.argv[1], sys.argv[2]
    eng, wave, followup = _setup()
    if phase == "save":
        _serve(eng, wave)
        n_pages = len(eng._trie)
        eng.save_snapshot(snap)
        follow_toks = _serve(eng, followup)
        print(json.dumps({"n_pages": n_pages,
                          "followup_tokens": follow_toks,
                          "crash": "os._exit"}))
        sys.stdout.flush()
        os._exit(0)                    # die hard: no teardown after save
    elif phase == "restore":
        restored = eng.load_snapshot(snap)
        trie_len = len(eng._trie)
        sp = SamplingParams(max_new_tokens=6)
        rids = [eng.submit(p, sp) for p in followup]
        eng.step()                     # admission tick
        # a trie hit admits with the restored 48-token shared prefix
        # already marked prefilled; a cold admission's first chunk is <= 32
        skipped = any(r.prefill_done >= 48
                      for r in eng.scheduler.active.values())
        res = eng.run()
        follow_toks = [list(res[r].token_ids) for r in rids]
        print(json.dumps({"restored": restored, "trie_len": trie_len,
                          "followup_tokens": follow_toks,
                          "prefill_skipped": skipped}))
    else:
        raise SystemExit(f"unknown phase {phase!r}")


if __name__ == "__main__":
    main()

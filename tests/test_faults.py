"""Fault-tolerant serving: the lifecycle + crash-recovery acceptance bar.

What this file pins down:

* **Load shedding** — a bounded admission queue rejects overflow at
  submit time (``finish_reason="shed"``): no slot, no pages, one final
  ``on_token`` snapshot, live traffic untouched.
* **Deadlines** — ``ttft_deadline_s`` fires only before the first token;
  ``deadline_s`` bounds total wall clock (queued requests expire too);
  and the precedence rule: a stop committed last tick beats a later
  deadline check, so a deadline can never retract emitted output.
  Driven by an injected clock — no real sleeping.
* **Cancellation** — ``cancel(rid)`` works queued / prefilling /
  decoding, releases the slot through the normal batched path, and the
  co-tenants' token streams are bit-identical to a run where the victim
  never existed.
* **Backoff requeue** — a deferred queue head doubles its backoff up to
  the cap and nothing admits around it (FIFO preserved).
* **Seeded fault matrix** — a :class:`FaultPlan` covering every engine
  site (page exhaustion, drafter error, cancels mid-prefill and
  mid-spec-window, double release) replayed against a paged+speculative
  engine until the plan drains: every request terminal, zero steady-state
  retraces, allocator refcounts conserved, untouched requests
  token-identical to a fault-free run.  CI runs this under
  ``REPRO_CHECKIFY=1`` so the device-side refcount invariants are live.
* **Warm restart** — snapshot save/load round-trips the paged arena +
  prefix index (follow-up wave token-identical, trie hits preserved);
  geometry mismatches and corrupt snapshot files raise readable
  ``ValueError``\\ s and never half-restore; the crash parity test
  (subprocess, ``-k restart``) hard-exits after saving and proves a new
  process resumes with identical greedy output.
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import (BlockAllocator, CachePool, ContinuousEngine,
                           Fault, FaultError, FaultPlan, PrefixTrie,
                           SamplingParams, Scheduler, SpecConfig,
                           corrupt_snapshot, stable_trace_counts)
from repro.serving.faults import (DOUBLE_RELEASE, DRAFTER_ERROR,
                                  ENGINE_SITES, PAGE_EXHAUSTION)

WORKER = os.path.join(os.path.dirname(__file__), "workers",
                      "restart_worker.py")


class FakeClock:
    """Injected monotonic clock: tests advance time, nothing sleeps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# fault plan: seeded, replayable, must drain
# ---------------------------------------------------------------------------

def test_fault_plan_seeded_replay_take_and_exhaustion():
    a = FaultPlan.generate(seed=7, ticks=20)
    b = FaultPlan.generate(seed=7, ticks=20)
    assert a.pending() == b.pending()
    assert {f.site for f in a.pending()} == set(ENGINE_SITES)
    # a fault is not due before its tick, fires at the first tick >= it,
    # and fires exactly once
    plan = FaultPlan([Fault(DOUBLE_RELEASE, 5), Fault(DOUBLE_RELEASE, 2)])
    assert not plan.take(DOUBLE_RELEASE, 1)
    assert plan.take(DOUBLE_RELEASE, 3)          # oldest (tick 2) pops first
    assert not plan.take(PAGE_EXHAUSTION, 99)    # wrong site never matches
    assert not plan.exhausted()
    assert plan.take(DOUBLE_RELEASE, 7)
    assert plan.exhausted() and plan.fired == [(3, DOUBLE_RELEASE),
                                               (7, DOUBLE_RELEASE)]
    # seeded victim selection replays
    p1, p2 = FaultPlan(seed=3), FaultPlan(seed=3)
    picks1 = [p1.choose(list(range(10))) for _ in range(8)]
    picks2 = [p2.choose(list(range(10))) for _ in range(8)]
    assert picks1 == picks2


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        Fault("frobnicate", 1)
    with pytest.raises(ValueError, match="tick"):
        Fault(DOUBLE_RELEASE, -1)
    with pytest.raises(ValueError, match="at least one option"):
        FaultPlan().choose([])
    with pytest.raises(FaultError, match="injected fault"):
        FaultPlan(seed=4).raise_fault(DRAFTER_ERROR)


# ---------------------------------------------------------------------------
# scheduler: shed / backoff / deadlines (host-only, injected clock)
# ---------------------------------------------------------------------------

def test_scheduler_sheds_past_queue_bound():
    clk = FakeClock()
    sch = Scheduler(slots=1, capacity_tokens=64, bs=16, clock=clk,
                    max_queue=2)
    r1 = sch.submit([1, 2, 3])
    r2 = sch.submit([4, 5, 6])
    r3 = sch.submit([7, 8, 9])                   # queue full -> shed
    assert len(sch.queue) == 2
    shed = sch.finished[r3]
    assert shed.finish_reason == "shed" and shed.finished_time == clk.t
    assert r1 not in sch.finished and r2 not in sch.finished
    # an unbounded scheduler never sheds
    free = Scheduler(slots=1, capacity_tokens=64, bs=16, clock=clk)
    for _ in range(10):
        free.submit([1])
    assert not free.finished


def test_scheduler_backoff_doubles_and_preserves_fifo():
    clk = FakeClock()
    sch = Scheduler(slots=2, capacity_tokens=64, bs=16, clock=clk,
                    backoff_base=0.01, backoff_cap=0.03)
    ra = sch.submit([1, 2])
    rb = sch.submit([3, 4])
    b1 = sch.defer_admission()
    assert b1 == 0.01
    assert sch.admit() is None                   # head backing off
    b2 = sch.defer_admission()
    assert b2 == 2 * b1
    b3 = sch.defer_admission()
    assert b3 == 0.03                            # capped
    # nothing admits around the backing-off head: FIFO holds
    clk.t = 0.02
    assert sch.admit() is None
    clk.t = 0.05
    first = sch.admit()
    assert first.rid == ra
    assert sch.admit().rid == rb                 # rb never jumped the line


def test_scheduler_deadlines_ttft_vs_total():
    clk = FakeClock()
    sch = Scheduler(slots=2, capacity_tokens=64, bs=16, clock=clk)
    ra = sch.submit([1, 2], SamplingParams(max_new_tokens=4,
                                           ttft_deadline_s=1.0))
    rb = sch.submit([3, 4], SamplingParams(max_new_tokens=4,
                                           deadline_s=2.0))
    a, b = sch.admit(), sch.admit()
    assert (a.rid, b.rid) == (ra, rb)
    # first token in time: the ttft deadline disarms
    clk.t = 0.5
    sch.record_token(a.slot, 11)
    clk.t = 1.5
    assert sch.expire() == []                    # ra produced in time
    # the total deadline fires even mid-stream
    sch.record_token(b.slot, 22)
    clk.t = 2.5
    expired = sch.expire()
    assert [r.rid for r in expired] == [rb]
    assert expired[0].finish_reason == "timeout" and expired[0].slot >= 0
    # queued requests expire without ever taking a slot
    rq = sch.submit([5], SamplingParams(max_new_tokens=1,
                                        ttft_deadline_s=0.1))
    clk.t = 3.0
    (gone,) = sch.expire()
    assert gone.rid == rq and gone.slot == -1
    assert gone.finish_reason == "timeout"


def test_scheduler_cancel_everywhere_and_validation():
    clk = FakeClock()
    sch = Scheduler(slots=1, capacity_tokens=64, bs=16, clock=clk)
    ra = sch.submit([1, 2])
    rb = sch.submit([3, 4])
    sch.admit()
    queued = sch.cancel(rb)
    assert queued.finish_reason == "cancelled" and queued.slot == -1
    active = sch.cancel(ra)
    assert active.finish_reason == "cancelled" and active.slot == 0
    assert not sch.active
    assert sch.cancel(ra) is None                # already finished: no-op
    assert sch.cancel(999) is None               # unknown rid: no-op


def test_prefix_trie_reload_keeps_bound_callbacks():
    """``reload`` mutates the trie in place, so the allocator's bound
    ``on_evict=trie.drop`` keeps pointing at the live index — an eviction
    after a warm restart must invalidate the RESTORED hash."""
    trie = PrefixTrie()
    alloc = BlockAllocator(1, on_evict=trie.drop)
    trie.insert(111, 0)
    trie.reload([(222, 0)])                      # restart: new population
    assert dict(trie.items()) == {222: 0}
    alloc.restore_registered([(222, 0)])
    alloc.alloc(1)                               # forces the LRU eviction
    assert len(trie) == 0                        # drop hit the same object


# ---------------------------------------------------------------------------
# checkpoint manager: corrupt / mismatched restores fail readably
# ---------------------------------------------------------------------------

def test_checkpoint_restore_errors_are_readable(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
    mgr.save(1, tree, blocking=True)

    with pytest.raises(ValueError, match="not found.*available"):
        mgr.restore(7, tree)
    with pytest.raises(ValueError, match=r"available steps: \[1\]"):
        mgr.read_manifest(7)
    with pytest.raises(ValueError, match="missing array"):
        mgr.restore(1, {"w": tree["w"], "extra": np.zeros(2)})
    with pytest.raises(ValueError, match="expects shape"):
        mgr.restore(1, {"w": np.zeros((3, 2), np.float32)})

    # a torn file (truncated npz) must answer with the corruption message,
    # not a raw zipfile traceback
    corrupt_snapshot(str(tmp_path), mode="truncate")
    with pytest.raises(ValueError, match="corrupt"):
        mgr.restore(1, tree)


def test_corrupt_snapshot_modes(tmp_path):
    with pytest.raises(ValueError, match="no snapshot steps"):
        corrupt_snapshot(str(tmp_path))
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"w": np.zeros(64, np.float32)}, blocking=True)
    path = corrupt_snapshot(str(tmp_path), mode="garbage", seed=1)
    assert path.endswith("arrays.npz")
    with pytest.raises(ValueError, match="corrupt"):
        mgr.restore(1, {"w": np.zeros(64, np.float32)})
    with pytest.raises(ValueError, match="unknown corruption mode"):
        corrupt_snapshot(str(tmp_path), mode="eat")


# ---------------------------------------------------------------------------
# pool: release is a masked no-op on an already-free slot (checkify live)
# ---------------------------------------------------------------------------

def test_release_idempotent_under_checkify():
    """Releasing a slot twice must NOT fire the refcount-underflow check:
    the live mask is gated on ``prefix_blocks``, so the second release sees
    an empty prefix and decrements nothing — the device half of the
    double-release fault site."""
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=16)
    pool = CachePool.build(cfg, slots=3, max_tokens=64, bs=16, paged=True,
                           checkify=True)
    from repro.serving.cache_pool import checkified_raw
    checked = jax.jit(checkified_raw(pool.release))

    def release(state, vec):
        err, out = checked(state, jnp.asarray(vec, jnp.int32))
        err.throw()
        return dict(out)

    tb = pool.tail // pool.bs
    state = pool.init_state()
    fill = jnp.asarray([16, 0, 0], jnp.int32)
    state = dict(state, tail_len=fill, pos=state["pos"] + fill)
    ids = np.zeros((pool.slots, tb), np.int32)
    state = jax.jit(checkified_raw(pool.refreeze))(
        state, jnp.asarray(ids))[1]
    state = dict(state)
    assert int(np.asarray(state["refcount"]).sum()) == 1

    rel = np.full(pool.slots, -1, np.int32)
    rel[0] = 0
    state = release(state, rel)
    assert int(np.asarray(state["refcount"]).sum()) == 0
    # second release of the same slot: masked no-op, no checkify error
    again = release(state, rel)
    assert int(np.asarray(again["refcount"]).sum()) == 0
    assert np.asarray(again["prefix_blocks"]).tolist() == [0, 0, 0]


# ---------------------------------------------------------------------------
# engine: shed / deadlines / cancellation (injected clock, flat pool)
# ---------------------------------------------------------------------------

def _setup(seed=0):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=16, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def test_engine_shed_deadline_and_eos_precedence():
    cfg, params = _setup()
    clk = FakeClock()
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           prefill_chunk=32, max_queue=2, clock=clk)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (20,)).tolist() for _ in range(4)]

    # shed: 2 queued fills the bound; the third submit is rejected at the
    # door with exactly one final callback and no registration
    snaps = []
    r1 = eng.submit(prompts[0], SamplingParams(max_new_tokens=6))
    r2 = eng.submit(prompts[1], SamplingParams(max_new_tokens=6))
    r3 = eng.submit(prompts[2], SamplingParams(max_new_tokens=6),
                    on_token=snaps.append)
    assert [s.finish_reason for s in snaps] == ["shed"]
    assert eng.fault_counters["shed"] == 1 and r3 not in eng._callbacks
    out = eng.run()
    assert out[r1].finish_reason == "length" and len(out[r1].token_ids) == 6
    assert out[r2].finish_reason == "length"
    baseline = list(out[r1].token_ids)

    # deadline mid-stream: advance the clock past deadline_s after a few
    # ticks — partial output survives, finish_reason flips to timeout, and
    # the co-tenant (no deadline) is token-identical to the clean run
    ra = eng.submit(prompts[0], SamplingParams(max_new_tokens=6))
    rb = eng.submit(prompts[3], SamplingParams(max_new_tokens=6,
                                               deadline_s=5.0))
    got = {}
    while not eng.scheduler.done():
        eng.step()
        vb = eng.scheduler.active or {}
        if any(r.rid == rb and len(r.generated) >= 2
               for r in vb.values()):
            clk.t += 10.0                        # blow rb's deadline
    res = {rid: req.output() for rid, req in eng.scheduler.finished.items()}
    assert res[rb].finish_reason == "timeout"
    assert 2 <= len(res[rb].token_ids) < 6       # partial output retained
    assert res[ra].finish_reason == "length"
    assert list(res[ra].token_ids) == baseline
    assert eng.fault_counters["timeout"] == 1
    assert not eng._blocks and not eng.scheduler.active

    # precedence: the deadline passes AFTER the final token committed —
    # the committed stop must win (deadline never retracts output)
    rc = eng.submit(prompts[1], SamplingParams(max_new_tokens=3,
                                               deadline_s=50.0))
    while not eng.scheduler.done():
        eng.step()
    clk.t += 100.0                               # now > deadline, too late
    eng.step()                                   # expiry pass sees finished
    outc = eng.scheduler.finished[rc].output()
    assert outc.finish_reason == "length" and len(outc.token_ids) == 3
    assert eng.fault_counters["timeout"] == 1    # unchanged

    # ttft deadline: a queued request that never got a slot in time
    eng.submit(prompts[0], SamplingParams(max_new_tokens=6))
    eng.submit(prompts[1], SamplingParams(max_new_tokens=6))
    eng.step()                                   # admit both into the slots
    rq = eng.submit(prompts[2], SamplingParams(max_new_tokens=6,
                                               ttft_deadline_s=1.0))
    eng.step()                                   # both slots busy, rq queued
    clk.t += 2.0
    eng.run()
    assert eng.scheduler.finished[rq].output().finish_reason == "timeout"


def test_cancellation_token_identity():
    """Cancelling one request leaves the co-tenants' token streams
    bit-identical to a run where the victim never existed, the slot is
    recycled, and nothing retraces."""
    cfg, params = _setup()
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           prefill_chunk=32)
    rng = np.random.default_rng(1)
    pa = rng.integers(0, cfg.vocab, (20,)).tolist()
    pb = rng.integers(0, cfg.vocab, (24,)).tolist()
    sp = SamplingParams(max_new_tokens=8)

    ra = eng.submit(pa, sp)
    solo = list(eng.run()[ra].token_ids)
    warm = eng.trace_counts()

    # cancel mid-decode: victim keeps its partial tokens, survivor matches
    ra = eng.submit(pa, sp)
    snaps = []
    rv = eng.submit(pb, sp, on_token=snaps.append)
    while not any(s.request_id == rv and len(s.token_ids) >= 2
                  for s in snaps):
        eng.step()
    assert eng.cancel(rv) is True
    assert eng.cancel(rv) is False               # second cancel: quiet no-op
    assert snaps[-1].finish_reason == "cancelled"
    out = eng.run()
    assert list(out[ra].token_ids) == solo
    assert out[rv].finish_reason == "cancelled"
    assert eng.fault_counters["cancelled"] == 1

    # cancel while still queued: never takes a slot, survivors unaffected
    ra = eng.submit(pa, sp)
    rb = eng.submit(pb, sp)
    rq = eng.submit(pa, sp)                      # 3rd request, 2 slots
    assert eng.cancel(rq) is True
    out = eng.run()
    assert list(out[ra].token_ids) == solo
    assert out[rq].finish_reason == "cancelled"
    assert len(out[rq].token_ids) == 0
    after = eng.trace_counts()
    assert stable_trace_counts(after) == stable_trace_counts(warm), \
        f"cancellation retraced: {warm} -> {after}"
    assert not eng.scheduler.active and not eng._blocks


# ---------------------------------------------------------------------------
# engine: the seeded fault matrix (paged + speculative)
# ---------------------------------------------------------------------------

def _fault_wave(cfg, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, (32,)).tolist()
    return [shared + rng.integers(0, cfg.vocab, (4,)).tolist(),
            shared + rng.integers(0, cfg.vocab, (7,)).tolist(),
            rng.integers(0, cfg.vocab, (40,)).tolist(),
            rng.integers(0, cfg.vocab, (12,)).tolist()]


def _drive_matrix(eng, prompts, plan=None, max_ticks=400):
    """Keep the engine under traffic until the plan drains (or one wave
    finishes, fault-free); returns {(wave, prompt index): Request}."""
    sp = SamplingParams(max_new_tokens=10)
    done = {}
    wave = 0
    rids = {eng.submit(p, sp): (wave, i) for i, p in enumerate(prompts)}
    for _ in range(max_ticks):
        if eng.scheduler.queue and not eng.scheduler.active:
            # whole queue backing off (injected page exhaustion): idle-wait
            # like a real server tick instead of spinning past the backoff
            time.sleep(0.005)
        eng.step()
        if eng.scheduler.done():
            for rid, key in rids.items():
                done[key] = eng.scheduler.finished[rid]
            if plan is None or plan.exhausted():
                break
            wave += 1
            rids = {eng.submit(p, sp): (wave, i)
                    for i, p in enumerate(prompts)}
    assert eng.scheduler.done(), "matrix run did not drain"
    return done


@pytest.mark.parametrize("seed,overlap",
                         [(0, False), (1, False), (0, True), (1, True)],
                         ids=["s0", "s1", "s0-overlap", "s1-overlap"])
def test_fault_matrix_engine_survives(seed, overlap):
    """Every engine fault site fires (seeded schedule); the engine ends
    drained and conserves refcounts, steady-state traces stay flat, and
    every request the plan didn't cancel is token-identical to the
    fault-free run.  The overlap variants replay the same plans against
    the double-buffered pipeline — the baseline stays serial, so every
    identity claim also proves overlapped faulted output matches
    serial fault-free output."""
    cfg, params = _setup()
    prompts = _fault_wave(cfg)
    kw = dict(slots=2, max_tokens=96, bs=16, prefill_chunk=32, paged=True,
              spec=SpecConfig(k=3))

    base_eng = ContinuousEngine(params, cfg, **kw)
    base = _drive_matrix(base_eng, prompts)
    base_toks = {i: list(req.output().token_ids)
                 for (_, i), req in base.items()}

    plan = FaultPlan.generate(seed=seed, ticks=16)
    eng = ContinuousEngine(params, cfg, **kw, faults=plan, max_queue=8,
                           overlap=overlap)
    done = _drive_matrix(eng, prompts, plan=plan)
    assert plan.exhausted(), f"plan stuck: {plan.pending()}"
    assert len(plan.fired) == len(ENGINE_SITES)

    # the sites left their fingerprints
    fc = eng.fault_counters
    assert fc["cancelled"] >= 2                  # prefill + spec cancels
    assert fc["drafter_error"] == 1
    assert fc["injected_page_exhaustion"] == 1 and fc["deferred"] >= 1
    assert fc["double_release"] == 1

    # zero steady-state retraces across the whole faulted run
    traces = stable_trace_counts(eng.trace_counts())
    assert all(v <= 1 for v in traces.values()), traces

    # every request terminal; non-victims token-identical to fault-free
    reasons = {req.finish_reason for req in done.values()}
    assert reasons <= {"length", "stop", "cancelled"}
    victims = 0
    for (_, i), req in done.items():
        if req.finish_reason == "cancelled":
            victims += 1
            continue
        assert list(req.output().token_ids) == base_toks[i], \
            f"prompt {i} perturbed by faults (seed {seed})"
    assert victims == fc["cancelled"]

    # conservation: all slots released, all refcounts back to zero
    assert not eng._blocks and not eng._reserved
    assert not eng._slot_live.any()
    assert int(eng._alloc._ref.sum()) == 0
    assert int(np.asarray(eng.state["refcount"]).sum()) == 0


def test_double_release_is_counted_not_fatal():
    """The engine-level half of the double-release bar: an already-free
    slot pushed through the release path is absorbed as a counted warning
    (allocator untouched, device no-op) and the engine keeps serving."""
    cfg, params = _setup()
    plan = FaultPlan([Fault(DOUBLE_RELEASE, 1)], seed=0)
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           prefill_chunk=32, paged=True, faults=plan)
    prompts = _fault_wave(cfg)[:2]
    out = {}
    rids = [eng.submit(p, SamplingParams(max_new_tokens=6))
            for p in prompts]
    while not eng.scheduler.done():
        eng.step()
    assert plan.exhausted()
    assert eng.fault_counters["double_release"] >= 1
    for r in rids:
        out[r] = eng.scheduler.finished[r].output()
        assert out[r].finish_reason == "length"
    assert int(eng._alloc._ref.sum()) == 0
    assert int(np.asarray(eng.state["refcount"]).sum()) == 0


# ---------------------------------------------------------------------------
# engine: snapshot round-trip + failure modes
# ---------------------------------------------------------------------------

def _paged_engine(params, cfg, **kw):
    return ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                            prefill_chunk=32, paged=True, **kw)


def test_snapshot_roundtrip_and_failure_modes(tmp_path):
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab, (48,)).tolist()
    wave = [shared + rng.integers(0, cfg.vocab, (4,)).tolist()
            for _ in range(2)]
    followup = [shared + rng.integers(0, cfg.vocab, (6,)).tolist()
                for _ in range(2)]
    sp = SamplingParams(max_new_tokens=6)
    snap = str(tmp_path / "snap")

    saver = _paged_engine(params, cfg)
    for p in wave:
        saver.submit(p, sp)
    saver.run()
    n_pages = len(saver._trie)
    assert n_pages > 0
    step = saver.save_snapshot(snap)
    assert step == 1
    rids = [saver.submit(p, sp) for p in followup]
    res = saver.run()
    base_follow = [list(res[r].token_ids) for r in rids]

    # busy-engine guard, then the round-trip on the same engine: a fresh
    # engine resumes with the trie populated and the follow-up wave
    # token-identical to the never-restarted engine
    loader = _paged_engine(params, cfg)
    loader.submit(wave[0], sp)
    with pytest.raises(ValueError, match="busy"):
        loader.load_snapshot(snap)
    loader.run()                                 # drain; trie gets replaced
    restored = loader.load_snapshot(snap)
    assert restored == n_pages and len(loader._trie) == n_pages
    rids = [loader.submit(p, sp) for p in followup]
    res = loader.run()
    assert [list(res[r].token_ids) for r in rids] == base_follow

    # loading from an empty directory is a readable error
    os.makedirs(str(tmp_path / "void"))
    strict = _paged_engine(params, cfg)
    with pytest.raises(ValueError, match="no snapshot"):
        strict.load_snapshot(str(tmp_path / "void"))

    # geometry mismatch: rewrite the manifest's geometry in place — every
    # differing field is named, nothing half-applies
    man = os.path.join(snap, f"step_{step:010d}", "manifest.json")
    with open(man) as f:
        manifest = json.load(f)
    manifest["geometry"]["n_phys"] = 999
    manifest["geometry"]["bs"] = 8
    with open(man, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError) as ei:
        strict.load_snapshot(snap)
    msg = str(ei.value)
    assert "geometry mismatch" in msg
    assert "n_phys" in msg and "999" in msg and "bs" in msg
    assert len(strict._trie) == 0                # nothing half-applied

    # corrupt arrays: readable error, engine stays cold but serviceable
    with open(man, "w") as f:
        json.dump({**manifest,
                   "geometry": saver.pool.geometry()}, f)
    corrupt_snapshot(snap, mode="truncate")
    cold = _paged_engine(params, cfg)
    with pytest.raises(ValueError, match="corrupt"):
        cold.load_snapshot(snap)
    assert len(cold._trie) == 0
    assert cold._alloc.free_blocks() == cold.pool.n_phys
    rids = [cold.submit(p, sp) for p in followup]
    res = cold.run()                             # cold but fully functional
    assert [list(res[r].token_ids) for r in rids] == base_follow


def test_snapshot_guards_need_paged_pool():
    cfg, params = _setup()
    flat = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                            prefill_chunk=32)
    with pytest.raises(ValueError, match="paged"):
        flat.save_snapshot("/tmp/nope")
    with pytest.raises(ValueError, match="paged"):
        flat.load_snapshot("/tmp/nope")


# ---------------------------------------------------------------------------
# crash-restart parity (subprocess; CI runs this under -k restart)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_crash_restart_parity(tmp_path):
    """Process A serves, snapshots, then HARD-exits (os._exit — no
    graceful teardown).  Process B starts fresh, warm-restarts from the
    snapshot, and must (a) restore every frozen page, (b) admit the
    follow-up wave on trie hits, and (c) emit greedy output identical to
    the never-restarted engine (printed by A before it died)."""
    snap = str(tmp_path / "snap")

    def run_worker(phase):
        out = subprocess.run([sys.executable, WORKER, phase, snap],
                             capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    a = run_worker("save")
    assert a["n_pages"] > 0 and a["crash"] == "os._exit"
    b = run_worker("restore")
    assert b["restored"] == a["n_pages"]
    assert b["trie_len"] == a["n_pages"]
    assert b["followup_tokens"] == a["followup_tokens"], \
        "warm-restarted output diverged from the never-restarted engine"
    assert b["prefill_skipped"], \
        "restored trie produced no prefix hit on the follow-up wave"

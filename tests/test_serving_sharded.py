"""Mesh-sharded serving: the multi-chip parity bar.

``ContinuousEngine(mesh=...)`` shards the WHOLE serving state — slots over
the data axes, KV heads over the model axis
(``repro.distributed.serving_sharding``) — and pins every jitted step with
``in_shardings``/``out_shardings``.  The acceptance bar, asserted on a
forced 8-host-device platform (subprocess worker, so this file runs under
any parent device count):

* greedy token streams on dp-only (8x1) and dp x tp (4x2) meshes are
  IDENTICAL to the unsharded engine, across lockstep and staggered
  admission/eviction waves with refreezes;
* re-running the waves adds ZERO retraces (``stable_trace_counts``);
* the draft–verify engine (jitted verify panel + on-device rollback)
  passes the same bar under the 4x2 mesh;
* a refreeze + rollback round-trip on sharded pool state — plain jits and
  shardings-pinned jits — matches the unsharded transitions on the
  observable state.

Sharding-spec *derivation* (no devices needed) is tested in-process below.
"""
import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.serving import CachePool, sampling

WORKER = os.path.join(os.path.dirname(__file__), "workers",
                      "sharded_serving_worker.py")


def run_worker(which, timeout=900):
    out = subprocess.run([sys.executable, WORKER, which],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_engine_token_identity_and_zero_retraces():
    rec = run_worker("engine")
    for label, row in rec["engine"]["meshes"].items():
        assert row["tokens_match"], (label, row)
        assert row["stable"], (label, row["warm"], row["after"])
        assert row["decode_traces"] == 1, (label, row)


@pytest.mark.slow
def test_sharded_spec_engine_parity():
    rec = run_worker("spec")["spec"]
    assert rec["tokens_match"], rec
    assert rec["verify_traces"] == 1 and rec["stable"], rec
    # speculation must actually accept drafts under sharding — an engine
    # degraded to one-token ticks would keep tokens_match green
    assert rec["hist_tail"] > 0, rec


@pytest.mark.slow
def test_sharded_pool_refreeze_rollback_roundtrip():
    rec = run_worker("pool")["pool"]
    assert rec["roundtrip_match"], rec
    assert rec["prefix_blocks"] == [1, 1, 1, 1]
    assert rec["tail_len"] == [0, 0, 0, 0]


# ---------------------------------------------------------------------------
# spec derivation units (no devices needed — FakeMesh like test_sharding)
# ---------------------------------------------------------------------------

class FakeMesh:
    shape = {"data": 4, "model": 2}
    axis_names = ("data", "model")


def _pool():
    cfg = get_config("qwen3-0.6b").reduced()
    return CachePool.build(cfg, slots=4, max_tokens=64, bs=16)


def test_state_axes_cover_every_leaf():
    """The pool + lane axes pytrees must mirror the state pytree leaf for
    leaf (a missing leaf would silently replicate new storage)."""
    import jax
    pool = _pool()
    state = {**jax.eval_shape(pool.init_state),
             "sample": jax.eval_shape(lambda: sampling.init_lanes(4))}
    axes = {**pool.state_axes(), "sample": sampling.lane_axes()}
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    sa = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda _: 0, axes, is_leaf=is_axes))
    ss = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda _: 0, state))
    assert sa == ss
    for ax, leaf in zip(
            jax.tree_util.tree_leaves(axes, is_leaf=is_axes),
            jax.tree_util.tree_leaves(state)):
        assert len(ax) == len(leaf.shape), (ax, leaf.shape)


def test_serving_specs_slots_and_heads():
    """Slots land on data, KV heads on model; non-dividing dims replicate."""
    from repro.distributed.sharding import ShardCtx, default_rules
    cfg = get_config("qwen3-0.6b").reduced()        # n_kv = 2
    ctx = ShardCtx(FakeMesh(), default_rules(False, cfg))
    # pooled cache leaf [P, slots, Hkv, Sb, X]: slots->data, Hkv->model
    assert ctx.spec((None, "slots", "kv_heads", None, None),
                    (2, 4, 2, 4, 64)) == P(None, "data", "model", None, None)
    # 3 slots don't divide data=4 -> replicate; Hkv=1 doesn't divide model
    assert ctx.spec((None, "slots", "kv_heads", None, None),
                    (2, 3, 1, 4, 64)) == P(None, None, None, None, None)
    # lane vectors: slots over data
    assert ctx.spec(("slots",), (4,)) == P("data")
    assert ctx.spec(("slots", None), (4, 2)) == P("data", None)

"""Sparse-KV flash-decode kernel vs oracle + flash attention paths."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import freeze_prefix, append_token
from repro.kernels import ops, ref
from repro.models.flash import blocked_attention, full_attention


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 4, 4, 128, 64),      # MHA
    (2, 8, 2, 256, 64),      # GQA g=4
    (2, 8, 8, 128, 128),
])
@pytest.mark.parametrize("ks,vs", [(0.0, 0.0), (0.3, 0.5)])
def test_sparse_decode_attention_sweep(b, hq, hkv, s, d, ks, vs):
    k = rand((b, hkv, s, d), 1)
    v = rand((b, hkv, s, d), 2)
    q = rand((b, hq, d), 3)
    cache = freeze_prefix(k, v, ks, vs, tail_size=32, bs=min(128, s))
    sm = 1.0 / d ** 0.5
    o_ref = ref.sparse_decode_attention_ref(
        q, cache.k_sp, cache.v_sp, sm, cache.k_tail, cache.v_tail,
        cache.tail_len)
    with ops.backend("interpret"):
        o_pl = ops.sparse_decode_attention(
            q, cache.k_sp, cache.v_sp, hkv, sm, cache.k_tail, cache.v_tail,
            cache.tail_len)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_zero_sparsity_matches_dense_attention():
    """ks=vs=0: the compressed path must equal exact dense attention."""
    b, hq, hkv, s, d = 2, 8, 4, 256, 64
    k = rand((b, hkv, s, d), 4)
    v = rand((b, hkv, s, d), 5)
    q = rand((b, hq, d), 6)
    cache = freeze_prefix(k, v, 0.0, 0.0, tail_size=16, bs=128)
    sm = 1.0 / d ** 0.5
    with ops.backend("interpret"):
        o = ops.sparse_decode_attention(q, cache.k_sp, cache.v_sp, hkv, sm,
                                        cache.k_tail, cache.v_tail,
                                        cache.tail_len)
    g = hq // hkv
    o_dense, _ = ref.attn_partial_ref(q, jnp.repeat(k, g, 1),
                                      jnp.repeat(v, g, 1), sm)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_dense),
                               rtol=1e-4, atol=1e-4)


def test_tail_tokens_participate():
    b, hq, hkv, s, d = 1, 4, 4, 128, 64
    k = rand((b, hkv, s, d), 7)
    v = rand((b, hkv, s, d), 8)
    q = rand((b, hq, d), 9)
    cache = freeze_prefix(k, v, 0.0, 0.0, tail_size=8, bs=128)
    kn, vn = rand((b, hkv, d), 10) * 5, rand((b, hkv, d), 11) * 5
    cache2 = append_token(cache, kn, vn)
    sm = 1.0 / d ** 0.5
    with ops.backend("interpret"):
        o1 = ops.sparse_decode_attention(q, cache.k_sp, cache.v_sp, hkv, sm,
                                         cache.k_tail, cache.v_tail,
                                         cache.tail_len)
        o2 = ops.sparse_decode_attention(q, cache2.k_sp, cache2.v_sp, hkv,
                                         sm, cache2.k_tail, cache2.v_tail,
                                         cache2.tail_len)
    # exact reference with the appended token
    kk = jnp.concatenate([k, kn[:, :, None]], axis=2)
    vv = jnp.concatenate([v, vn[:, :, None]], axis=2)
    o_ref, _ = ref.attn_partial_ref(q, kk, vv, sm)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(o1) - np.asarray(o2)).max() > 1e-3


@pytest.mark.parametrize("s,skv", [(512, 512), (1024, 1024)])
@pytest.mark.parametrize("impl", ["masked", "triangular"])
def test_blocked_attention_matches_full(s, skv, impl):
    b, h, d = 1, 2, 64
    q, k, v = rand((b, h, s, d), 1), rand((b, h, skv, d), 2), \
        rand((b, h, skv, d), 3)
    sm = 1.0 / d ** 0.5
    o1 = blocked_attention(q, k, v, sm, causal=True, bq=256, bkv=256,
                           impl=impl)
    o2 = full_attention(q, k, v, sm, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)


def test_blocked_attention_noncausal():
    b, h, s, d = 1, 2, 512, 64
    q, k, v = rand((b, h, s, d), 4), rand((b, h, s, d), 5), \
        rand((b, h, s, d), 6)
    sm = 1.0 / d ** 0.5
    o1 = blocked_attention(q, k, v, sm, causal=False, bq=128, bkv=128)
    o2 = full_attention(q, k, v, sm, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)

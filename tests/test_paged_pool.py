"""Paged shared-prefix cache: block tables + copy-on-write over the
compressed pool.

The acceptance bar for the subsystem:

* the paged fused kernel (interpret mode) — block table as a
  scalar-prefetch operand, prefix phase loading ``table[slot, i]`` —
  matches the gather-then-flat XLA oracle across the pooled edge grid,
  including tables that SHARE physical blocks across slots;
* dead arena blocks are never *read*: poisoning every physical block not
  referenced by a live table entry (and pointing dead table entries at
  poisoned pages) leaves the kernel output bit-identical on both
  backends;
* refcounts are conserved: across any admit / refreeze / CoW / release
  sequence, ``sum(refcount) == live table entries`` and the device vector
  mirrors the host :class:`BlockAllocator` exactly (property tests,
  hypothesis-gated like tests/test_sparse_format.py); the allocator never
  evicts a referenced block and catches double-frees;
* greedy ``ContinuousEngine(paged=True)`` output is token-identical to
  the flat pre-PR pool on mixed shared/unshared request waves — including
  refreeze, copy-on-write divergence, prefix-cache hits, LRU eviction,
  and speculative-decoding rollback — with ZERO decode retraces across
  admissions/evictions (``trace_counts()``), and a cache hit admits with
  the shared prefill already done.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container ships without hypothesis
    class _St:
        def integers(self, *a, **k): return None
        def lists(self, *a, **k): return None
    st = _St()

    def settings(**_kw):
        return lambda fn: fn

    def given(**_kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def wrapper():
                pass
            wrapper.__name__ = fn.__name__
            return wrapper
        return deco

from repro.configs import get_config
from repro.core.sparse_kv import freeze_chunk_blocks
from repro.kernels import ops
from repro.models import lm
from repro.serving import (BlockAllocator, CachePool, ContinuousEngine,
                           PrefixTrie, SamplingParams, SpecConfig,
                           block_hashes, stable_trace_counts)


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# kernel: table indirection vs the gather-then-flat oracle
# ---------------------------------------------------------------------------

def _arena_case(n_phys=10, hkv=2, bs=16, d=32, ks=0.3, vs=0.5, seed=0):
    """A frozen arena of ``n_phys`` independent compressed blocks."""
    k = _rand((n_phys, hkv, bs, d), seed)
    v = _rand((n_phys, hkv, bs, d), seed + 1)
    cap = bs * d
    kbm, kvl, vbm, vvl = freeze_chunk_blocks(k, v, ks, vs, bs, cap, cap)
    return tuple(a[:, :, 0] for a in (kbm, kvl, vbm, vvl))  # [n_phys,Hkv,X]


# tables share physical pages across slots on purpose — that sharing is
# the feature the indirection exists for
PAGED_GRID = [
    # (table rows, prefix_blocks, tail_len)  b=4, sb=4
    pytest.param([[0, 1, 2, 3], [0, 1, 2, 3], [0, 1, 2, 3], [0, 1, 2, 3]],
                 [4, 4, 4, 4], [1, 9, 14, 16], id="all_shared"),
    pytest.param([[0, 1, 2, 3], [0, 1, 5, 6], [7, 8, 0, 0], [9, 0, 0, 0]],
                 [4, 4, 2, 1], [1, 5, 9, 13], id="cow_divergence"),
    pytest.param([[0, 1, 2, 3], [0, 1, 9, 9], [0, 0, 0, 0], [5, 6, 7, 8]],
                 [2, 2, 0, 4], [3, 14, 7, 1], id="dead_entries"),
    pytest.param([[0, 0, 0, 0]] * 4, [0, 0, 0, 0], [1, 4, 9, 16],
                 id="empty_prefix"),
]


@pytest.mark.parametrize("table,prefix_blocks,tail_len", PAGED_GRID)
@pytest.mark.parametrize("qn", [0, 3])
def test_paged_kernel_matches_gather_oracle(table, prefix_blocks, tail_len,
                                            qn):
    """Paged attention == gather each slot's blocks out of the arena, then
    flat attention: single-query ticks and [B, Q, Hq, D] verify panels,
    slots sharing pages, dead in-range table entries."""
    b, hkv, g, d, bs, t = 4, 2, 2, 32, 16, 16
    arena = _arena_case(hkv=hkv, bs=bs, d=d)
    tbl = jnp.asarray(table, jnp.int32)
    pl_ = jnp.asarray(prefix_blocks, jnp.int32) * bs
    tl = jnp.asarray(tail_len, jnp.int32)
    k_tail = _rand((b, hkv, t, d), 10)
    v_tail = _rand((b, hkv, t, d), 11)
    q = (_rand((b, hkv * g, d), 12) if qn == 0
         else _rand((b, qn, hkv * g, d), 12))
    if qn:                          # panel query j sees tail_len + j
        tl = jnp.minimum(tl, t - (qn - 1))
    sm = 1.0 / d ** 0.5
    with ops.backend("xla"):
        o_ref = ops.sparse_decode_attention_paged(
            q, *arena, tbl, hkv, sm, bs, k_tail, v_tail, tl, pl_)
    with ops.backend("interpret"):
        o_k = ops.sparse_decode_attention_paged(
            q, *arena, tbl, hkv, sm, bs, k_tail, v_tail, tl, pl_)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_poisoned_arena_blocks_never_read(backend):
    """Poison every physical page NOT referenced by a live table entry
    (including the pages dead table entries point at): the output must be
    bit-identical to the clean arena — the ``n_blocks`` gate, not luck,
    keeps dead fetches out of the softmax."""
    b, hkv, g, d, bs, t = 4, 2, 2, 32, 16, 16
    n_phys = 10
    arena = _arena_case(n_phys=n_phys, hkv=hkv, bs=bs, d=d)
    table = jnp.asarray([[0, 1, 2, 3], [0, 1, 9, 9],
                         [4, 0, 0, 0], [5, 5, 5, 5]], jnp.int32)
    prefix_blocks = np.asarray([4, 2, 1, 0])
    live = {int(table[s, i]) for s in range(b)
            for i in range(prefix_blocks[s])}
    dead = np.asarray([p not in live for p in range(n_phys)])
    assert dead.any(), "case must exercise dead pages"
    poisoned = tuple(
        jnp.where(dead[:, None, None],
                  jnp.full(a.shape, ~np.uint32(0))
                  if a.dtype == jnp.uint32 else jnp.full(a.shape, 1e4),
                  a).astype(a.dtype)
        for a in arena)
    pl_ = jnp.asarray(prefix_blocks, jnp.int32) * bs
    tl = jnp.asarray([1, 9, 16, 4], jnp.int32)
    k_tail = _rand((b, hkv, t, d), 20)
    v_tail = _rand((b, hkv, t, d), 21)
    q = _rand((b, hkv * g, d), 22)
    sm = 1.0 / d ** 0.5
    with ops.backend(backend):
        o_clean = ops.sparse_decode_attention_paged(
            q, *arena, table, hkv, sm, bs, k_tail, v_tail, tl, pl_)
        o_poison = ops.sparse_decode_attention_paged(
            q, *poisoned, table, hkv, sm, bs, k_tail, v_tail, tl, pl_)
    np.testing.assert_array_equal(np.asarray(o_clean), np.asarray(o_poison))


# ---------------------------------------------------------------------------
# pool transitions: table / refcount bookkeeping
# ---------------------------------------------------------------------------

def _paged_pool(slots=3, kv_tail=16, bs=16, max_tokens=64, n_phys=0):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=kv_tail)
    pool = CachePool.build(cfg, slots=slots, max_tokens=max_tokens, bs=bs,
                           paged=True, n_phys=n_phys)
    return cfg, pool


def test_paged_build_defaults_and_errors():
    cfg, pool = _paged_pool(slots=3, max_tokens=64, bs=16)
    assert pool.paged and pool.n_phys == 3 * pool.max_blocks
    st0 = pool.init_state()
    assert st0["table"].shape == (3, pool.max_blocks)
    assert st0["refcount"].shape == (pool.n_phys,)
    # the build-time contracts read as errors, not asserts
    with pytest.raises(ValueError, match="not a multiple"):
        CachePool.build(cfg, 2, 64, bs=12)
    with pytest.raises(ValueError, match="cannot serve arch"):
        CachePool.build(get_config("rwkv6-7b").reduced(), 2, 64)


def test_assign_refreeze_release_refcount_bookkeeping():
    """One shared-prefix lifetime, by hand: slot 0 freezes two pages,
    slot 1 takes a shared reference (admission hit), slot 1 diverges onto
    a fresh page (CoW), then a batched release drops both slots and every
    refcount returns to zero."""
    cfg, pool = _paged_pool(slots=3, kv_tail=16, bs=16)
    tb = pool.tail // pool.bs
    state = pool.init_state()

    # slot 0 fills its tail twice and refreezes onto fresh pages 0, 1
    for newpage in range(2):
        fill = jnp.asarray([16, 0, 0], jnp.int32)
        state = dict(state, tail_len=fill, pos=state["pos"] + fill)
        ids = np.zeros((pool.slots, tb), np.int32)
        ids[0] = [newpage]
        state = jax.jit(pool.refreeze)(state, jnp.asarray(ids))
    assert np.asarray(state["prefix_blocks"]).tolist() == [2, 0, 0]
    assert np.asarray(state["table"])[0, :2].tolist() == [0, 1]
    assert np.asarray(state["refcount"])[:2].tolist() == [1, 1]

    # slot 1 admits on a prefix-cache hit over the same two pages
    pad = np.zeros(pool.max_blocks, np.int32)
    pad[:2] = [0, 1]
    state = jax.jit(pool.assign_blocks)(state, jnp.int32(1),
                                        jnp.asarray(pad), jnp.int32(2))
    assert np.asarray(state["refcount"])[:2].tolist() == [2, 2]
    assert np.asarray(state["pos"]).tolist() == [32, 32, 0]
    assert np.asarray(state["table"])[1, :2].tolist() == [0, 1]

    # slot 1 diverges: its own tail refreezes onto FRESH page 2 (CoW) —
    # the shared pages are untouched, only its table row grows
    before = [np.asarray(state["layers"]["l0"]["kv"][k])[:, :2].copy()
              for k in ("k_bitmap", "k_values")]
    fill = jnp.asarray([0, 16, 0], jnp.int32)
    state = dict(state, tail_len=fill, pos=state["pos"] + fill)
    ids = np.zeros((pool.slots, tb), np.int32)
    ids[1] = [2]
    state = jax.jit(pool.refreeze)(state, jnp.asarray(ids))
    assert np.asarray(state["table"])[1, :3].tolist() == [0, 1, 2]
    assert np.asarray(state["table"])[0, :2].tolist() == [0, 1]
    assert np.asarray(state["refcount"])[:3].tolist() == [2, 2, 1]
    for b4, key in zip(before, ("k_bitmap", "k_values")):
        np.testing.assert_array_equal(
            b4, np.asarray(state["layers"]["l0"]["kv"][key])[:, :2],
            err_msg=f"CoW wrote shared {key} pages")

    # batched release of both slots in ONE call: the shared pages are
    # decref'd once per referencing slot (scatter-add), all counts at 0
    rel = np.full(pool.slots, -1, np.int32)
    rel[:2] = [0, 1]
    state = jax.jit(pool.release)(state, jnp.asarray(rel))
    assert np.asarray(state["refcount"]).sum() == 0
    assert np.asarray(state["table"]).sum() == 0
    assert np.asarray(state["pos"]).tolist() == [0, 0, 0]


def test_release_vector_matches_scalar_loop():
    """Batched release == the scalar loop it replaces, flat and paged."""
    for paged in (False, True):
        cfg = get_config("qwen3-0.6b").reduced()
        cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                                  kv_tail=16)
        pool = CachePool.build(cfg, slots=4, max_tokens=64, paged=paged)
        state = pool.init_state()
        state["pos"] = jnp.asarray([5, 9, 3, 7], jnp.int32)
        state["tail_len"] = jnp.asarray([5, 9, 3, 7], jnp.int32)
        if paged:
            state["prefix_blocks"] = jnp.asarray([2, 1, 0, 0], jnp.int32)
            state["table"] = state["table"].at[0, :2].set(
                jnp.asarray([3, 4]))
            state["table"] = state["table"].at[1, :1].set(5)
            state["refcount"] = state["refcount"].at[
                jnp.asarray([3, 4, 5])].set(1)
        vec = jnp.asarray([0, 2, -1, -1], jnp.int32)
        batched = pool.release(state, vec)
        looped = pool.release(pool.release(state, jnp.int32(0)),
                              jnp.int32(2))
        for a, b in zip(jax.tree_util.tree_leaves(batched),
                        jax.tree_util.tree_leaves(looped)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=25, deadline=None)
@given(ops_seq=st.lists(st.integers(min_value=0, max_value=99),
                        min_size=1, max_size=12))
def test_refcount_conservation_property(ops_seq):
    """Any admit / refreeze(CoW) / release walk conserves refcounts:
    ``sum(refcount) == live table entries`` after every transition, the
    device vector mirrors the host allocator, nothing double-frees, and
    the allocator never hands out a page some slot still references."""
    cfg, pool = _paged_pool(slots=3, kv_tail=16, bs=16, max_tokens=64)
    tb = pool.tail // pool.bs
    alloc = BlockAllocator(pool.n_phys)
    state = pool.init_state()
    blocks = {}                                   # slot -> [ids]

    refreeze = jax.jit(pool.refreeze)
    assign = jax.jit(pool.assign_blocks)
    release = jax.jit(pool.release)

    def check():
        rc = np.asarray(state["refcount"])
        live = sum(len(v) for v in blocks.values())
        assert rc.sum() == live, (rc, blocks)
        assert rc.min() >= 0
        for bid in range(pool.n_phys):
            assert rc[bid] == alloc.refcount(bid), bid
        held = {b for ids in blocks.values() for b in ids}
        for bid in held:
            assert rc[bid] > 0

    for code in ops_seq:
        op, arg = code % 3, code // 3
        if op == 0:       # grow a slot: fill tail, refreeze onto fresh page
            slot = arg % pool.slots
            if (len(blocks.get(slot, ())) + tb > pool.max_blocks
                    or alloc.free_blocks() < tb):
                continue
            tl = np.zeros(pool.slots, np.int32)
            tl[slot] = pool.tail
            fresh = alloc.alloc(tb)
            ids = np.zeros((pool.slots, tb), np.int32)
            ids[slot] = fresh
            state = dict(state, tail_len=jnp.asarray(tl),
                         pos=state["pos"] + jnp.asarray(tl))
            state = dict(refreeze(state, jnp.asarray(ids)))
            blocks.setdefault(slot, []).extend(fresh)
        elif op == 1:     # admit a free slot on a hit over another's prefix
            free = [s for s in range(pool.slots) if s not in blocks]
            donors = [s for s in blocks if blocks[s]]
            if not free or not donors:
                continue
            slot, donor = free[0], donors[arg % len(donors)]
            n = arg % len(blocks[donor]) + 1
            hits = blocks[donor][:n]
            alloc.incref(hits)
            pad = np.zeros(pool.max_blocks, np.int32)
            pad[:n] = hits
            state = dict(assign(state, jnp.int32(slot),
                                jnp.asarray(pad), jnp.int32(n)))
            blocks[slot] = list(hits)
        else:             # release a subset of live slots in one call
            live_slots = sorted(blocks)
            if not live_slots:
                continue
            picked = live_slots[:arg % len(live_slots) + 1]
            vec = np.full(pool.slots, -1, np.int32)
            vec[:len(picked)] = picked
            state = dict(release(state, jnp.asarray(vec)))
            for s in picked:
                alloc.decref(blocks.pop(s))
        check()


# ---------------------------------------------------------------------------
# sanitized mode: the same transitions under checkify
# ---------------------------------------------------------------------------

def _checked_paged_pool():
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=16)
    return CachePool.build(cfg, slots=3, max_tokens=64, bs=16, paged=True,
                           checkify=True)


def _checked(fn):
    """jit the functionalized transition, throw at the call site — the
    same composition the engine's checkify mode uses."""
    from repro.serving.cache_pool import checkified_raw
    checked = jax.jit(checkified_raw(fn))

    def run(*args):
        err, out = checked(*args)
        err.throw()
        return dict(out)
    return run


def test_checkify_clean_refcount_walk():
    """The shared-prefix lifetime (freeze twice, admit on a hit, CoW
    diverge, batched release) runs unchanged under the sanitized mode —
    every transition carries live checkify invariants and none fires."""
    pool = _checked_paged_pool()
    assert pool.checkify
    tb = pool.tail // pool.bs
    state = pool.init_state()
    refreeze = _checked(pool.refreeze)
    assign = _checked(pool.assign_blocks)
    release = _checked(pool.release)

    for newpage in range(2):
        fill = jnp.asarray([16, 0, 0], jnp.int32)
        state = dict(state, tail_len=fill, pos=state["pos"] + fill)
        ids = np.zeros((pool.slots, tb), np.int32)
        ids[0] = [newpage]
        state = refreeze(state, jnp.asarray(ids))
    pad = np.zeros(pool.max_blocks, np.int32)
    pad[:2] = [0, 1]
    state = assign(state, jnp.int32(1), jnp.asarray(pad), jnp.int32(2))
    fill = jnp.asarray([0, 16, 0], jnp.int32)
    state = dict(state, tail_len=fill, pos=state["pos"] + fill)
    ids = np.zeros((pool.slots, tb), np.int32)
    ids[1] = [2]
    state = refreeze(state, jnp.asarray(ids))
    rel = np.full(pool.slots, -1, np.int32)
    rel[:2] = [0, 1]
    state = release(state, jnp.asarray(rel))
    assert np.asarray(state["refcount"]).sum() == 0


def test_checkify_catches_cow_violation():
    """Refreezing onto a page another slot still references is the
    copy-on-write violation the sanitized mode exists to catch."""
    from jax.experimental.checkify import JaxRuntimeError
    pool = _checked_paged_pool()
    tb = pool.tail // pool.bs
    state = pool.init_state()
    refreeze = _checked(pool.refreeze)

    fill = jnp.asarray([16, 0, 0], jnp.int32)
    state = dict(state, tail_len=fill, pos=state["pos"] + fill)
    ids = np.zeros((pool.slots, tb), np.int32)
    state = refreeze(state, jnp.asarray(ids))       # slot 0 -> page 0
    fill = jnp.asarray([0, 16, 0], jnp.int32)
    state = dict(state, tail_len=fill, pos=state["pos"] + fill)
    ids = np.zeros((pool.slots, tb), np.int32)      # slot 1 -> page 0 again
    with pytest.raises(JaxRuntimeError, match="already referenced"):
        refreeze(state, jnp.asarray(ids))


def test_checkify_catches_release_underflow():
    from jax.experimental.checkify import JaxRuntimeError
    pool = _checked_paged_pool()
    state = pool.init_state()
    state["prefix_blocks"] = jnp.asarray([1, 0, 0], jnp.int32)
    state["table"] = state["table"].at[0, 0].set(3)
    state["pos"] = jnp.asarray([16, 0, 0], jnp.int32)
    # refcount[3] left at 0: a device-side double free
    release = _checked(pool.release)
    with pytest.raises(JaxRuntimeError, match="underflow"):
        release(state, jnp.int32(0))


def test_checkify_off_traces_no_check_eqns():
    """The default pool must trace ZERO check primitives — sanitized mode
    is opt-in, not a tax."""
    cfg, pool = _paged_pool()
    assert not pool.checkify
    state = pool.init_state()
    ids = jnp.zeros((pool.slots, pool.tail // pool.bs), jnp.int32)
    jaxpr = str(jax.make_jaxpr(pool.refreeze)(state, ids))
    assert "check " not in jaxpr and "check[" not in jaxpr


# ---------------------------------------------------------------------------
# host side: allocator + prefix trie
# ---------------------------------------------------------------------------

def test_block_allocator_lru_eviction_and_revival():
    evicted = []
    alloc = BlockAllocator(3, on_evict=evicted.append)
    a, b, c = alloc.alloc(3)
    alloc.register(a, 100)
    alloc.register(b, 200)
    assert alloc.free_blocks() == 0
    alloc.decref([a, b])          # both park in the LRU, oldest = a
    assert alloc.free_blocks() == 2
    assert alloc.lookup(100) == a and alloc.lookup(200) == b
    alloc.incref([b])             # revive b out of the LRU
    [d] = alloc.alloc(1)          # must evict a (cold end), NEVER b or c
    assert d == a and evicted == [100]
    assert alloc.lookup(100) is None and alloc.lookup(200) == b
    alloc.decref([c])             # unregistered: straight to the free list
    assert alloc.free_blocks() == 1
    with pytest.raises(RuntimeError, match="double free"):
        alloc.decref([c])
    with pytest.raises(RuntimeError, match="exhausted"):
        alloc.alloc(2)            # only 1 reclaimable (b, d live)


def test_block_hashes_chain_and_trie_match():
    bs = 4
    a = list(range(12))
    b = list(range(8)) + [99, 98, 97, 96]
    ha, hb = block_hashes(a, bs), block_hashes(b, bs)
    assert len(ha) == 3 and ha[:2] == hb[:2] and ha[2] != hb[2]
    # a trailing partial block is never hashed; chaining => a block's hash
    # commits to the WHOLE prefix, so equal blocks at different depths
    # do not collide
    assert block_hashes(a[:11], bs) == ha[:2]
    same_block = block_hashes(a[4:8], bs)
    assert same_block[0] != ha[1]
    trie = PrefixTrie()
    for i, h in enumerate(ha):
        trie.insert(h, i + 10)
    assert trie.match(hb) == [10, 11]         # longest shared prefix
    assert trie.match(block_hashes([7] * 8, bs)) == []
    trie.drop(ha[1])                          # eviction invalidates mid-chain
    assert trie.match(ha) == [10]
    assert len(trie) == 2


# ---------------------------------------------------------------------------
# engine: token identity + zero retraces (the acceptance criterion)
# ---------------------------------------------------------------------------

def _setup(seed=0, kv_tail=16):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.3, kv_v_sparsity=0.5,
                              kv_tail=kv_tail, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    return cfg, params


def _shared_wave(cfg, seed=0):
    """Mixed shared/unshared prompts: a 64-token system prefix with unique
    suffixes (prefix-cache hits), a divergence INSIDE the shared region
    (copy-on-write at block 2), and an unrelated prompt."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab, (64,)).tolist()
    return [
        shared + rng.integers(0, cfg.vocab, (5,)).tolist(),
        shared + rng.integers(0, cfg.vocab, (9,)).tolist(),
        shared[:32] + rng.integers(0, cfg.vocab, (20,)).tolist(),
        rng.integers(0, cfg.vocab, (40,)).tolist(),
    ]


def _drive(eng, prompts, steps=24):
    rids = [eng.submit(p, SamplingParams(max_new_tokens=steps))
            for p in prompts]
    res = eng.run()
    return [res[r].token_ids for r in rids], res


def test_paged_engine_token_identity_and_zero_retraces():
    """Greedy paged output == flat output on the mixed wave (refreeze:
    max_new_tokens > kv_tail; CoW divergence; hits), decode/verify traces
    stay at 1 across a second wave that replays admissions, evictions and
    prefix-cache hits against a warm trie."""
    cfg, params = _setup()
    prompts = _shared_wave(cfg)

    flat = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16,
                            prefill_chunk=32)
    out_flat, _ = _drive(flat, prompts)

    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16,
                           prefill_chunk=32, paged=True)
    out_paged, res = _drive(eng, prompts)
    assert out_paged == out_flat
    warm = eng.trace_counts()
    assert warm["decode"] == 1 and warm["assign"] >= 1

    # second wave: every shared-prefix request now admits on a trie hit
    assert len(eng._trie) > 0
    out2, res2 = _drive(eng, prompts)
    out_flat2, _ = _drive(flat, prompts)
    assert out2 == out_flat2
    after = eng.trace_counts()
    assert stable_trace_counts(after) == stable_trace_counts(warm), \
        f"paged engine retraced: {warm} -> {after}"
    # hit TTFT < miss TTFT: the shared prefill was skipped outright
    ttft1 = min(o.metrics.ttft for o in res.values())
    ttft2 = min(o.metrics.ttft for o in res2.values())
    assert ttft2 < ttft1


def test_paged_prefix_hit_skips_prefill_and_shares_pages():
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    shared = rng.integers(0, cfg.vocab, (64,)).tolist()
    p0 = shared + rng.integers(0, cfg.vocab, (6,)).tolist()
    p1 = shared + rng.integers(0, cfg.vocab, (3,)).tolist()

    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16,
                           prefill_chunk=32, paged=True)
    eng.submit(p0, SamplingParams(max_new_tokens=4))
    eng.run()
    assert len(eng._trie) == 4                   # 64 tokens / bs, chunked
    cached = eng._alloc.free_blocks()

    rid = eng.submit(p1, SamplingParams(max_new_tokens=4))
    eng.step()                                   # admission tick
    req = eng.scheduler.active[
        next(s for s, r in eng.scheduler.active.items() if r.rid == rid)]
    # the 64-token hit IS the prefill: one tick covers hit + the 3-token
    # suffix chunk (a cold 67-token prompt at chunk=32 needs 3 ticks)
    assert req.prefill_done == len(p1)
    row = eng._blocks[req.slot]
    assert len(row) >= 4
    rc = np.asarray(eng.state["refcount"])
    assert all(rc[b] == 1 for b in row[:4])      # revived from the LRU
    assert eng._alloc.free_blocks() < cached
    out = eng.run()
    assert out[rid].finish_reason == "length"

    # flat engine on the same prompt agrees token-for-token
    flat = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16,
                            prefill_chunk=32)
    fid = flat.submit(p1, SamplingParams(max_new_tokens=4))
    assert flat.run()[fid].token_ids == out[rid].token_ids


def test_paged_eviction_invalidates_trie_and_stays_correct():
    """A tiny arena: new traffic must LRU-evict the cached shared prefix
    (trie entries drop), and a later request with that prefix re-prefills
    and still matches the flat engine."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab, (48,)).tolist()
    p0 = shared + rng.integers(0, cfg.vocab, (4,)).tolist()
    other = [rng.integers(0, cfg.vocab, (52,)).tolist() for _ in range(2)]

    # arena of 7 pages; each request freezes 3 (and reserves 4), so the
    # third wave must evict the first's cached pages
    eng = ContinuousEngine(params, cfg, slots=1, max_tokens=64, bs=16,
                           prefill_chunk=16, paged=True, phys_blocks=7)
    sp = SamplingParams(max_new_tokens=8)
    r0 = eng.submit(p0, sp)
    first = eng.run()[r0].token_ids
    trie0 = len(eng._trie)
    assert trie0 > 0
    for p in other:                               # churn: forces eviction
        eng.submit(p, sp)
        eng.run()
    assert len(eng._trie) < trie0 + 2 * 3         # evictions really fired
    r2 = eng.submit(p0, sp)
    assert eng.run()[r2].token_ids == first

    flat = ContinuousEngine(params, cfg, slots=1, max_tokens=64, bs=16,
                            prefill_chunk=16)
    fid = flat.submit(p0, sp)
    assert flat.run()[fid].token_ids == first


def test_paged_spec_decode_token_identity():
    """Speculative decoding on the paged pool: draft-verify rollback is a
    pure tail decrement, so paged + spec greedy == flat spec-off greedy on
    a wave with draft hits (loopy) and misses (random)."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab, (32,)).tolist()
    prompts = [shared + [3, 4, 5] * 4,
               shared + rng.integers(0, cfg.vocab, (7,)).tolist(),
               rng.integers(0, cfg.vocab, (20,)).tolist()]

    flat = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                            prefill_chunk=32)
    out_flat, _ = _drive(flat, prompts, steps=20)

    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           prefill_chunk=32, paged=True,
                           spec=SpecConfig(k=3))
    out_spec, _ = _drive(eng, prompts, steps=20)
    assert out_spec == out_flat
    assert eng.trace_counts()["verify"] == 1
    assert eng.spec_hist.sum() > 0


def test_paged_interpret_mode_parity():
    """The paged engine through the actual Pallas kernels (interpret mode)
    stays token-identical to the flat engine on the same backend — the CI
    paged-parity bar."""
    cfg, params = _setup(kv_tail=16)
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab, (32,)).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab, (4,)).tolist(),
               shared + rng.integers(0, cfg.vocab, (2,)).tolist()]
    sp = SamplingParams(max_new_tokens=6)
    with ops.backend("interpret"):
        flat = ContinuousEngine(params, cfg, slots=2, max_tokens=64, bs=16,
                                prefill_chunk=32)
        rf = [flat.submit(p, sp) for p in prompts]
        out_flat = [flat.run()[r].token_ids for r in rf]
        eng = ContinuousEngine(params, cfg, slots=2, max_tokens=64, bs=16,
                               prefill_chunk=32, paged=True)
        rp = [eng.submit(p, sp) for p in prompts]
        out_paged = [eng.run()[r].token_ids for r in rp]
        assert eng.trace_counts()["decode"] == 1
    assert out_paged == out_flat

"""INT4 extension (paper §8): nibble packing, kernel-vs-oracle, end-to-end."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import pack, make_mask
from repro.core.sparse_format import pack_nibbles, unpack_nibbles, unpack
from repro.core.quant import quantize_weight_int4, quantize_act_int8
from repro.distributed import NULL_CTX
from repro.distributed.convert_plan import convert_concrete, _to_int4
from repro.kernels import ops, ref
from repro.kernels.sparse_matmul_int4 import sparse_matmul_int4_pallas
from repro.models import lm


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


def test_nibble_roundtrip():
    v = jnp.asarray(np.random.default_rng(0).integers(-7, 8, 256), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(pack_nibbles(v))), np.asarray(v))


def test_int4_quant_error_bounded():
    w = rand((128, 64), 1)
    q, scale = quantize_weight_int4(w)
    assert int(np.abs(np.asarray(q)).max()) <= 7
    back = np.asarray(q, np.float32) * np.asarray(scale)[None, :]
    err = np.abs(back - np.asarray(w)).max()
    assert err <= float(np.abs(np.asarray(w)).max()) / 7.0 + 1e-6


def make_int4(k, n, sparsity=0.5, seed=2, block=(128, 128)):
    w = rand((k, n), seed)
    mask = make_mask(w, sparsity, "balanced", block)
    q, scale = quantize_weight_int4(jnp.where(mask, w, 0))
    sw8 = pack(q, mask, block, scale=scale)
    return w, mask, _to_int4(sw8), sw8


def test_unpack_matches_int8_layout():
    w, mask, sw4, sw8 = make_int4(256, 128)
    np.testing.assert_array_equal(np.asarray(unpack(sw4)),
                                  np.asarray(unpack(sw8)))
    assert sw4.values.nbytes == sw8.values.nbytes // 2


@pytest.mark.parametrize("m,k,n", [(16, 128, 128), (32, 256, 384)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5])
def test_int4_kernel_vs_oracle(m, k, n, sparsity):
    w, mask, sw4, _ = make_int4(k, n, sparsity, seed=3)
    x = rand((m, k), 4)
    xq, sx = quantize_act_int8(x)
    out = sparse_matmul_int4_pallas(xq, sx, sw4, tm=16, interpret=True)
    expect = ref.sparse_matmul_int8_ref(x, sw4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-3)
    # int4 end-to-end approximates the f32 product (4-bit weights on random
    # gaussian data: ~12% relative error is the expected quantization noise)
    dense = np.asarray(x @ jnp.where(mask, w, 0))
    rel = np.abs(np.asarray(out) - dense).mean() / np.abs(dense).mean()
    assert rel < 0.15, rel


def test_int4_linear_dispatch_and_model():
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              sparsity=0.5)
    params = lm.init_params(cfg, jax.random.PRNGKey(5))
    sp4 = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX,
                           mode="int4")
    sp16 = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    h4 = np.asarray(lm.forward_train(sp4, batch, cfg, NULL_CTX), np.float32)
    h16 = np.asarray(lm.forward_train(sp16, batch, cfg, NULL_CTX),
                     np.float32)
    assert np.all(np.isfinite(h4))
    rel = np.abs(h4 - h16).mean() / (np.abs(h16).mean() + 1e-9)
    assert rel < 0.25, rel

    # bytes: int4 values half of int8
    def val_bytes(t):
        from repro.core.sparse_format import BlockSparseWeight
        return sum(l.values.nbytes for l in jax.tree_util.tree_leaves(
            t, is_leaf=lambda x: isinstance(x, BlockSparseWeight))
            if isinstance(l, BlockSparseWeight))
    sp8 = convert_concrete(params, lm.model_specs(cfg), cfg, NULL_CTX,
                           mode="int8")
    assert val_bytes(sp4) * 2 == val_bytes(sp8)

"""Paper §6.2 refreeze: folding a full dynamic tail back into the
compressed prefix (amortized, off the per-token hot path)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (freeze_prefix, append_token, refreeze, unpack)
from repro.kernels import ref
from repro.models import lm
from repro.serving import Engine


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


def test_refreeze_preserves_attention():
    b, hkv, s, d, t = 2, 4, 256, 64, 128
    k, v = rand((b, hkv, s, d), 1), rand((b, hkv, s, d), 2)
    cache = freeze_prefix(k, v, 0.0, 0.0, tail_size=t, bs=128)
    for i in range(t):
        cache = append_token(cache, rand((b, hkv, d), 10 + i) * 0.5,
                             rand((b, hkv, d), 500 + i) * 0.5)
    q = rand((b, 8, d), 3)
    sm = 1.0 / d ** 0.5
    o_before = ref.sparse_decode_attention_ref(
        q, cache.k_sp, cache.v_sp, sm, cache.k_tail, cache.v_tail,
        cache.tail_len)
    cache2 = refreeze(cache, 0.0, 0.0)
    assert int(cache2.tail_len) == 0
    assert cache2.k_sp.bitmap.shape[2] == (s + t) // 128   # longer prefix
    o_after = ref.sparse_decode_attention_ref(
        q, cache2.k_sp, cache2.v_sp, sm, cache2.k_tail, cache2.v_tail,
        cache2.tail_len)
    np.testing.assert_allclose(np.asarray(o_after), np.asarray(o_before),
                               rtol=1e-4, atol=1e-4)


def test_refreeze_prunes_new_tokens():
    b, hkv, s, d, t = 1, 2, 128, 64, 128
    k, v = rand((b, hkv, s, d), 4), rand((b, hkv, s, d), 5)
    cache = freeze_prefix(k, v, 0.3, 0.5, tail_size=t, bs=128)
    for i in range(t):
        cache = append_token(cache, rand((b, hkv, d), 20 + i),
                             rand((b, hkv, d), 700 + i))
    cache2 = refreeze(cache, 0.3, 0.5)
    dense_k = np.asarray(unpack(cache2.k_sp))
    frac_zero = (dense_k == 0).mean()
    assert 0.2 < frac_zero < 0.45        # ~30% K pruning over prefix+tail


def test_engine_generates_past_tail_capacity():
    """Decoding more tokens than the tail holds triggers refreeze and keeps
    generating valid tokens."""
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              kv_tail=64)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 64)), jnp.int32)
    eng = Engine(params, cfg, kv_mode="sparse")
    steps = 64 + 8                      # exceeds the tail
    out, cache = eng.generate({"tokens": toks}, steps=steps)
    assert out.shape == (2, steps + 1)
    assert int(cache["pos"]) == 64 + steps
    # prefix grew by one tail fold
    kv = cache["layers"]["l0"]["kv"]
    assert kv.k_sp.bitmap.shape[3] * kv.k_sp.block[0] >= 128
    assert int(kv.tail_len[0]) < 64

"""Paper §6.2 refreeze: folding a full dynamic tail back into the
compressed prefix (amortized, off the per-token hot path)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (freeze_prefix, append_token, refreeze, unpack)
from repro.kernels import ref
from repro.models import lm
from repro.serving import Engine, SamplingParams


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


def test_refreeze_preserves_attention():
    b, hkv, s, d, t = 2, 4, 256, 64, 128
    k, v = rand((b, hkv, s, d), 1), rand((b, hkv, s, d), 2)
    cache = freeze_prefix(k, v, 0.0, 0.0, tail_size=t, bs=128)
    for i in range(t):
        cache = append_token(cache, rand((b, hkv, d), 10 + i) * 0.5,
                             rand((b, hkv, d), 500 + i) * 0.5)
    q = rand((b, 8, d), 3)
    sm = 1.0 / d ** 0.5
    o_before = ref.sparse_decode_attention_ref(
        q, cache.k_sp, cache.v_sp, sm, cache.k_tail, cache.v_tail,
        cache.tail_len)
    cache2 = refreeze(cache, 0.0, 0.0)
    assert int(cache2.tail_len) == 0
    assert cache2.k_sp.bitmap.shape[2] == (s + t) // 128   # longer prefix
    o_after = ref.sparse_decode_attention_ref(
        q, cache2.k_sp, cache2.v_sp, sm, cache2.k_tail, cache2.v_tail,
        cache2.tail_len)
    np.testing.assert_allclose(np.asarray(o_after), np.asarray(o_before),
                               rtol=1e-4, atol=1e-4)


def test_refreeze_prunes_new_tokens():
    b, hkv, s, d, t = 1, 2, 128, 64, 128
    k, v = rand((b, hkv, s, d), 4), rand((b, hkv, s, d), 5)
    cache = freeze_prefix(k, v, 0.3, 0.5, tail_size=t, bs=128)
    for i in range(t):
        cache = append_token(cache, rand((b, hkv, d), 20 + i),
                             rand((b, hkv, d), 700 + i))
    cache2 = refreeze(cache, 0.3, 0.5)
    dense_k = np.asarray(unpack(cache2.k_sp))
    frac_zero = (dense_k == 0).mean()
    assert 0.2 < frac_zero < 0.45        # ~30% K pruning over prefix+tail


def test_pack_capacity_truncation_keeps_bitmap_consistent():
    """Regression: pack() at a capacity below a block's nnz used to keep
    every mask bit while silently dropping the overflow values — unpack
    then gathered garbage for ~1/3 of the entries.  The bitmap must now
    describe exactly what is stored."""
    from repro.core.sparse_format import pack, unpack
    w = rand((128, 64), 7)
    mask = jnp.abs(w) > 0.5                      # nnz >> capacity
    sw = pack(w, mask, block=(128, 64), capacity=2048)
    nnz = int(np.unpackbits(np.asarray(sw.bitmap).view(np.uint8)).sum())
    assert nnz == 2048                           # bits == stored values
    back = np.asarray(unpack(sw))
    kept = back != 0
    # every claimed entry round-trips its true value, and the kept set is
    # the magnitude-top-capacity of the requested mask
    np.testing.assert_array_equal(back[kept], np.asarray(w)[kept])
    dropped_max = np.abs(np.asarray(w))[np.asarray(mask) & ~kept].max()
    assert dropped_max <= np.abs(back[kept]).min() + 1e-7


def test_repack_capacity_roundtrip_grow_and_shrink():
    """Regression for Engine._repack: growing pads bit-exactly; shrinking
    re-ranks and keeps bitmap/values consistent."""
    from repro.core.sparse_format import pack, unpack, repack_capacity
    w = rand((256, 64), 8)
    mask = jnp.abs(w) > 0.9
    sw = pack(w, mask, block=(128, 64))          # natural capacity
    grown = repack_capacity(sw, sw.capacity + 256)
    np.testing.assert_array_equal(np.asarray(unpack(grown)),
                                  np.asarray(unpack(sw)))
    shrunk = repack_capacity(sw, 128)
    back = np.asarray(unpack(shrunk))
    kept = back != 0
    np.testing.assert_array_equal(back[kept], np.asarray(w)[kept])
    nnz = int(np.unpackbits(np.asarray(shrunk.bitmap).view(np.uint8)).sum())
    assert nnz == kept.sum() and nnz <= 2 * 128  # <= Kb blocks * capacity


def test_engine_repack_preserves_decode_attention():
    """Stacked-period repack at a common capacity must not change what any
    period decodes to (the motivating bug for the pooled redesign)."""
    from repro.serving.engine import Engine
    from repro.core import freeze_prefix

    class _E(Engine):                            # repack without a model
        def __init__(self):
            pass
    b, hkv, s, d = 1, 2, 128, 64
    caches = [freeze_prefix(rand((b, hkv, s, d), 30 + i) * (1.0 + i),
                            rand((b, hkv, s, d), 40 + i), 0.3, 0.5,
                            tail_size=128, bs=128) for i in range(2)]
    cap_k = max(c.k_sp.capacity for c in caches)
    cap_v = max(c.v_sp.capacity for c in caches)
    eng = _E()
    q = rand((b, 4, d), 9)
    sm = 1.0 / d ** 0.5
    for c in caches:
        r = eng._repack(c, cap_k, cap_v)
        assert r.k_sp.capacity == cap_k and r.v_sp.capacity == cap_v
        o1 = ref.sparse_decode_attention_ref(q, c.k_sp, c.v_sp, sm,
                                             c.k_tail, c.v_tail, c.tail_len)
        o2 = ref.sparse_decode_attention_ref(q, r.k_sp, r.v_sp, sm,
                                             r.k_tail, r.v_tail, r.tail_len)
        np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                                   rtol=1e-5, atol=1e-5)


def test_engine_generates_past_tail_capacity():
    """Decoding more tokens than the tail holds triggers refreeze and keeps
    generating valid tokens."""
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              kv_tail=64)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 64)), jnp.int32)
    eng = Engine(params, cfg, kv_mode="sparse")
    steps = 64 + 8                      # decode steps exceed the tail
    out, cache = eng.generate({"tokens": toks},
                              SamplingParams(max_new_tokens=steps + 1))
    assert out.shape == (2, steps + 1)
    assert int(cache["pos"]) == 64 + steps
    # prefix grew by one tail fold
    kv = cache["layers"]["l0"]["kv"]
    assert kv.k_sp.bitmap.shape[3] * kv.k_sp.block[0] >= 128
    assert int(kv.tail_len[0]) < 64

"""Analyzer coverage: fixture corpus, clean tree, jaxpr rules, lockfile.

Three layers, three test groups:

* lint — every bad fixture is flagged by EXACTLY its intended rule, the
  clean fixture and the real tree produce zero findings, pragmas work;
* jaxpr audit — hand-built jaxprs trip each rule (host transfer, arena
  gather in PROMISE_IN_BOUNDS, silent bf16->f32) and their fixed
  counterparts don't;
* manifest — lockfile round-trip (write then check passes) and pointed
  failures for each mutation class (signature / hash / transfer /
  donation).
"""
from pathlib import Path

import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.analysis import lint_file, lint_tree
from repro.analysis.jaxpr_audit import Geometry, audit_jaxpr
from repro.analysis.manifest import (check_manifest, fingerprint,
                                     render_manifest, write_manifest)

FIXTURES = Path(__file__).parent / "fixtures" / "jitlint"
GEO = Geometry("fixture", paged=True, spec=False)


# --------------------------------------------------------------------------
# layer 1: AST lint over the fixture corpus
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name,rule,count", [
    ("bad_host_sync.py", "host-sync", 4),
    ("bad_hot_path.py", "hot-path-op", 4),
    ("bad_assert.py", "bare-assert", 2),
    ("bad_block.py", "block-until-ready", 1),
])
def test_fixture_flagged_by_exactly_intended_rule(name, rule, count):
    findings = lint_file(FIXTURES / name, jit_reachable=True, hot_path=True)
    assert {f.rule for f in findings} == {rule}, findings
    assert len(findings) == count, findings
    assert all(f.line > 0 for f in findings)


def test_clean_fixture_zero_findings():
    assert lint_file(FIXTURES / "clean.py",
                     jit_reachable=True, hot_path=True) == []


def test_lint_clean_tree():
    """The committed tree is lint-clean — the CI gate's baseline."""
    findings = lint_tree()
    assert findings == [], "\n".join(str(f) for f in findings)


def test_scope_gating():
    """Outside jit-reachable scope, host-sync/bare-assert don't fire;
    hot-path-op is gated on hot_path."""
    bad = FIXTURES / "bad_host_sync.py"
    assert lint_file(bad, jit_reachable=False, hot_path=False) == []
    hot = FIXTURES / "bad_hot_path.py"
    assert lint_file(hot, jit_reachable=True, hot_path=False) == []


# --------------------------------------------------------------------------
# layer 2: jaxpr audit rules on hand-built jaxprs
# --------------------------------------------------------------------------

def _abs(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_audit_flags_promise_in_bounds_arena_gather():
    n_phys = 29

    def bad(arena, table_row):
        return arena[table_row]            # default: PROMISE_IN_BOUNDS

    closed = jax.make_jaxpr(bad)(_abs((n_phys, 4, 8), jnp.float32),
                                 _abs((3,), jnp.int32))
    findings, _ = audit_jaxpr(closed, "bad", GEO, n_phys=n_phys)
    assert any(f.rule == "table-gather-bounds" for f in findings), findings


def test_audit_accepts_clipped_arena_gather():
    n_phys = 29

    def good(arena, table_row):
        return jnp.take(arena, table_row, axis=0, mode="clip")

    closed = jax.make_jaxpr(good)(_abs((n_phys, 4, 8), jnp.float32),
                                  _abs((3,), jnp.int32))
    findings, _ = audit_jaxpr(closed, "good", GEO, n_phys=n_phys)
    assert findings == [], findings


def test_audit_ignores_non_arena_gather():
    """PROMISE_IN_BOUNDS over a non-arena-shaped operand is fine — the
    rule keys on the leading dim matching n_phys."""
    def f(x, ids):
        return x[ids]

    closed = jax.make_jaxpr(f)(_abs((7, 4), jnp.float32),
                               _abs((3,), jnp.int32))
    findings, _ = audit_jaxpr(closed, "f", GEO, n_phys=29)
    assert findings == []


def test_audit_flags_host_transfer():
    def bad(x):
        jax.debug.print("x={x}", x=x)      # lowers to a callback prim
        return x + 1

    closed = jax.make_jaxpr(bad)(_abs((3,), jnp.float32))
    findings, _ = audit_jaxpr(closed, "bad", GEO)
    assert any(f.rule == "transfer-prim" for f in findings), findings


def test_audit_reports_dtype_promotion():
    def widen(x):
        return x.astype(jnp.float32) * 2.0

    closed = jax.make_jaxpr(widen)(_abs((4,), jnp.bfloat16))
    findings, sites = audit_jaxpr(closed, "widen", GEO)
    assert any(f.rule == "dtype-promote" for f in findings), findings
    assert len(sites) == 1 and not sites[0]["allowed"]
    assert sites[0]["from"] == "bfloat16" and sites[0]["to"] == "float32"


# --------------------------------------------------------------------------
# layer 3: manifest lockfile round-trip + mutation classes
# --------------------------------------------------------------------------

def _tiny_manifest():
    def step(x, y):
        return jnp.dot(x, y) + 1.0

    args = (_abs((4, 8), jnp.float32), _abs((8, 2), jnp.float32))
    closed = jax.make_jaxpr(step)(*args)
    return {"_format": 1, "flat": {"step": fingerprint(closed, args)}}


def test_fingerprint_deterministic_and_structure_sensitive():
    def f(x):
        return x * 2.0 + 1.0

    def g(x):
        return x * 3.0 + 1.0

    args = (_abs((4,), jnp.float32),)
    h1 = fingerprint(jax.make_jaxpr(f)(*args), args)["hash"]
    h2 = fingerprint(jax.make_jaxpr(f)(*args), args)["hash"]
    h3 = fingerprint(jax.make_jaxpr(g)(*args), args)["hash"]
    assert h1 == h2
    assert h1 != h3


def test_lockfile_round_trip(tmp_path):
    lock = tmp_path / "jit_manifest.lock"
    man = _tiny_manifest()
    assert "missing" in check_manifest(man, path=lock)[0]
    write_manifest(man, path=lock)
    assert check_manifest(man, path=lock) == []
    # second write of the same manifest is diff-free
    assert write_manifest(man, path=lock) == ""


@pytest.mark.parametrize("mutate,needle", [
    (lambda r: r.update(signature="1 leaves: float32[9]"),
     "retrace-shaped signature change"),
    (lambda r: r.update(hash="sha256:deadbeefdeadbeef"),
     "structural hash changed"),
    (lambda r: r.update(transfers=r["transfers"] + 1),
     "NEW host transfer"),
])
def test_lockfile_catches_each_mutation_class(tmp_path, mutate, needle):
    lock = tmp_path / "jit_manifest.lock"
    write_manifest(_tiny_manifest(), path=lock)
    drifted = _tiny_manifest()
    mutate(drifted["flat"]["step"])
    problems = check_manifest(drifted, path=lock)
    assert problems, "mutation not caught"
    assert needle in "\n".join(problems)
    assert "flat/step" in "\n".join(problems)


def test_lockfile_catches_lost_donation(tmp_path):
    lock = tmp_path / "jit_manifest.lock"
    locked = _tiny_manifest()
    locked["flat"]["step"]["donated"] = [0]     # pin a donation
    write_manifest(locked, path=lock)
    current = _tiny_manifest()                  # trace donates nothing
    problems = check_manifest(current, path=lock)
    assert any("donation LOST" in p for p in problems), problems


def test_lockfile_catches_new_and_vanished_entries(tmp_path):
    lock = tmp_path / "jit_manifest.lock"
    write_manifest(_tiny_manifest(), path=lock)
    cur = _tiny_manifest()
    cur["flat"]["extra"] = dict(cur["flat"]["step"])
    msgs = "\n".join(check_manifest(cur, path=lock))
    assert "flat/extra: new jitted entry point" in msgs
    gone = _tiny_manifest()
    del gone["flat"]["step"]
    msgs = "\n".join(check_manifest(gone, path=lock))
    assert "flat/step: entry point vanished" in msgs


def test_render_is_deterministic():
    man = _tiny_manifest()
    assert render_manifest(man) == render_manifest(_tiny_manifest())
    assert "[flat]" in render_manifest(man)


def test_committed_lockfile_exists():
    """The real lockfile ships with the tree; `python -m repro.analysis
    --check` (CI) verifies the expensive part — here we only pin that it
    is present and well-formed."""
    import json

    from repro.analysis import LOCKFILE
    assert LOCKFILE.is_file(), "run `python -m repro.analysis --update`"
    data = json.loads(LOCKFILE.read_text())
    assert data["_format"] == 1
    cells = [k for k in data if not k.startswith("_")]
    assert set(cells) >= {"flat", "paged", "flat-spec", "paged-spec"}
    for cell in cells:
        for entry, rec in data[cell].items():
            assert rec["transfers"] == 0, (cell, entry)

"""§Perf features: context-parallel decode attention, expert-parallel MoE,
structured cache layout — numerical equivalence on multi-device meshes
(subprocess with 8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

HERE = os.path.dirname(__file__)


def run_py(code, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.distributed import ShardCtx, default_rules
from repro.launch.mesh import make_mesh
"""


@pytest.mark.slow
def test_cp_attention_exact():
    out = run_py(PRELUDE + """
from repro.core import freeze_prefix, append_token
from repro.kernels import ref
from repro.distributed.cp_attention import sparse_decode_attention_cp
rng = np.random.default_rng(0)
B, Hq, Hkv, S, D = 4, 8, 4, 512, 64
k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
q = jnp.asarray(rng.normal(size=(B, Hq, D)).astype(np.float32))
cache = freeze_prefix(k, v, 0.3, 0.5, tail_size=16, bs=128)
cache = append_token(cache, jnp.zeros((B,Hkv,D)), jnp.zeros((B,Hkv,D)))
sm = 1.0/np.sqrt(D)
o_ref = ref.sparse_decode_attention_ref(q, cache.k_sp, cache.v_sp, sm,
                                        cache.k_tail, cache.v_tail, cache.tail_len)
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh, default_rules(False, get_config("llama3-8b")))
with mesh:
    o_cp = jax.jit(lambda q, c: sparse_decode_attention_cp(q, c, Hkv, sm, ctx))(q, cache)
err = float(np.abs(np.asarray(o_cp) - np.asarray(o_ref)).max())
print("ERR", err)
assert err < 1e-4
""")
    assert "ERR" in out


@pytest.mark.slow
def test_ep_moe_exact():
    out = run_py(PRELUDE + """
import dataclasses
from repro.models import lm
from repro.models.moe import moe_apply
cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
cfg_ep = dataclasses.replace(cfg, ep_moe=True)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
p_moe = jax.tree_util.tree_map(lambda a: a[0], params["blocks"]["l0"]["ffn"])
x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, cfg.d_model)).astype(np.float32))
o_local = moe_apply(p_moe, x, cfg, None)
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh, default_rules(False, cfg_ep))
with mesh:
    o_ep = jax.jit(lambda p, x: moe_apply(p, x, cfg_ep, ctx))(p_moe, x)
err = float(np.abs(np.asarray(o_ep) - np.asarray(o_local)).max())
print("ERR", err)
assert err < 1e-4
""")
    assert "ERR" in out


@pytest.mark.slow
def test_cp_ep_decode_step_runs():
    """Full serve_step with cp+ep on a hybrid MoE arch under a mesh."""
    run_py(PRELUDE + """
import dataclasses
from repro.models import lm
cfg = dataclasses.replace(get_config("jamba-1.5-large-398b").reduced(),
                          cp_decode=True, ep_moe=True)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
cache = lm.init_cache(cfg, 2, 128, mode="sparse")
cache["pos"] = jnp.asarray(128, jnp.int32)
mesh = make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh, default_rules(False, cfg))
with mesh:
    logits, cache2 = jax.jit(
        lambda p, c, t: lm.forward_decode(p, c, t, cfg, ctx))(
            params, cache, jnp.ones((2, 1), jnp.int32))
assert np.all(np.isfinite(np.asarray(logits)))
print("OK", logits.shape)
""")


def test_structured_layout_roundtrip():
    import jax
    import jax.numpy as jnp
    from repro.core import freeze_prefix, unpack
    rng = np.random.default_rng(3)
    k = jnp.asarray(rng.normal(size=(2, 4, 256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 4, 256, 64)).astype(np.float32))
    c_flat = freeze_prefix(k, v, 0.0, 0.0, bs=128, structured=False)
    c_str = freeze_prefix(k, v, 0.0, 0.0, bs=128, structured=True)
    d_flat = np.asarray(unpack(c_flat.k_sp)).reshape(2, 4, 256, 64)
    d_str = np.asarray(unpack(c_str.k_sp))
    np.testing.assert_array_equal(d_flat, d_str)
    np.testing.assert_array_equal(d_str, np.asarray(k))

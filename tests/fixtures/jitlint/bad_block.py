"""Fixture: `.block_until_ready()` outside the engine's sync point.

Must be flagged as `block-until-ready` and nothing else.
"""


def await_tokens(tokens):
    return tokens.block_until_ready()

"""Fixture: bare asserts inside a jit-reachable pool transition.

Every violation here must be flagged as `bare-assert` and nothing else.
"""


def refreeze(state, fresh_ids, n_phys):
    assert fresh_ids.shape[0] > 0
    assert n_phys > 0, "empty arena"
    return state

"""Fixture: host<->device syncs inside a jit-reachable tick helper.

Every violation here must be flagged as `host-sync` and nothing else.
"""
import numpy as np


def tick(state, cache):
    tail = int(cache.tail_len)          # sync: concrete read of a field
    frac = float(state["occupancy"])    # sync: float() on traced value
    flag = state["done"].item()         # sync: .item()
    host = np.asarray(state["tokens"])  # sync: np on a traced value
    return tail, frac, flag, host

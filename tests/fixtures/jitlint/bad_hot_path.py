"""Fixture: banned per-token ops reintroduced on the serving hot path.

Every violation here must be flagged as `hot-path-op` and nothing else.
"""
import jax.numpy as jnp


def decode_step(kv, new_kv, logits):
    kv = jnp.concatenate([kv, new_kv], axis=1)   # per-token realloc
    kv = jnp.repeat(kv, 2, axis=2)               # GQA expansion by copy
    order = jnp.argsort(logits, axis=-1)         # full-vocab sort per token
    return kv, jnp.sort(order)

"""Fixture: zero findings expected.

Exercises the negative space of every rule — shape-tuple ints are host
Python already, pragma'd exceptions are documented escapes, and ops that
merely *look* like banned ones (method names on other objects) pass.
"""
import jax.numpy as jnp


def sizes(x):
    # int() over .shape / .ndim is not a sync: shapes are Python ints
    return int(x.shape[0]), int(x.ndim)


def legacy_prefill(chunks):
    # documented exception: prefill-only path, not per-token
    return jnp.concatenate(chunks, axis=1)  # jitlint: disable=hot-path-op


def sync_boundary(tokens):
    # the engine's one designated sync point carries the pragma
    return tokens.block_until_ready()  # jitlint: disable=block-until-ready


def not_the_real_thing(db):
    # `.repeat`/`.sort` as methods of non-jnp objects are out of scope
    return db.sort(key=len)

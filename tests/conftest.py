import os
import sys

# Smoke tests and benches must see 1 device (the dry-run subprocesses set
# their own XLA_FLAGS before importing jax) — so do NOT set device-count
# flags here.  A couple of sharding tests spawn subprocesses with their own
# flags instead.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

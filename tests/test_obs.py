"""Observability: exactness, export formats, and the zero-overhead bar.

Three layers of guarantees:

* **Primitives** — the pure-Python :func:`repro.obs.percentile`
  reproduces ``numpy.percentile`` bit-for-bit (it is the shared helper
  every ``bench_serving`` mode reports through); histogram buckets use
  Prometheus ``le`` edge semantics; the rolling median matches a sorted
  reference.
* **Exporters** — ``registry.snapshot()``, the Prometheus text
  exposition (``_bucket`` series cumulative, ``+Inf`` == ``_count``),
  a live ``MetricsServer`` scrape over HTTP, and the trace sink's
  Chrome trace-event JSON all round-trip real values.
* **Zero overhead** — the acceptance bar from the PR: a greedy engine
  run with observability on is token-identical to the same run with it
  off, steady-state trace counts stay flat, and the tokens-committed
  counter agrees exactly with the tokens actually emitted.  Fault
  firings and snapshot save/load show up in the trace.
"""
import dataclasses
import json
import math
import urllib.request

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.obs import (DEFAULT_LATENCY_BUCKETS, Histogram, MetricsRegistry,
                       MetricsServer, Observability, RollingWindow,
                       TraceSink, percentile, percentile_summary, render)
from repro.serving import (ContinuousEngine, Fault, FaultPlan,
                           SamplingParams, stable_trace_counts)
from repro.serving.faults import PAGE_EXHAUSTION
from repro.serving.sampling import RequestMetrics


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# percentile: exact NumPy parity
# ---------------------------------------------------------------------------

def test_percentile_matches_numpy_reference():
    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 7, 100, 101):
        vals = rng.normal(size=n).tolist()
        for q in (0, 1, 25, 50, 75, 90, 99, 99.9, 100):
            assert percentile(vals, q) == pytest.approx(
                float(np.percentile(vals, q)), rel=0, abs=0), (n, q)


def test_percentile_edge_cases():
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile([1.0], 101)
    with pytest.raises(ValueError):
        percentile([1.0], -1)
    assert percentile([3.0], 99) == 3.0          # single sample: any q
    assert percentile([1.0, 2.0], 50) == 1.5     # exact midpoint interp


def test_percentile_summary_filters_none_and_scales():
    s = percentile_summary([0.1, None, 0.3, None, 0.2], qs=(50,), scale=1e3)
    assert s == {"count": 3, "p50": pytest.approx(200.0)}
    empty = percentile_summary([None, None])
    assert empty["count"] == 0
    assert empty["p50"] is None and empty["p99"] is None


# ---------------------------------------------------------------------------
# histogram: le edge semantics + exact percentiles
# ---------------------------------------------------------------------------

def test_histogram_bucket_edges_use_le_semantics():
    h = Histogram(buckets=(1.0, 2.0))
    for v in (0.5, 1.0, 1.5, 2.0, 99.0):
        h.observe(v)
    # le=1.0 owns {0.5, 1.0}; le=2.0 adds {1.5, 2.0}; +Inf adds {99.0}
    assert h.cumulative_buckets() == [(1.0, 2), (2.0, 4), (math.inf, 5)]
    assert h.count == 5
    assert h.sum == pytest.approx(0.5 + 1.0 + 1.5 + 2.0 + 99.0)


def test_histogram_percentiles_exact_vs_numpy():
    rng = np.random.default_rng(1)
    vals = rng.exponential(0.05, size=500)
    h = Histogram()
    for v in vals:
        h.observe(float(v))
    assert h.exact
    for q in (50, 90, 99):
        assert h.percentile(q) == pytest.approx(
            float(np.percentile(vals, q)), rel=1e-12)
    assert Histogram().percentile(50) is None    # empty: soft None
    snap = h.snapshot()
    assert snap["count"] == 500 and snap["p50"] == h.percentile(50)


def test_histogram_validation():
    with pytest.raises(ValueError):
        Histogram(buckets=())
    with pytest.raises(ValueError):
        Histogram(buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram(buckets=(1.0, 1.0))


def test_histogram_reservoir_is_deterministic_and_bounded():
    a = Histogram(buckets=(1.0,), max_samples=16, seed=3)
    b = Histogram(buckets=(1.0,), max_samples=16, seed=3)
    for i in range(200):
        a.observe(i * 0.01)
        b.observe(i * 0.01)
    assert not a.exact and len(a._samples) == 16
    assert a._samples == b._samples              # seeded: replayable
    assert a.count == 200                        # buckets never degrade


def test_rolling_window_median_and_eviction():
    w = RollingWindow(size=3)
    assert w.median() is None and w.mean() is None
    w.push(10.0)
    assert w.median() == 10.0
    w.push(30.0)
    assert w.median() == 20.0                    # even count: midpoint
    w.push(20.0)
    assert w.median() == 20.0
    w.push(1000.0)                               # evicts the 10.0
    assert w.median() == 30.0
    assert len(w) == 3


# ---------------------------------------------------------------------------
# registry + exporters
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_kind_conflict():
    r = MetricsRegistry()
    c1 = r.counter("x_total", "help", reason="stop")
    c2 = r.counter("x_total", reason="stop")
    assert c1 is c2                              # same name+labels
    c3 = r.counter("x_total", reason="shed")
    assert c3 is not c1                          # distinct series
    with pytest.raises(ValueError):
        r.gauge("x_total")                       # kind conflict
    with pytest.raises(ValueError):
        r.counter("bad name")
    with pytest.raises(ValueError):
        r.counter("ok_total", **{"bad-label": 1})
    with pytest.raises(ValueError):
        c1.inc(-1)                               # counters are monotonic


def test_registry_snapshot_keys():
    r = MetricsRegistry()
    r.counter("a_total").inc(3)
    r.gauge("g").set(7)
    r.histogram("h_seconds").observe(0.2)
    s = r.snapshot()
    assert s["a_total"] == 3.0
    assert s["g"] == 7.0
    assert s["h_seconds"]["count"] == 1
    r.counter("lbl_total", reason="stop").inc()
    assert r.snapshot()['lbl_total{reason="stop"}'] == 1.0


def test_prometheus_render_format():
    r = MetricsRegistry()
    r.counter("req_total", "requests", reason="stop").inc(4)
    h = r.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = render(r)
    assert "# TYPE req_total counter" in text
    assert '# HELP req_total requests' in text
    assert 'req_total{reason="stop"} 4' in text
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text   # == _count
    assert "lat_seconds_count 3" in text
    assert text.endswith("\n")


def test_metrics_server_live_scrape():
    r = MetricsRegistry()
    r.counter("up_total").inc(2)
    srv = MetricsServer(r, port=0).start()
    try:
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            body = resp.read().decode()
            assert resp.headers["Content-Type"].startswith("text/plain")
        assert "up_total 2" in body
        r.counter("up_total").inc()              # live: next scrape moves
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            assert "up_total 3" in resp.read().decode()
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://{srv.host}:{srv.port}/nope", timeout=5)
    finally:
        srv.close()


def test_trace_sink_writes_valid_chrome_trace(tmp_path):
    p = tmp_path / "trace.json"
    t = TraceSink(str(p))
    t.process_name(0, "engine")
    t.complete("tick", 10.0, 0.25, tid=0, args={"n": 1})
    t.instant("fault:x", 10.1, tid=0)
    t.counter("load", 10.2, {"queue": 3})
    t.close()
    t.close()                                    # idempotent
    evs = json.loads(p.read_text())
    assert [e["ph"] for e in evs] == ["M", "X", "i", "C"]
    tick = evs[1]
    assert tick["ts"] == 0.0                     # rebased to first stamp
    assert tick["dur"] == pytest.approx(0.25e6)  # seconds -> us
    assert evs[2]["ts"] == pytest.approx(0.1e6)
    assert t.events_written == 4


# ---------------------------------------------------------------------------
# RequestMetrics derived timings
# ---------------------------------------------------------------------------

def test_request_metrics_ttft_split_and_tpot():
    m = RequestMetrics(arrival_time=1.0, first_token_time=4.0,
                       finished_time=10.0, decode_ticks=6,
                       num_generated=7, admitted_time=3.0)
    assert m.queue_time == pytest.approx(2.0)    # submit -> slot
    assert m.prefill_time == pytest.approx(1.0)  # slot -> first token
    assert m.ttft == pytest.approx(3.0)          # their sum
    assert m.decode_time == pytest.approx(6.0)
    assert m.tpot == pytest.approx(1.0)          # 6s / (7 - 1) tokens
    assert m.e2e_latency == pytest.approx(9.0)


def test_request_metrics_none_propagation():
    # died in the queue: no admission, no first token
    m = RequestMetrics(arrival_time=1.0, first_token_time=None,
                       finished_time=2.0)
    assert m.queue_time is None and m.prefill_time is None
    assert m.decode_time is None and m.tpot is None
    # one generated token: tpot undefined (no inter-token gap)
    m1 = RequestMetrics(arrival_time=0.0, first_token_time=1.0,
                        finished_time=2.0, num_generated=1,
                        admitted_time=0.5)
    assert m1.tpot is None
    assert m1.prefill_time == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Observability facade (clock-driven, no engine)
# ---------------------------------------------------------------------------

def test_observability_delta_sync_and_report():
    obs = Observability()
    counters = {"shed": 0, "timeout": 0}
    obs.tick(start=0.0, now=0.1, tick_no=1, committed=3, queue_depth=2,
             active=1, slots=4, counters=counters, spec_hist=[0, 2, 0])
    counters["shed"] = 2
    obs.tick(start=0.1, now=0.2, tick_no=2, committed=1, queue_depth=0,
             active=1, slots=4, counters=counters, spec_hist=[0, 2, 1])
    s = obs.snapshot()
    assert s["repro_engine_ticks_total"] == 2.0
    assert s["repro_tokens_committed_total"] == 4.0
    assert s['repro_lifecycle_events_total{event="shed"}'] == 2.0
    # spec histogram synced by delta, not re-added
    assert s['repro_spec_windows_total{accepted="1"}'] == 2.0
    assert s['repro_spec_windows_total{accepted="2"}'] == 1.0
    line = obs.report_line()
    assert line.startswith("[obs]") and "ticks=2" in line and "shed=2" in line


def test_observability_periodic_report_fires_on_interval():
    lines = []
    obs = Observability(report_every=1.0, report_fn=lines.append)
    for i in range(5):
        obs.tick(start=i * 0.4, now=i * 0.4 + 0.1, tick_no=i, committed=1,
                 queue_depth=0, active=1, slots=1, counters={})
    # now stamps: 0.1, 0.5, 0.9, 1.3, 1.7 -> fires at 0.1 (first tick)
    # and 1.3 (first tick >= one interval later), nothing in between
    assert len(lines) == 2 and all(l.startswith("[obs]") for l in lines)


# ---------------------------------------------------------------------------
# the zero-overhead bar: engine integration
# ---------------------------------------------------------------------------

def _setup(kv_tail=32):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=kv_tail)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_obs_on_is_token_identical_and_flat(tmp_path):
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (24,)).tolist() for _ in range(4)]
    sp = SamplingParams(max_new_tokens=8)

    def serve(obs):
        eng = ContinuousEngine(params, cfg, slots=2, max_tokens=80,
                               prefill_chunk=16, obs=obs)
        rids = [eng.submit(p, sp) for p in prompts]
        out = eng.run()
        return eng, {r: list(out[r].token_ids) for r in rids}

    _, base = serve(None)
    obs = Observability(trace_path=str(tmp_path / "t.json"))
    eng, toks = serve(obs)

    assert toks == base                          # token-identical
    traces = stable_trace_counts(eng.trace_counts())
    assert all(v <= 1 for v in traces.values()), traces

    s = obs.snapshot()
    total = sum(len(t) for t in toks.values())
    assert s["repro_tokens_committed_total"] == float(total)
    assert s['repro_requests_finished_total{reason="length"}'] == 4.0
    assert s["repro_ttft_seconds"]["count"] == 4
    assert s["repro_tpot_seconds"]["count"] == 4
    assert s["repro_queue_time_seconds"]["count"] == 4

    obs.close()
    evs = json.loads((tmp_path / "t.json").read_text())
    names = {e["name"] for e in evs}
    assert {"tick", "decode", "prefill_chunk", "queued", "prefill",
            "submit", "finish:length", "engine_load"} <= names


def test_obs_traces_faults_and_snapshots(tmp_path):
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (32,)).tolist() for _ in range(3)]
    plan = FaultPlan([Fault(PAGE_EXHAUSTION, 2)])
    obs = Observability(trace_path=str(tmp_path / "t.json"))
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           prefill_chunk=16, paged=True, faults=plan,
                           obs=obs)
    for p in prompts:
        eng.submit(p, SamplingParams(max_new_tokens=4))
    eng.run()
    eng.save_snapshot(str(tmp_path / "snap"))

    eng2 = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                            prefill_chunk=16, paged=True, obs=obs)
    assert eng2.load_snapshot(str(tmp_path / "snap")) > 0
    obs.close()

    s = obs.snapshot()
    assert s['repro_fault_injections_total{site="page-exhaustion"}'] == 1.0
    assert s['repro_snapshots_total{kind="save"}'] == 1.0
    assert s['repro_snapshots_total{kind="load"}'] == 1.0
    assert s["repro_trie_lookup_blocks_total"] > 0
    names = {e["name"] for e in
             json.loads((tmp_path / "t.json").read_text())}
    assert {"fault:page-exhaustion", "snapshot:save",
            "snapshot:load"} <= names

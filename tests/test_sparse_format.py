"""Sparse format: pack/unpack round-trips, bitmaps, property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container ships without hypothesis
    class _St:
        """Minimal stand-in so @given-decorated tests collect (then skip)."""
        def integers(self, *a, **k): return None
        def floats(self, *a, **k): return None
    st = _St()

    def settings(**_kw):
        return lambda fn: fn

    def given(**_kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def wrapper():
                pass
            wrapper.__name__ = fn.__name__
            return wrapper
        return deco

from repro.core import (pack, unpack, pack_bits, unpack_bits, make_mask,
                        prune_global, prune_balanced, prune_wanda,
                        quantize_weight_int8, packed_spec)
from repro.core.sparse_format import balanced_capacity


def rand(shape, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(dtype))


@pytest.mark.parametrize("shape,block", [
    ((128, 128), (128, 128)),
    ((256, 384), (128, 128)),
    ((300, 200), (128, 128)),      # non-multiple -> padding
    ((512, 256), (256, 128)),
    ((64, 96), (32, 32)),
])
@pytest.mark.parametrize("sparsity", [0.0, 0.3, 0.5, 0.9])
def test_pack_unpack_roundtrip(shape, block, sparsity):
    w = rand(shape)
    mask = make_mask(w, sparsity, "balanced", block)
    sw = pack(w, mask, block)
    wd = unpack(sw)
    np.testing.assert_array_equal(np.asarray(wd),
                                  np.asarray(jnp.where(mask, w, 0)))


def test_global_mask_roundtrip_exact():
    w = rand((256, 256), seed=3)
    mask = prune_global(w, 0.5)
    sw = pack(w, mask, (128, 128))
    np.testing.assert_array_equal(np.asarray(unpack(sw)),
                                  np.asarray(jnp.where(mask, w, 0)))


def test_bitmap_roundtrip():
    m = (np.random.default_rng(1).random((7, 4, 96)) > 0.5).astype(np.int32)
    words = pack_bits(jnp.asarray(m))
    back = unpack_bits(words, 96)
    np.testing.assert_array_equal(np.asarray(back), m)


def test_compression_ratio_matches_formula():
    # bf16 at 50% balanced: 0.5 values + 1/16 bitmap
    w = rand((1024, 1024)).astype(jnp.bfloat16)
    mask = make_mask(w, 0.5, "balanced", (256, 128))
    sw = pack(w, mask, (256, 128))
    assert abs(sw.compression_ratio() - (0.5 + 1 / 16)) < 0.01


def test_balanced_capacity_exact():
    w = rand((512, 512), seed=5)
    mask = prune_balanced(w, 0.5, (128, 128))
    sw = pack(w, mask, (128, 128))
    assert sw.capacity == balanced_capacity(0.5, (128, 128))


def test_pad_to_blocks_sharding_padding():
    w = rand((512, 384))
    mask = make_mask(w, 0.5, "balanced", (128, 128))
    sw = pack(w, mask, (128, 128), pad_to_blocks=(1, 4))
    assert sw.bitmap.shape[1] == 4          # 3 blocks padded to 4
    np.testing.assert_array_equal(np.asarray(unpack(sw)),
                                  np.asarray(jnp.where(mask, w, 0)))


def test_stacked_leading_dims():
    w = rand((3, 256, 256), seed=9)
    def pack_one(w2):
        return pack(w2, make_mask(w2, 0.5, "balanced", (128, 128)),
                    (128, 128), capacity=8192)
    sw = jax.vmap(pack_one)(w)
    assert sw.bitmap.shape[0] == 3
    wd = unpack(sw)
    assert wd.shape == (3, 256, 256)
    for i in range(3):
        ref = unpack(pack_one(w[i]))
        np.testing.assert_array_equal(np.asarray(wd[i]), np.asarray(ref))


def test_packed_spec_matches_real_pack():
    w = rand((300, 200)).astype(jnp.bfloat16)
    mask = make_mask(w, 0.5, "balanced", (128, 128))
    cap = balanced_capacity(0.5, (128, 128))
    sw = pack(w, mask, (128, 128), capacity=cap)
    spec = packed_spec(300, 200, 0.5, (128, 128), jnp.bfloat16)
    assert spec.bitmap.shape == sw.bitmap.shape
    assert spec.values.shape == sw.values.shape
    assert spec.values.dtype == sw.values.dtype


# ---------------------------------------------------------------------------
# property-based
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 300), n=st.integers(1, 300),
       sparsity=st.floats(0.0, 0.95), seed=st.integers(0, 2**16))
def test_property_roundtrip_any_shape(k, n, sparsity, seed):
    w = rand((k, n), seed=seed)
    mask = make_mask(w, sparsity, "balanced", (32, 32))
    sw = pack(w, mask, (32, 32))
    np.testing.assert_array_equal(np.asarray(unpack(sw)),
                                  np.asarray(jnp.where(mask, w, 0)))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), sparsity=st.floats(0.05, 0.95))
def test_property_sparsity_level(seed, sparsity):
    w = rand((128, 128), seed=seed)
    mask = prune_global(w, sparsity)
    actual = 1.0 - float(jnp.mean(mask.astype(jnp.float32)))
    assert abs(actual - sparsity) < 0.02


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_balanced_keeps_largest(seed):
    """Every kept entry within a block is >= every dropped entry."""
    w = rand((64, 64), seed=seed)
    mask = prune_balanced(w, 0.5, (32, 32))
    a = np.abs(np.asarray(w))
    m = np.asarray(mask)
    for bi in range(2):
        for bj in range(2):
            blk_a = a[bi*32:(bi+1)*32, bj*32:(bj+1)*32]
            blk_m = m[bi*32:(bi+1)*32, bj*32:(bj+1)*32]
            if blk_m.all() or not blk_m.any():
                continue
            assert blk_a[blk_m].min() >= blk_a[~blk_m].max() - 1e-7


def test_wanda_uses_activation_norms():
    w = jnp.ones((64, 32))
    act = jnp.concatenate([jnp.full((32,), 10.0), jnp.full((32,), 0.1)])
    mask = prune_wanda(w, act, 0.5)
    # high-activation input channels should be kept
    assert float(mask[:32].mean()) > float(mask[32:].mean())


def test_int8_quant_error_bounded():
    w = rand((256, 128), seed=11)
    q, scale = quantize_weight_int8(w)
    back = q.astype(jnp.float32) * scale[None, :]
    err = np.abs(np.asarray(back - w))
    assert err.max() <= float(np.abs(np.asarray(w)).max()) / 127.0 + 1e-6

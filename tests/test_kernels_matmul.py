"""Pallas matmul kernels vs pure-jnp oracles — shape/dtype sweeps in
interpret mode (the kernel body executes on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pack, make_mask, quantize_weight_int8
from repro.kernels import ops, ref
from repro.kernels.dense_matmul import dense_matmul_pallas
from repro.kernels.sparse_matmul import sparse_matmul_pallas
from repro.kernels.sparse_gemv import sparse_gemv_pallas
from repro.kernels.sparse_matmul_int8 import sparse_matmul_int8_pallas
from repro.core.quant import quantize_act_int8


def rand(shape, seed=0, dtype=np.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(dtype))


def make_sparse(k, n, sparsity=0.5, block=(128, 128), dtype=jnp.float32,
                seed=0, policy="balanced"):
    w = rand((k, n), seed=seed).astype(dtype)
    mask = make_mask(w.astype(jnp.float32), sparsity, policy, block)
    return jnp.where(mask, w, 0), pack(w, mask, block)


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (16, 256, 384),
                                   (128, 512, 256), (5, 200, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_matmul(m, k, n, dtype):
    x = rand((m, k), 1).astype(dtype)
    w = rand((k, n), 2).astype(dtype)
    out = dense_matmul_pallas(x, w, block=(128, 128, 128), interpret=True)
    expect = ref.dense_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2)


@pytest.mark.parametrize("m,k,n", [(16, 128, 128), (32, 384, 256),
                                   (128, 256, 512)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_matmul_sweep(m, k, n, sparsity, dtype):
    x = rand((m, k), 3).astype(dtype)
    wd, sw = make_sparse(k, n, sparsity, dtype=dtype, seed=4)
    out = sparse_matmul_pallas(x, sw, tm=16, interpret=True)
    expect = jnp.dot(x.astype(jnp.float32),
                     wd.astype(jnp.float32)).astype(dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2)


def test_sparse_matmul_global_policy():
    x = rand((8, 256), 5)
    wd, sw = make_sparse(256, 256, 0.6, seed=6, policy="global")
    out = sparse_matmul_pallas(x, sw, tm=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ wd),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m", [1, 2, 8])
def test_sparse_gemv(m):
    x = rand((m, 384), 7)
    wd, sw = make_sparse(384, 256, 0.5, seed=8)
    out = sparse_gemv_pallas(x, sw, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ wd),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(16, 128, 128), (64, 256, 384)])
@pytest.mark.parametrize("sparsity", [0.0, 0.5])
def test_sparse_int8(m, k, n, sparsity):
    x = rand((m, k), 9)
    w = rand((k, n), 10)
    mask = make_mask(w, sparsity, "balanced", (128, 128))
    q, scale = quantize_weight_int8(jnp.where(mask, w, 0))
    sw = pack(q, mask, (128, 128), scale=scale)
    xq, sx = quantize_act_int8(x)
    out = sparse_matmul_int8_pallas(xq, sx, sw, tm=16, interpret=True)
    expect = ref.sparse_matmul_int8_ref(x, sw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-3)
    # and the whole int8 path approximates the f32 product
    dense = np.asarray(x @ jnp.where(mask, w, 0))
    rel = np.abs(np.asarray(out) - dense).mean() / np.abs(dense).mean()
    assert rel < 0.05


def test_ops_dispatch_backends():
    x = rand((4, 256), 11)
    wd, sw = make_sparse(256, 128, 0.5, seed=12)
    with ops.backend("xla"):
        a = ops.sparse_matmul(x, sw)
    with ops.backend("interpret"):
        b = ops.sparse_matmul(x, sw)   # m<=8 -> gemv kernel
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_linear_dispatch_types():
    x = rand((4, 256), 13)
    w = rand((256, 128), 14)
    wd, sw = make_sparse(256, 128, 0.5, seed=14)
    assert ops.linear(x, w).shape == (4, 128)
    assert ops.linear(x, sw).shape == (4, 128)


def test_leading_batch_dims():
    x = rand((2, 3, 256), 15)
    wd, sw = make_sparse(256, 128, 0.5, seed=16)
    with ops.backend("xla"):
        out = ops.sparse_matmul(x, sw)
    assert out.shape == (2, 3, 128)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(x.reshape(6, 256) @ wd).reshape(2, 3, 128),
        rtol=1e-4, atol=1e-4)

"""Multi-device tests (8 host devices in subprocesses): sharded training
equivalence, sparse decode under a mesh, compressed-DP gradients, elastic
checkpoint restore, and spec-derivation units."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import ShardCtx, default_rules, tree_param_specs
from repro.distributed.sharding import zero1_specs
from repro.launch.train import train_loop
from repro.data import DataConfig
from repro.models import lm
from repro.models import module as mod

WORKER = os.path.join(os.path.dirname(__file__), "workers",
                      "sharded_train_worker.py")


def run_worker(which, timeout=600):
    out = subprocess.run([sys.executable, WORKER, which],
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_matches_single_device():
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen3-0.6b").reduced(),
                              param_dtype="float32",
                              compute_dtype="float32")
    dc = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    from repro.optim import OptConfig
    _, _, single = train_loop(
        cfg, 4, dc, optc=OptConfig(peak_lr=1e-3, warmup_steps=1,
                                   decay_steps=4))
    sharded = run_worker("train")["losses"]
    np.testing.assert_allclose(single, sharded, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_sparse_decode_under_mesh():
    rec = run_worker("decode_sparse")
    assert rec["ok"] and rec["shape"][0] == 2


@pytest.mark.slow
def test_compressed_dp_gradients():
    rec = run_worker("compressed")
    assert abs(rec["loss_c"] - rec["loss_r"]) < 1e-3
    assert rec["rel"] < 0.05           # bf16-compressed grads ~= fp32 grads
    assert 0 < rec["err_mag"] < 1e-1   # error feedback captured residuals


@pytest.mark.slow
def test_elastic_restore_different_mesh():
    rec = run_worker("elastic")
    assert np.isfinite(rec["loss_after"])
    assert rec["loss_after"] < rec["loss_before"] + 0.5


# ---------------------------------------------------------------------------
# sharding-spec derivation units (no devices needed)
# ---------------------------------------------------------------------------

class FakeMesh:
    shape = {"data": 16, "model": 16}


def _ctx():
    return ShardCtx(FakeMesh(), default_rules(False, get_config("llama3-8b")))


def test_spec_divisibility_fallback():
    ctx = _ctx()
    # kv_heads=8 can't shard over model=16 -> None
    assert ctx.spec(("batch", "kv_heads"), (128, 8)) == P("data", None)
    assert ctx.spec(("batch", "heads"), (128, 32)) == P("data", "model")


def test_spec_duplicate_axis_first_wins():
    ctx = _ctx()
    s = ctx.spec(("batch", "ctx", None), (256, 4096, 64))
    # "ctx" wants (data, model) but data already used by batch
    assert s == P("data", "model", None)


def test_param_specs_tp_axes():
    cfg = get_config("llama3-8b")
    ctx = _ctx()
    specs = lm.model_specs(cfg)
    params = mod.abstract(specs)
    ps = tree_param_specs(ctx, specs, params)
    wq = ps["blocks"]["l0"]["mixer"]["wq"]
    assert wq == P(None, None, "model")          # (layers, embed, heads)
    wdown = ps["blocks"]["l0"]["ffn"]["w_down"]
    assert wdown == P(None, "model", None)       # (layers, ffn, embed)


def test_zero1_adds_dp_dim():
    cfg = get_config("llama3-8b")
    ctx = _ctx()
    specs = lm.model_specs(cfg)
    params = mod.abstract(specs)
    ps = tree_param_specs(ctx, specs, params)
    z = zero1_specs(ps, params, cfg, ctx)
    wq = z["blocks"]["l0"]["mixer"]["wq"]        # (32, 4096, 4096)
    assert "data" in jax.tree_util.tree_leaves([list(wq)])  # dp somewhere
    assert wq == P("data", None, "model") or wq == P(None, "data", "model")


def test_fsdp_rules_shard_embed_axis():
    cfg = get_config("deepseek-67b")  # fsdp=True
    ctx = ShardCtx(FakeMesh(), default_rules(False, cfg))
    specs = lm.model_specs(cfg)
    params = mod.abstract(specs)
    ps = tree_param_specs(ctx, specs, params)
    wq = ps["blocks"]["l0"]["mixer"]["wq"]       # (layers, embed, heads)
    assert wq == P(None, "data", "model")

"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs.  One test per assigned architecture."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, PAPER_ARCH, get_config, applicable_shapes
from repro.distributed import NULL_CTX
from repro.models import lm
from repro.optim import OptConfig, init_opt_state
from repro.train import make_train_step


def make_batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32),
             "mask": jnp.ones((b, s), jnp.float32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.zeros((b, s, cfg.d_model), jnp.float32)
    if cfg.frontend:
        batch["frontend_embeds"] = jnp.zeros(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + [PAPER_ARCH])
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    h = lm.forward_train(params, batch, cfg, NULL_CTX)
    logits = lm.logits_fn(params, h, cfg, NULL_CTX)
    assert logits.shape[-1] == cfg.vocab
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, NULL_CTX, OptConfig(peak_lr=1e-3)))
    params2, opt2, mets = step(params, opt, make_batch(cfg))
    assert np.isfinite(float(mets["loss"]))
    assert int(opt2["step"]) == 1
    # fp32 master weights actually moved (bf16 params may round to equal)
    l1 = jax.tree_util.tree_leaves(opt["master"])[0]
    l2 = jax.tree_util.tree_leaves(opt2["master"])[0]
    assert np.abs(np.asarray(l1) - np.asarray(l2)).max() > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    cache = lm.init_cache(cfg, 2, 128, mode="sparse")
    cache["pos"] = jnp.asarray(128, jnp.int32)
    logits, cache2 = lm.forward_decode(params, cache,
                                       jnp.ones((2, 1), jnp.int32),
                                       cfg, NULL_CTX)
    assert logits.shape == (2, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert int(cache2["pos"]) == 129


def test_applicable_shapes_per_family():
    longs = [a for a in ARCH_IDS
             if "long_500k" in applicable_shapes(get_config(a))]
    assert set(longs) == {"rwkv6-7b", "jamba-1.5-large-398b"}
    # 40 assigned cells = 10 archs x 4 shapes; 32 runnable + 8 noted skips
    total = sum(len(applicable_shapes(get_config(a))) for a in ARCH_IDS)
    assert total == 32


def test_full_configs_match_assignment():
    c = get_config("deepseek-67b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (95, 8192, 64, 8, 22016, 102400)
    c = get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.n_experts, c.top_k, c.attn_every) == (72, 16, 2, 8)
    c = get_config("qwen3-0.6b")
    assert c.qk_norm and (c.n_layers, c.d_model, c.vocab) == (28, 1024, 151936)
    c = get_config("rwkv6-7b")
    assert c.family == "ssm" and c.n_kv == 0 and c.d_ff == 14336
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.n_experts, c.top_k, c.d_ff) == (16, 2, 6400)
    c = get_config("llama4-scout-17b-a16e")
    assert c.top_k == 1 and c.shared_expert
    c = get_config("seamless-m4t-medium")
    assert c.family == "encdec" and c.vocab == 256206 and c.enc_layers == 12
    c = get_config("internvl2-1b")
    assert c.family == "vlm" and (c.d_model, c.n_heads, c.n_kv) == (896, 14, 2)
    c = get_config("phi3-mini-3.8b")
    assert c.n_kv == 32 and c.vocab == 32064
    c = get_config("llama3.2-3b")
    assert (c.n_layers, c.d_model, c.d_ff) == (28, 3072, 8192)


def test_param_counts_plausible():
    """Full-config param counts should be near the advertised sizes."""
    from repro.models.module import param_count
    approx = {
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "llama3.2-3b": (2.6e9, 4.2e9),
        "deepseek-67b": (60e9, 72e9),
        "phi3-mini-3.8b": (3.3e9, 4.5e9),
        "rwkv6-7b": (6e9, 9e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 46e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
    }
    for arch, (lo, hi) in approx.items():
        n = param_count(lm.model_specs(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"

"""``ops.linear`` is the run-time face of the paper's "automatically
replace all linear layers" feature: callers hand it whatever leaf the
conversion produced and must land on the right kernel.  This pins the
dispatch table — dense jax.Array, bf16 block-sparse, int8 block-sparse,
nibble-packed int4 — and the numerics of each route."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_mask, pack
from repro.core.quant import quantize_weight_int4, quantize_weight_int8
from repro.core.sparse_format import BlockSparseWeight, pack_nibbles
from repro.kernels import ops

K, N = 64, 128
BLOCK = (32, 128)


@pytest.fixture
def xw():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    return x, w


@pytest.fixture
def routes(monkeypatch):
    """Record which matmul entry point ops.linear picks per call."""
    calls = []
    for name in ("dense_matmul", "sparse_matmul", "sparse_matmul_int8"):
        orig = getattr(ops, name)

        def wrapper(*a, _name=name, _orig=orig, **kw):
            calls.append(_name)
            return _orig(*a, **kw)

        monkeypatch.setattr(ops, name, wrapper)
    return calls


def _sparse_bf16(w, sparsity=0.0):
    mask = make_mask(w, sparsity, policy="balanced", block=BLOCK)
    return mask, pack(jnp.where(mask, w, 0).astype(jnp.bfloat16), mask, BLOCK)


def _sparse_int8(w, sparsity=0.5):
    mask = make_mask(w, sparsity, policy="balanced", block=BLOCK)
    q, scale = quantize_weight_int8(jnp.where(mask, w, 0))
    return mask, pack(q, mask, BLOCK, scale=scale)


def _sparse_int4(w, sparsity=0.5):
    mask = make_mask(w, sparsity, policy="balanced", block=BLOCK)
    q, scale = quantize_weight_int4(jnp.where(mask, w, 0))
    sw = pack(q, mask, BLOCK, scale=scale)
    return mask, BlockSparseWeight(sw.bitmap, pack_nibbles(sw.values),
                                   sw.scale, sw.shape, sw.block,
                                   packed4=True)


def test_linear_dense_route(xw, routes):
    x, w = xw
    out = ops.linear(x, w)
    assert routes == ["dense_matmul"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               rtol=1e-5, atol=1e-5)


def test_linear_bf16_sparse_route(xw, routes):
    x, w = xw
    mask, sw = _sparse_bf16(w, sparsity=0.0)
    assert not sw.packed4 and sw.values.dtype == jnp.bfloat16
    out = ops.linear(x, sw, out_dtype=jnp.float32)
    assert routes == ["sparse_matmul"]
    expect = x @ jnp.where(mask, w, 0).astype(jnp.bfloat16).astype(
        jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-2, atol=1e-2)


def test_linear_int8_sparse_route(xw, routes):
    x, w = xw
    mask, sw = _sparse_int8(w)
    assert sw.values.dtype == jnp.int8 and not sw.packed4
    out = ops.linear(x, sw, out_dtype=jnp.float32)
    assert routes == ["sparse_matmul_int8"]
    expect = np.asarray(x @ jnp.where(mask, w, 0))
    got = np.asarray(out)
    rel = np.abs(got - expect).mean() / (np.abs(expect).mean() + 1e-9)
    assert rel < 0.05, rel


def test_linear_packed4_route(xw, routes):
    x, w = xw
    mask, sw = _sparse_int4(w)
    assert sw.packed4 and sw.values.dtype == jnp.uint8
    out = ops.linear(x, sw, out_dtype=jnp.float32)
    assert routes == ["sparse_matmul_int8"]        # int4 rides the int8 path
    expect = np.asarray(x @ jnp.where(mask, w, 0))
    got = np.asarray(out)
    rel = np.abs(got - expect).mean() / (np.abs(expect).mean() + 1e-9)
    assert rel < 0.15, rel


def test_linear_one_route_per_leaf_type(xw, routes):
    """The dispatch is exhaustive and exclusive: every leaf type takes
    exactly one route per call."""
    x, w = xw
    leaves = [w, _sparse_bf16(w, 0.5)[1], _sparse_int8(w)[1],
              _sparse_int4(w)[1]]
    for leaf in leaves:
        ops.linear(x, leaf)
    assert routes == ["dense_matmul", "sparse_matmul",
                      "sparse_matmul_int8", "sparse_matmul_int8"]

"""Multi-pod dry-run smoke: one (arch x shape) cell lowers + compiles on
the production meshes inside a 512-host-device subprocess, and the roofline
pipeline consumes the artifacts."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_dryrun(args, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-2000:] + out.stdout[-2000:]
    return out.stdout


@pytest.mark.slow
def test_single_pod_cell(tmp_path):
    out = run_dryrun(["--arch", "qwen3-0.6b", "--shape", "decode_32k",
                      "--out", str(tmp_path)])
    rec = json.load(open(os.path.join(
        str(tmp_path), "qwen3-0.6b_decode_32k_16x16_paper.json")))
    assert rec["n_devices"] == 256
    assert rec["flops"] > 0 and rec["bytes_accessed"] > 0
    assert rec["memory"]["argument_size_in_bytes"] < 16e9   # fits HBM
    assert rec["collective_bytes"]["total"] > 0


@pytest.mark.slow
def test_multi_pod_cell(tmp_path):
    out = run_dryrun(["--arch", "qwen3-0.6b", "--shape", "decode_32k",
                      "--multipod", "--out", str(tmp_path)])
    rec = json.load(open(os.path.join(
        str(tmp_path), "qwen3-0.6b_decode_32k_2x16x16_paper.json")))
    assert rec["n_devices"] == 512


def test_roofline_pipeline_on_recorded_artifacts():
    """The committed sweep artifacts combine into a full table."""
    dr = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(dr):
        pytest.skip("no recorded sweep artifacts")
    sys.path.insert(0, ROOT)
    from benchmarks.roofline import table
    t = table(dryrun_dir=dr,
              probe_dir=os.path.join(ROOT, "experiments", "probes"))
    assert "deepseek-67b" in t and "long_500k" in t
    assert "(missing)" not in t

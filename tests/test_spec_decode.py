"""Speculative decoding: draft–verify multi-token ticks on the pooled
serving engine.

The acceptance bar for the subsystem:

* the fused kernel's query-panel extension (interpret mode) matches the
  concat-free panel oracle AND a per-query sweep of the single-query
  fused oracle (query ``j`` == one decode tick at ``tail_len + j``)
  across the pooled edge grid, with poisoned out-of-range storage;
* ``CachePool.rollback`` is the exact inverse of ``append_many`` on the
  observable (length-gated) state, never crosses the frozen-prefix
  boundary, and composes with refreeze (property tests, hypothesis-gated
  like tests/test_sparse_format.py);
* with ``SpecConfig(k>0)``, greedy ``ContinuousEngine`` outputs are
  token-identical to the spec-disabled engine across a staggered
  mixed-prompt wave — including slots that never get a draft hit — with
  ZERO retraces of the verify/decode steps across accept lengths 0..K
  (asserted via ``trace_counts()``);
* acceptance semantics: greedy lanes accept by exact match; sampled lanes
  leave the output distribution unchanged (rejection sampling against the
  lane's masked distribution); stop sequences crossed mid-window truncate
  the commit.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # container ships without hypothesis
    class _St:
        def integers(self, *a, **k): return None
        def lists(self, *a, **k): return None
    st = _St()

    def settings(**_kw):
        return lambda fn: fn

    def given(**_kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def wrapper():
                pass
            wrapper.__name__ = fn.__name__
            return wrapper
        return deco

from repro.configs import get_config
from repro.core.sparse_kv import freeze_chunk_blocks, pooled_view
from repro.kernels import ops, ref
from repro.models import lm
from repro.serving import (CachePool, ContinuousEngine, NGramDrafter,
                           SamplingParams, Scheduler, SpecConfig,
                           stable_trace_counts)
from repro.serving import sampling


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# kernel: query-panel extension of the fused prefix+tail flash decode
# ---------------------------------------------------------------------------

def _pooled_case(b=4, hkv=2, g=2, d=32, sb=4, bs=16, t=16, qn=3,
                 ks=0.3, vs=0.5, seed=0):
    k = _rand((b, hkv, sb * bs, d), seed)
    v = _rand((b, hkv, sb * bs, d), seed + 1)
    cap = bs * d
    k_bm, k_vl, v_bm, v_vl = freeze_chunk_blocks(k, v, ks, vs, bs, cap, cap)
    k_sp = pooled_view(k_bm, k_vl, bs, d)
    v_sp = pooled_view(v_bm, v_vl, bs, d)
    k_tail = _rand((b, hkv, t, d), seed + 2)
    v_tail = _rand((b, hkv, t, d), seed + 3)
    q = _rand((b, qn, hkv * g, d), seed + 4)
    return q, k_sp, v_sp, k_tail, v_tail


PANEL_GRID = [
    # (prefix_blocks per slot, tail_len visible to panel query 0)  b=4
    pytest.param([4, 4, 4, 4], [1, 1, 1, 1], id="fresh_tail"),
    pytest.param([4, 4, 4, 4], [14, 14, 14, 14], id="near_full_tail"),
    pytest.param([0, 0, 0, 0], [1, 5, 9, 13], id="empty_prefix"),
    pytest.param([0, 4, 2, 1], [1, 3, 14, 7], id="mixed_lengths"),
]


@pytest.mark.parametrize("prefix_blocks,tail_len", PANEL_GRID)
@pytest.mark.parametrize("qn", [1, 3])
def test_panel_kernel_matches_per_query_oracle(prefix_blocks, tail_len, qn):
    """The [B, Q, Hq, D] panel through the fused kernel == the panel ref
    == Q independent single-query fused calls at tail_len + j (the verify
    step's intra-window causal semantics).  Out-of-range tail entries are
    poisoned so masking leaks break parity loudly."""
    bs, d, hkv, g, t = 16, 32, 2, 2, 16
    q, k_sp, v_sp, k_tail, v_tail = _pooled_case(bs=bs, d=d, hkv=hkv, g=g,
                                                 t=t, qn=qn)
    tl = jnp.asarray(tail_len, jnp.int32)
    pl_ = jnp.asarray(prefix_blocks, jnp.int32) * bs
    # poison beyond the LAST panel query's visibility (earlier queries'
    # masks are then checked against the per-query oracle)
    dead = jnp.arange(t)[None, None, :, None] >= \
        (tl + qn - 1)[:, None, None, None]
    k_tail = jnp.where(dead, 50.0, k_tail)
    v_tail = jnp.where(dead, 50.0, v_tail)
    sm = 1.0 / d ** 0.5

    with ops.backend("interpret"):
        o_kernel = ops.sparse_decode_attention(
            q, k_sp, v_sp, hkv, sm, k_tail, v_tail, tl, prefix_len=pl_)
    o_ref = ref.sparse_decode_attention_panel_ref(
        q, k_sp, v_sp, sm, k_tail, v_tail, tl, pl_)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    for j in range(qn):
        o_j = ref.sparse_decode_attention_fused_ref(
            q[:, j], k_sp, v_sp, sm, k_tail, v_tail, tl + j, pl_)
        np.testing.assert_allclose(np.asarray(o_kernel[:, j]),
                                   np.asarray(o_j), rtol=1e-4, atol=1e-4)


def test_panel_single_query_reduces_to_fused():
    """A [B, 1, Hq, D] panel must equal the plain 3-D fused dispatch
    BIT FOR BIT on every backend — the ops layer squeezes Q == 1 panels
    onto the single-query path, which is what lets the unified panel
    forward serve plain decode without perturbing greedy outputs."""
    bs, d, hkv = 16, 32, 2
    q, k_sp, v_sp, k_tail, v_tail = _pooled_case(bs=bs, d=d, hkv=hkv, qn=1)
    tl = jnp.asarray([0, 1, 9, 16], jnp.int32)
    sm = 1.0 / d ** 0.5
    for backend in ("interpret", "xla"):
        with ops.backend(backend):
            o_panel = ops.sparse_decode_attention(
                q, k_sp, v_sp, hkv, sm, k_tail, v_tail, tl)
            o_single = ops.sparse_decode_attention(
                q[:, 0], k_sp, v_sp, hkv, sm, k_tail, v_tail, tl)
        np.testing.assert_array_equal(np.asarray(o_panel[:, 0]),
                                      np.asarray(o_single),
                                      err_msg=backend)


def test_panel_forward_q1_sequential_parity():
    """The unified forward's Q == 1 guarantee at the model level: a
    [B, 3] teacher-forced panel scores exactly what three sequential
    Q == 1 decode ticks (the plain serving path) produce, position by
    position — decode really is the 1-wide instance of the one panel
    forward."""
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=16, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    pool = CachePool.build(cfg, slots=2, max_tokens=64, bs=16)
    rng = np.random.default_rng(7)
    p = lm.period_len(cfg)
    shape = (cfg.n_layers // p, 2, cfg.n_kv, 5, cfg.hd)
    panels = {f"l{j}": {"k": jnp.asarray(rng.normal(size=shape), cfg.cdtype),
                        "v": jnp.asarray(rng.normal(size=shape), cfg.cdtype)}
              for j in range(p)}
    state = pool.append_many(pool.init_state(), panels,
                             jnp.asarray([5, 3], jnp.int32))
    from repro.distributed import NULL_CTX
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 3)), jnp.int32)
    mask = jnp.ones((2,), bool)

    logits_panel, _ = lm.forward_panel_pooled(
        params, state, toks, mask, cfg, NULL_CTX, pool.bs)
    st = state
    for j in range(3):
        logits_j, st = lm.forward_panel_pooled(
            params, st, toks[:, j:j + 1], mask, cfg, NULL_CTX, pool.bs)
        np.testing.assert_allclose(np.asarray(logits_panel[:, j]),
                                   np.asarray(logits_j[:, 0]),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# acceptance (sampling.accept_step)
# ---------------------------------------------------------------------------

def _lanes(temps, seed=0):
    b = len(temps)
    lanes = sampling.init_lanes(b)
    lanes["temperature"] = jnp.asarray(temps, jnp.float32)
    lanes["rng"] = jnp.stack([jax.random.PRNGKey(seed + i)
                              for i in range(b)])
    return lanes


def test_accept_step_greedy_exact_match():
    """Greedy lanes accept drafts exactly while they match argmax, commit
    the correction after the first miss, and ignore padding lanes."""
    v, qn = 11, 4
    # logits: position j's argmax is j+1 -> the "model" continues 1,2,3,4
    logits = np.full((3, qn, v), -10.0, np.float32)
    for j in range(qn):
        logits[:, j, j + 1] = 10.0
    panel = np.zeros((3, qn), np.int32)
    panel[0] = [0, 1, 2, 99]       # 2 good drafts, third wrong
    panel[1] = [0, 1, 2, 3]        # all 3 drafts right
    panel[2] = [0, 9, 9, 9]        # draft lanes invalid (draft_len 0)
    dlen = jnp.asarray([3, 3, 0], jnp.int32)
    tok, logp, nc, _ = sampling.accept_step(
        jnp.asarray(logits), jnp.asarray(panel), dlen,
        _lanes([0.0, 0.0, 0.0]), jnp.ones((3,), bool))
    tok, nc = np.asarray(tok), np.asarray(nc)
    assert nc.tolist() == [3, 4, 1]
    assert tok[0, :3].tolist() == [1, 2, 3]    # 2 accepted + correction
    assert tok[1].tolist() == [1, 2, 3, 4]     # 3 accepted + bonus
    assert tok[2, 0] == 1                      # no drafts: plain argmax
    # logprobs are the unmodified log-softmax of the committed tokens
    lp = jax.nn.log_softmax(jnp.asarray(logits[0, 0]))[1]
    np.testing.assert_allclose(np.asarray(logp)[0, 0], float(lp), rtol=1e-6)


def test_accept_step_masked_slot_commits_nothing():
    logits = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 3, 7)).astype(np.float32))
    panel = jnp.zeros((2, 3), jnp.int32)
    lanes = _lanes([0.0, 0.7])
    live = jnp.asarray([True, False])
    _, _, nc, out_lanes = sampling.accept_step(
        logits, panel, jnp.asarray([2, 2], jnp.int32), lanes, live)
    assert np.asarray(nc).tolist()[1] == 0
    # dead lane's RNG key must not advance
    np.testing.assert_array_equal(np.asarray(out_lanes["rng"])[1],
                                  np.asarray(lanes["rng"])[1])


def test_accept_step_rejection_preserves_distribution():
    """Sampled lanes: accepted-or-resampled output of a point-mass drafter
    must match the target categorical distribution (the standard
    speculative-sampling identity), and a rejection never re-emits the
    rejected draft when its probability is 0.  One batched call: every
    lane is an independent seeded trial."""
    v, n = 4, 600
    probs = np.asarray([0.5, 0.3, 0.2, 0.0], np.float32)
    logits = np.log(np.maximum(probs, 1e-9))
    draft = 3                                   # p(draft) = 0: always reject
    lg = jnp.broadcast_to(jnp.asarray(logits), (n, 2, v))
    panel = jnp.broadcast_to(jnp.asarray([0, draft], jnp.int32), (n, 2))
    tok, _, nc, _ = sampling.accept_step(
        lg, panel, jnp.full((n,), 1, jnp.int32), _lanes([1.0] * n),
        jnp.ones((n,), bool))
    assert np.asarray(nc).tolist() == [1] * n   # always rejected
    first = np.asarray(tok)[:, 0]
    counts = np.bincount(first, minlength=v)
    assert counts[draft] == 0                   # residual excludes draft
    np.testing.assert_allclose(counts[:3] / n, probs[:3] / probs[:3].sum(),
                               atol=0.07)


def test_accept_step_certain_draft_always_accepted():
    """A draft with probability ~1 under the lane's distribution must be
    accepted (rejection sampling accepts with prob p(d))."""
    v, qn = 5, 3
    logits = np.full((1, qn, v), -30.0, np.float32)
    logits[:, :, 2] = 30.0                      # point mass at token 2
    panel = jnp.asarray([[2, 2, 2]], jnp.int32)
    tok, _, nc, _ = sampling.accept_step(
        jnp.asarray(logits), panel, jnp.asarray([2], jnp.int32),
        _lanes([0.9]), jnp.ones((1,), bool))
    assert int(np.asarray(nc)[0]) == 3          # 2 accepts + bonus
    assert np.asarray(tok)[0].tolist() == [2, 2, 2]


# ---------------------------------------------------------------------------
# CachePool: append_many / rollback / refreeze interaction
# ---------------------------------------------------------------------------

def _pool_setup(slots=2, kv_tail=16, bs=16):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=kv_tail)
    pool = CachePool.build(cfg, slots=slots, max_tokens=64, bs=bs)
    return cfg, pool


def _panels(pool, cfg, m, seed=0):
    rng = np.random.default_rng(seed)
    p = lm.period_len(cfg)
    n_periods = cfg.n_layers // p
    shape = (n_periods, pool.slots, cfg.n_kv, m, cfg.hd)
    return {f"l{j}": {"k": jnp.asarray(rng.normal(size=shape),
                                       cfg.cdtype),
                      "v": jnp.asarray(rng.normal(size=shape),
                                       cfg.cdtype)}
            for j in range(p)}


def _visible(state, pool):
    """The observable (length-gated) pool state: lengths + valid tail
    region + full prefix storage."""
    vis = {"pos": np.asarray(state["pos"]),
           "prefix_blocks": np.asarray(state["prefix_blocks"]),
           "tail_len": np.asarray(state["tail_len"])}
    tl = vis["tail_len"]
    for name, leaf in state["layers"].items():
        kv = leaf["kv"]
        live = (np.arange(pool.tail)[None, None, None, :, None]
                < tl[None, :, None, None, None])
        for key in ("k_tail", "v_tail"):
            vis[f"{name}/{key}"] = np.where(live, np.asarray(kv[key]), 0)
        for key in ("k_bitmap", "k_values", "v_bitmap", "v_values"):
            vis[f"{name}/{key}"] = np.asarray(kv[key])
    return vis


def _assert_state_equal(a, b):
    assert a.keys() == b.keys()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


def test_rollback_is_exact_inverse_of_append():
    cfg, pool = _pool_setup()
    state = pool.init_state()
    state["tail_len"] = jnp.asarray([3, 7], jnp.int32)
    state["pos"] = jnp.asarray([3, 7], jnp.int32)
    before = _visible(state, pool)
    n = jnp.asarray([4, 2], jnp.int32)
    appended = pool.append_many(state, _panels(pool, cfg, 4, seed=1), n)
    assert np.asarray(appended["tail_len"]).tolist() == [7, 9]
    assert np.asarray(appended["pos"]).tolist() == [7, 9]
    back = pool.rollback(appended, n)
    _assert_state_equal(_visible(back, pool), before)


def test_rollback_clamps_at_frozen_prefix_boundary():
    """Rolling back more than the tail holds must stop at the boundary —
    the frozen prefix (and pos accounting for it) is untouchable."""
    cfg, pool = _pool_setup()
    state = pool.init_state()
    state["prefix_blocks"] = jnp.asarray([1, 0], jnp.int32)
    state["tail_len"] = jnp.asarray([2, 5], jnp.int32)
    state["pos"] = jnp.asarray([18, 5], jnp.int32)   # 16 frozen + 2 tail
    out = jax.jit(pool.rollback)(state, jnp.asarray([100, 3], jnp.int32))
    assert np.asarray(out["tail_len"]).tolist() == [0, 2]
    assert np.asarray(out["pos"]).tolist() == [16, 2]
    assert np.asarray(out["prefix_blocks"]).tolist() == [1, 0]


def test_refreeze_after_partial_rollback_roundtrips():
    """append to full -> partial rollback -> re-append -> refreeze must
    fold exactly the surviving tail (bitmap and values consistent), as if
    the rolled-back tokens never existed."""
    cfg, pool = _pool_setup()
    t = pool.tail
    panels = _panels(pool, cfg, t, seed=2)
    repl = _panels(pool, cfg, t, seed=3)

    # path A: fill the tail, roll 5 back, re-append 5 replacement tokens
    st = pool.append_many(pool.init_state(), panels, t)
    st = pool.rollback(st, 5)
    tail5 = {name: {"k": p["k"][:, :, :, :5], "v": p["v"][:, :, :, :5]}
             for name, p in repl.items()}
    st = pool.append_many(st, tail5, 5)
    out_a = jax.jit(pool.refreeze)(st)

    # path B: the same surviving tokens appended directly
    direct = {name: {
        "k": jnp.concatenate([panels[name]["k"][:, :, :, :t - 5],
                              repl[name]["k"][:, :, :, :5]], axis=3),
        "v": jnp.concatenate([panels[name]["v"][:, :, :, :t - 5],
                              repl[name]["v"][:, :, :, :5]], axis=3)}
        for name in panels}
    out_b = jax.jit(pool.refreeze)(pool.append_many(pool.init_state(),
                                                    direct, t))
    _assert_state_equal(_visible(out_a, pool), _visible(out_b, pool))
    assert np.asarray(out_a["tail_len"]).tolist() == [0, 0]
    assert np.asarray(out_a["prefix_blocks"]).tolist() == [1, 1]


@settings(max_examples=20, deadline=None)
@given(tl0=st.integers(min_value=0, max_value=10),
       m=st.integers(min_value=1, max_value=6),
       n=st.integers(min_value=0, max_value=6),
       roll=st.integers(min_value=0, max_value=20))
def test_append_rollback_property(tl0, m, n, roll):
    """For any starting fill, append width, valid count n <= m and
    rollback <= n: rollback(append(n), n) is the identity on observable
    state, and rollback never drives lengths below the pre-append fill
    (frozen-prefix boundary)."""
    n = min(n, m)
    cfg, pool = _pool_setup()
    state = pool.init_state()
    state["tail_len"] = jnp.asarray([tl0, 0], jnp.int32)
    state["pos"] = jnp.asarray([tl0, 0], jnp.int32)
    before = _visible(state, pool)
    appended = pool.append_many(state, _panels(pool, cfg, m, seed=tl0 + m),
                                jnp.asarray([n, 0], jnp.int32))
    assert np.asarray(appended["tail_len"])[0] == tl0 + n
    if roll <= n:
        back = pool.rollback(appended, jnp.asarray([roll, 0], jnp.int32))
        assert np.asarray(back["tail_len"])[0] == tl0 + n - roll
        if roll == n:
            _assert_state_equal(_visible(back, pool), before)
    # unconditional: a huge rollback clamps at zero fill, never negative
    huge = pool.rollback(appended, 1000)
    assert np.asarray(huge["tail_len"]).min() >= 0
    assert np.asarray(huge["pos"]).min() >= 0


# ---------------------------------------------------------------------------
# scheduler: multi-token commits with in-window stop scanning
# ---------------------------------------------------------------------------

def test_record_tokens_stop_inside_window_truncates():
    sch = Scheduler(slots=1, capacity_tokens=128, bs=16)
    rid = sch.submit([1, 2], SamplingParams(max_new_tokens=32, eos_id=42))
    req = sch.admit()
    assert sch.record_tokens(req.slot, [7, 8], [-0.1, -0.2]) is None
    # eos mid-window: the commit truncates AT the stop token
    assert sch.record_tokens(req.slot, [9, 42, 77, 78]) == "stop"
    assert sch.finished[rid].generated == [7, 8, 9, 42]
    assert sch.finished[rid].logprobs == [-0.1, -0.2, None, None]


def test_record_tokens_stop_sequence_crossing_window_boundary():
    """A stop sequence whose tokens span two commits must still fire."""
    sch = Scheduler(slots=1, capacity_tokens=128, bs=16)
    rid = sch.submit([1], SamplingParams(max_new_tokens=32,
                                         stop_ids=((5, 6),)))
    req = sch.admit()
    assert sch.record_tokens(req.slot, [4, 5]) is None
    assert sch.record_tokens(req.slot, [6, 9]) == "stop"
    assert sch.finished[rid].generated == [4, 5, 6]


def test_record_tokens_length_mid_window_and_metrics():
    sch = Scheduler(slots=1, capacity_tokens=128, bs=16)
    rid = sch.submit([1], SamplingParams(max_new_tokens=4))
    req = sch.admit()
    sch.record_tokens(req.slot, [10], decode_tick=False)   # prefill token
    assert sch.record_tokens(req.slot, [11, 12, 13, 99]) == "length"
    out = sch.finished[rid].output()
    assert out.token_ids == (10, 11, 12, 13)               # budget trims
    assert out.metrics.decode_ticks == 1
    assert out.metrics.num_generated == 4
    assert out.metrics.accepted_per_tick == 3.0            # 3 decode tokens


# ---------------------------------------------------------------------------
# drafter
# ---------------------------------------------------------------------------

def test_ngram_drafter_prompt_lookup():
    d = NGramDrafter(max_ngram=3, min_ngram=1)
    # longest suffix [2, 3] recurs -> continue from the most recent match
    assert d.propose([1, 2, 3, 9, 2, 3, 4, 2, 3], 3) == [4, 2, 3]
    assert d.propose([1, 2, 3], 4) == []         # no earlier recurrence
    assert d.propose([], 4) == []
    assert d.propose([7, 7], 2) == [7]           # 1-gram, truncated by end
    assert d.propose([1, 2], 0) == []


# ---------------------------------------------------------------------------
# engine: greedy token identity + zero retraces (the acceptance criterion)
# ---------------------------------------------------------------------------

def _setup(seed=0, b=2, s=16, kv_tail=16):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=kv_tail, compute_dtype="float32",
                              param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jnp.asarray(np.random.default_rng(seed).integers(
        0, cfg.vocab, (b, s)), jnp.int32)
    return cfg, params, toks


def _staggered_wave(eng, toks, loopy):
    """3 requests through 2 slots: admissions + evictions, unaligned
    prompts, one strongly loopy prompt (draft hits) and random ones
    (slots that may never get a draft hit)."""
    rids = [eng.submit(loopy, SamplingParams(max_new_tokens=18))]
    rids += [eng.submit(toks[i % 2][:9 + 4 * i],
                        SamplingParams(max_new_tokens=16 - 2 * i))
             for i in range(2)]
    res = eng.run()
    return [res[r].token_ids for r in rids], res


def test_spec_greedy_token_identity_and_zero_retraces():
    """SpecConfig(k=3): greedy outputs token-identical to the spec-off
    engine across a lockstep wave AND a staggered mixed-prompt wave, with
    the verify step compiled exactly once across accept lengths 0..K."""
    cfg, params, toks = _setup()
    sp = SamplingParams(max_new_tokens=24)       # > kv_tail: refreezes
    loopy = [3, 4, 5] * 5                        # n-gram paradise

    base = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16)
    out_base = base.generate_batch(toks, sp)
    wave_base, _ = _staggered_wave(base, toks, loopy)

    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           spec=SpecConfig(k=3))
    out_spec = eng.generate_batch(toks, sp)
    warm = eng.trace_counts()
    assert warm["verify"] == 1 and warm["decode"] == 0
    wave_spec, res = _staggered_wave(eng, toks, loopy)
    after = eng.trace_counts()
    assert (stable_trace_counts(after) == stable_trace_counts(warm)
            and after["verify"] == 1), \
        f"verify retraced: {warm} -> {after}"

    np.testing.assert_array_equal(np.asarray(out_spec), np.asarray(out_base))
    assert wave_spec == wave_base
    # accept lengths 0..K all exercised: padded lanes (no hit) and full
    # accepts both occur on this wave
    assert eng.spec_hist[0] > 0 and eng.spec_hist[1:].sum() > 0
    apt = [o.metrics.accepted_per_tick for o in res.values()]
    assert all(a is not None and a >= 1.0 for a in apt)


def test_spec_interpret_mode_parity():
    """The verify panel through the actual Pallas kernel (interpret mode)
    stays token-identical to the spec-off engine on the same backend —
    the CI spec-parity bar."""
    cfg, params, toks = _setup(s=12, kv_tail=16)
    sp = SamplingParams(max_new_tokens=10)
    with ops.backend("interpret"):
        base = ContinuousEngine(params, cfg, slots=2, max_tokens=64, bs=16)
        out_base = base.generate_batch(toks, sp)
        eng = ContinuousEngine(params, cfg, slots=2, max_tokens=64, bs=16,
                               spec=SpecConfig(k=2))
        out_spec = eng.generate_batch(toks, sp)
        assert eng.trace_counts()["verify"] == 1
    np.testing.assert_array_equal(np.asarray(out_spec), np.asarray(out_base))


def test_adaptive_k_token_identity_and_histogram():
    """SpecConfig(adaptive=True): per-slot draft windows scale with each
    slot's acceptance rate on the host side only — greedy outputs stay
    token-identical to both the fixed-K and the spec-off engines, the
    verify panel never retraces, and the adaptive histogram records the
    per-tick proposals (backing off on draft-hostile streams)."""
    cfg, params, toks = _setup()
    sp = SamplingParams(max_new_tokens=24)
    base = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16)
    out_base = base.generate_batch(toks, sp)

    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           spec=SpecConfig(k=3, adaptive=True))
    out = eng.generate_batch(toks, sp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_base))
    assert eng.trace_counts()["verify"] == 1
    hist = eng.adaptive_hist
    assert hist is not None and hist.sum() == eng.spec_hist.sum()
    # random prompts are drafter-hostile: the controller must have backed
    # off below full k on at least some ticks (unlike the fixed-K engine,
    # whose proposals are always k whenever an n-gram hits)
    fixed = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                             spec=SpecConfig(k=3))
    out_fixed = fixed.generate_batch(toks, sp)
    np.testing.assert_array_equal(np.asarray(out_fixed), np.asarray(out_base))
    assert fixed.adaptive_hist is None


def test_adaptive_draft_controller_units():
    """AdaptiveDraft: optimistic start, EMA convergence toward the
    observed acceptance rate, floor at adapt_min_k, reset-on-release."""
    from repro.serving import AdaptiveDraft
    ad = AdaptiveDraft(SpecConfig(k=4, adaptive=True, adapt_decay=0.5,
                                  adapt_min_k=1))
    assert ad.draft_len(0) == 4                 # no evidence: probe at k
    ad.update(0, proposed=4, accepted=0)
    assert ad.draft_len(0) == 1                 # full rejection -> floor
    for _ in range(6):
        ad.update(0, proposed=4, accepted=4)
    assert ad.draft_len(0) == 4                 # accepts recover full depth
    ad.update(1, proposed=0, accepted=0)        # no proposal: no evidence
    assert ad.draft_len(1) == 4
    ad.reset(0)
    assert ad.draft_len(0) == 4                 # fresh tenant starts clean
    assert ad.hist.sum() == 8 and ad.hist[0] == 1


def test_spec_sampled_lanes_run_and_respect_budget():
    """Sampled lanes under speculation: the engine must run retrace-free
    with mixed greedy+sampled lanes and honor stop/length inside accepted
    windows (distribution-level checks live in the accept_step tests)."""
    cfg, params, toks = _setup()
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                           spec=SpecConfig(k=3))
    loopy = [2, 9] * 6
    r1 = eng.submit(loopy, SamplingParams(temperature=0.8, top_k=8,
                                          seed=7, max_new_tokens=11))
    r2 = eng.submit(toks[0], SamplingParams(max_new_tokens=9,
                                            stop_ids=((3, 4),)))
    res = eng.run()
    assert len(res[r1].token_ids) == 11 or res[r1].finish_reason == "stop"
    assert res[r2].finish_reason in ("stop", "length")
    assert len(res[r2].token_ids) <= 9
    assert eng.trace_counts()["verify"] == 1
    # seeded sampled stream is reproducible tick-for-tick
    eng2 = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16,
                            spec=SpecConfig(k=3))
    r1b = eng2.submit(loopy, SamplingParams(temperature=0.8, top_k=8,
                                            seed=7, max_new_tokens=11))
    assert res[r1].token_ids == eng2.run()[r1b].token_ids

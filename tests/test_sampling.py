"""Request-level serving API: SamplingParams, per-slot on-device sampling
lanes, streaming outputs, and the scheduler's finish-reason contract.

Acceptance bars pinned here:
* ``SamplingParams(temperature=0)`` through ``ContinuousEngine`` is
  token-identical to the greedy legacy engine (the equivalence suite in
  test_serving_pool covers the greedy path; here the *sampled* lanes);
* a mixed-params batch — greedy + temperature/top-k/top-p slots in one
  pool — completes with ``trace_counts()`` flat after warmup;
* same request, different slot => same tokens (seeded lanes are
  slot-independent);
* stop sequences beat max_new_tokens when both trigger on the same token.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import (ContinuousEngine, Engine, SamplingParams,
                           Scheduler, sampling)

VOCAB = 64


# ---------------------------------------------------------------------------
# the sampler (pure unit tests)
# ---------------------------------------------------------------------------

def _lanes(temps, top_ks=None, top_ps=None, seeds=None):
    b = len(temps)
    lanes = sampling.init_lanes(b)
    lanes["temperature"] = jnp.asarray(temps, jnp.float32)
    if top_ks is not None:
        lanes["top_k"] = jnp.asarray(top_ks, jnp.int32)
    if top_ps is not None:
        lanes["top_p"] = jnp.asarray(top_ps, jnp.float32)
    keys = [jax.random.PRNGKey(s) for s in (seeds or range(b))]
    lanes["rng"] = jnp.stack(keys)
    return lanes


def _draws(logits, lanes, n):
    """n successive sample_step draws (the lane RNG advances in between)."""
    toks = []
    adv = jnp.ones((logits.shape[0],), bool)
    for _ in range(n):
        tok, _, lanes = sampling.sample_step(logits, lanes, adv)
        toks.append(np.asarray(tok))
    return np.stack(toks)                                  # [n, B]


def test_temperature0_is_exact_argmax():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, VOCAB)).astype(np.float32))
    tok, _, _ = sampling.sample_step(logits, _lanes([0.0] * 4),
                                     jnp.ones((4,), bool))
    np.testing.assert_array_equal(
        np.asarray(tok), np.asarray(jnp.argmax(logits, -1)))
    assert tok.dtype == jnp.int32


def test_top_k1_is_argmax_at_any_temperature():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(2, VOCAB)).astype(np.float32))
    draws = _draws(logits, _lanes([5.0, 0.7], top_ks=[1, 1]), 20)
    np.testing.assert_array_equal(
        draws, np.tile(np.asarray(jnp.argmax(logits, -1)), (20, 1)))


def test_top_k_restricts_support():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(1, VOCAB)).astype(np.float32))
    top3 = set(np.asarray(jnp.argsort(-logits[0]))[:3].tolist())
    draws = _draws(logits, _lanes([1.5], top_ks=[3]), 200).ravel()
    assert set(draws.tolist()) <= top3
    assert len(set(draws.tolist())) > 1            # it does sample, not argmax


def test_top_p_restricts_support():
    probs = np.full(8, 1e-6)
    probs[:4] = [0.5, 0.3, 0.1, 0.1 - 6e-6 + 2e-6]
    logits = jnp.log(jnp.asarray(probs, jnp.float32))[None, :]
    # nucleus at 0.6: token 0 (mass before it 0) and token 1 (0.5) are in,
    # token 2 (0.8) is out
    draws = _draws(logits, _lanes([1.0], top_ps=[0.6]), 200).ravel()
    assert set(draws.tolist()) <= {0, 1}
    assert set(draws.tolist()) == {0, 1}


def test_seeded_determinism_and_seed_variation():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(1, VOCAB)).astype(np.float32))
    a = _draws(logits, _lanes([1.0], seeds=[7]), 50)
    b = _draws(logits, _lanes([1.0], seeds=[7]), 50)
    c = _draws(logits, _lanes([1.0], seeds=[8]), 50)
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()


def test_mixed_lanes_one_batch():
    """A greedy lane and a sampled lane coexist in one sample_step call."""
    rng = np.random.default_rng(4)
    row = rng.normal(size=(VOCAB,)).astype(np.float32)
    logits = jnp.asarray(np.stack([row, row]))
    lanes = _lanes([0.0, 2.0], top_ks=[0, 4], seeds=[0, 1])
    draws = _draws(logits, lanes, 50)
    top4 = set(np.asarray(jnp.argsort(-logits[1]))[:4].tolist())
    assert (draws[:, 0] == int(jnp.argmax(logits[0]))).all()
    assert set(draws[:, 1].tolist()) <= top4
    assert len(set(draws[:, 1].tolist())) > 1


def test_masked_lanes_keep_their_key():
    """advance=False lanes must not consume RNG (a parked slot's stream
    may not depend on how long it sat parked)."""
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.normal(size=(2, VOCAB)).astype(np.float32))
    lanes = _lanes([1.0, 1.0], seeds=[3, 3])
    adv = jnp.asarray([True, False])
    _, _, lanes2 = sampling.sample_step(logits, lanes, adv)
    assert (np.asarray(lanes2["rng"][0]) != np.asarray(lanes["rng"][0])).any()
    np.testing.assert_array_equal(np.asarray(lanes2["rng"][1]),
                                  np.asarray(lanes["rng"][1]))


def test_bucketed_topp_matches_sorted_masker():
    """The sort-free (lax.top_k bucket) masker must produce the IDENTICAL
    mask — and therefore identical samples at equal seed — as the full-sort
    reference for every lane whose support fits the bucket."""
    rng = np.random.default_rng(7)
    v = sampling.TOPP_BUCKET * 4                   # force the bucketed path
    cases = [(1.0, 3, 0.9), (0.7, 8, 0.5), (1.3, 1, 1.0), (2.0, 64, 0.99),
             (0.9, 5, 1.0), (1.0, 0, 1.0)]        # (temp, top_k, top_p)
    logits = jnp.asarray(rng.normal(size=(len(cases), v)).astype(np.float32))
    temp = jnp.asarray([c[0] for c in cases], jnp.float32)
    top_k = jnp.asarray([c[1] for c in cases], jnp.int32)
    top_p = jnp.asarray([c[2] for c in cases], jnp.float32)
    scaled = logits / temp[:, None]
    m_sort = sampling._mask_logits_sorted(scaled, top_k, top_p)
    m_fast = sampling._mask_logits(logits, temp, top_k, top_p)
    np.testing.assert_array_equal(np.asarray(m_fast), np.asarray(m_sort))

    # identical samples at equal seed through sample_step on both maskers
    lanes = _lanes([c[0] for c in cases], top_ks=[c[1] for c in cases],
                   top_ps=[c[2] for c in cases], seeds=[11] * len(cases))
    draws_fast = _draws(logits, lanes, 25)
    orig = sampling._mask_logits
    sampling._mask_logits = \
        lambda lg, t, k, p, live=None: sampling._mask_logits_sorted(
            lg / jnp.maximum(t, 1e-6)[:, None], k, p)
    try:
        draws_sorted = _draws(logits, lanes, 25)
    finally:
        sampling._mask_logits = orig
    np.testing.assert_array_equal(draws_fast, draws_sorted)


def test_bucketed_topp_exact_fallback():
    """Lanes needing unbounded support (top_k == 0 with top_p < 1, or
    top_k > TOPP_BUCKET) must take the exact full-sort branch."""
    rng = np.random.default_rng(8)
    v = sampling.TOPP_BUCKET * 2
    logits = jnp.asarray(rng.normal(size=(2, v)).astype(np.float32))
    for top_k, top_p in ((0, 0.7), (sampling.TOPP_BUCKET + 9, 0.8)):
        tk = jnp.asarray([top_k, 3], jnp.int32)
        tp = jnp.asarray([top_p, 0.9], jnp.float32)
        temp = jnp.ones((2,), jnp.float32)
        m_sort = sampling._mask_logits_sorted(logits, tk, tp)
        m = sampling._mask_logits(logits, temp, tk, tp)
        np.testing.assert_array_equal(np.asarray(m), np.asarray(m_sort))


def test_stale_dead_lane_does_not_force_exact_sort():
    """A released slot keeps its lane params until the next admission; a
    parked exact-support lane (top_k=0, top_p<1) must NOT drag live lanes
    through the full-sort branch — the fallback decision is gated on
    ``live``."""
    rng = np.random.default_rng(10)
    v = sampling.TOPP_BUCKET * 2
    logits = jnp.asarray(rng.normal(size=(2, v)).astype(np.float32))
    tk = jnp.asarray([5, 0], jnp.int32)            # lane 1: stale, exact
    tp = jnp.asarray([0.9, 0.7], jnp.float32)
    temp = jnp.ones((2,), jnp.float32)
    live = jnp.asarray([True, False])
    m = sampling._mask_logits(logits, temp, tk, tp, live=live)
    m_bucket = sampling._mask_logits_bucketed(logits / temp[:, None],
                                              tk, tp, sampling.TOPP_BUCKET)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_bucket))
    # ...but a LIVE exact-support lane still gets the exact branch
    m2 = sampling._mask_logits(logits, temp, tk, tp,
                               live=jnp.asarray([True, True]))
    m2_sort = sampling._mask_logits_sorted(logits / temp[:, None], tk, tp)
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(m2_sort))


def test_sample_step_returns_chosen_logprob():
    """The logprob lane is log_softmax of the RAW logits at the chosen
    token — for greedy and sampled lanes alike."""
    rng = np.random.default_rng(9)
    logits = jnp.asarray(rng.normal(size=(3, VOCAB)).astype(np.float32))
    lanes = _lanes([0.0, 1.0, 2.5], top_ks=[0, 4, 0], seeds=[1, 2, 3])
    tok, logp, _ = sampling.sample_step(logits, lanes,
                                        jnp.ones((3,), bool))
    want = jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                               tok[:, None], axis=-1)[:, 0]
    np.testing.assert_allclose(np.asarray(logp), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    assert (np.asarray(logp) <= 0).all()


def test_params_validation():
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(max_new_tokens=0),
                dict(stop_ids=((),))):
        with pytest.raises(ValueError):
            SamplingParams(**bad)
    sp = SamplingParams(stop_ids=(5, (6, 7)))
    assert sp.stop_ids == ((5,), (6, 7))           # ints become 1-sequences
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.1).greedy


# ---------------------------------------------------------------------------
# scheduler edge cases (host-side, no model)
# ---------------------------------------------------------------------------

def test_admission_exactly_fills_capacity():
    sch = Scheduler(slots=1, capacity_tokens=64, bs=16)
    rid = sch.submit(list(range(48)), SamplingParams(max_new_tokens=16))
    req = sch.admit()
    assert req is not None and req.rid == rid      # 48 + 16 == 64 admits
    with pytest.raises(ValueError):                # one past capacity: never
        sch.submit(list(range(49)), SamplingParams(max_new_tokens=16))


def test_stop_sequence_beats_max_new_tokens():
    """A stop hit on the budget's very last token must report "stop"."""
    sch = Scheduler(slots=1, capacity_tokens=64, bs=16)
    rid = sch.submit([1, 2], SamplingParams(max_new_tokens=4,
                                            stop_ids=((7, 8),)))
    sch.admit()
    assert sch.record_token(0, 5) is None
    assert sch.record_token(0, 6) is None
    assert sch.record_token(0, 7) is None
    assert sch.record_token(0, 8) == "stop"        # token #4 = budget edge
    assert sch.finished[rid].finish_reason == "stop"
    assert sch.finished[rid].generated == [5, 6, 7, 8]


def test_stop_sequence_mid_stream_and_length_reason():
    sch = Scheduler(slots=2, capacity_tokens=64, bs=16)
    r1 = sch.submit([1], SamplingParams(max_new_tokens=8,
                                        stop_ids=(9, (3, 4))))
    r2 = sch.submit([1], SamplingParams(max_new_tokens=2))
    sch.admit(), sch.admit()
    assert sch.record_token(0, 3) is None
    assert sch.record_token(0, 4) == "stop"        # 2-token sequence match
    assert sch.record_token(1, 3) is None
    assert sch.record_token(1, 4) == "length"      # no stop_ids -> budget
    assert sch.finished[r1].finish_reason == "stop"
    assert sch.finished[r2].finish_reason == "length"
    # timing is populated monotonically
    m = sch.finished[r1]
    assert m.arrival_time <= m.first_token_time <= m.finished_time


def test_request_output_snapshot():
    sch = Scheduler(slots=1, capacity_tokens=64, bs=16)
    rid = sch.submit([1, 2], SamplingParams(max_new_tokens=2))
    req = sch.admit()
    sch.record_token(0, 5)
    out = req.output()
    assert (out.request_id, out.prompt_token_ids, out.token_ids) == \
        (rid, (1, 2), (5,))
    assert out.finish_reason is None and not out.finished
    assert out.metrics.ttft is not None and out.metrics.e2e_latency is None
    sch.record_token(0, 6)
    out = req.output()
    assert out.finished and out.finish_reason == "length"
    assert out.metrics.e2e_latency >= 0


# ---------------------------------------------------------------------------
# engine-level: sampling lanes through the pooled decode step
# ---------------------------------------------------------------------------

def _setup(seed=0, b=2, s=16, kv_tail=16):
    cfg = get_config("qwen3-0.6b").reduced()
    cfg = dataclasses.replace(cfg, kv_k_sparsity=0.0, kv_v_sparsity=0.0,
                              kv_tail=kv_tail)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jnp.asarray(np.random.default_rng(seed).integers(
        0, cfg.vocab, (b, s)), jnp.int32)
    return cfg, params, toks


@pytest.fixture(scope="module")
def engine_env():
    cfg, params, toks = _setup()
    eng = ContinuousEngine(params, cfg, slots=2, max_tokens=128, bs=16)
    return cfg, params, toks, eng


def test_mixed_params_batch_zero_retraces(engine_env):
    """Greedy + temperature/top-k/top-p requests in one pool: completes,
    differs from greedy where expected, and adds ZERO jit traces after
    warmup — heterogeneous SamplingParams are data, not shapes."""
    cfg, params, toks, eng = engine_env
    # warmup wave touches every compiled path (incl. a sampled lane)
    eng.submit(toks[0], SamplingParams(max_new_tokens=20))
    eng.submit(toks[1], SamplingParams(temperature=0.9, top_k=8, top_p=0.9,
                                       seed=0, max_new_tokens=20))
    eng.run()
    warm = eng.trace_counts()
    assert warm["decode"] == 1 and warm["set_lane"] == 1

    grid = [SamplingParams(max_new_tokens=12),
            SamplingParams(temperature=0.7, seed=1, max_new_tokens=12),
            SamplingParams(temperature=1.3, top_k=5, seed=2,
                           max_new_tokens=12),
            SamplingParams(temperature=0.5, top_p=0.8, seed=3,
                           max_new_tokens=12)]
    rids = [eng.submit(toks[i % 2], sp) for i, sp in enumerate(grid)]
    res = eng.run()
    assert all(len(res[r].token_ids) == 12 for r in rids)
    assert eng.trace_counts() == warm, \
        f"sampling lanes retraced: {warm} -> {eng.trace_counts()}"
    # the greedy and sampled streams over the same prompt diverge
    assert res[rids[0]].token_ids != res[rids[1]].token_ids


def test_seeded_sampling_slot_independent(engine_env):
    """Same request, different slot => same tokens: the RNG lane seeds from
    the request, never the slot, and slots are numerically independent."""
    cfg, params, toks, eng = engine_env
    sp = SamplingParams(temperature=0.8, top_k=8, seed=11, max_new_tokens=10)

    r1 = eng.submit(toks[0], sp)
    first = eng.run()[r1]
    assert eng.scheduler.finished[r1].slot == 0

    # occupy slot 0 with a longer filler, then resubmit the probe -> slot 1
    eng.submit(toks[1], SamplingParams(max_new_tokens=24))
    eng.step()
    r2 = eng.submit(toks[0], sp)
    res = eng.run()
    assert eng.scheduler.finished[r2].slot == 1
    assert res[r2].token_ids == first.token_ids


def test_temperature0_lane_equals_legacy_greedy(engine_env):
    """The acceptance bar: SamplingParams(temperature=0) through the
    continuous engine is token-identical to the legacy greedy engine."""
    cfg, params, toks, eng = engine_env
    legacy = Engine(params, cfg, kv_mode="sparse")
    sp = SamplingParams(temperature=0.0, max_new_tokens=24)
    out_leg, _ = legacy.generate({"tokens": toks}, sp)
    out = eng.generate_batch(toks, sp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_leg))


def test_sampled_continuous_matches_legacy_same_seed(engine_env):
    """Same seed + same params => the continuous engine's sampled stream
    matches the legacy engine's (both sample one split per token from
    PRNGKey(seed), and the logits agree)."""
    cfg, params, toks, eng = engine_env
    sp = SamplingParams(temperature=0.7, seed=3, max_new_tokens=16)
    legacy = Engine(params, cfg, kv_mode="sparse")
    out_leg, _ = legacy.generate({"tokens": toks}, sp)
    out = eng.generate_batch(toks, sp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_leg))


def test_engine_stop_sequence_and_eos(engine_env):
    cfg, params, toks, eng = engine_env
    greedy = [int(t) for t in np.asarray(
        eng.generate_batch(toks[:1], SamplingParams(max_new_tokens=8)))[0]]

    # stop on a 2-token sequence the greedy stream is known to produce
    sp = SamplingParams(max_new_tokens=8, stop_ids=(tuple(greedy[2:4]),))
    rid = eng.submit(toks[0], sp)
    out = eng.run()[rid]
    assert list(out.token_ids) == greedy[:4]
    assert out.finish_reason == "stop"

    # eos_id finishes early too
    rid = eng.submit(toks[0], SamplingParams(max_new_tokens=8,
                                             eos_id=greedy[1]))
    out = eng.run()[rid]
    assert list(out.token_ids) == greedy[:2]
    assert out.finish_reason == "stop"


def test_streaming_iterator_and_callback(engine_env):
    cfg, params, toks, eng = engine_env
    got_cb = []
    r1 = eng.submit(toks[0], SamplingParams(max_new_tokens=6),
                    on_token=got_cb.append)
    r2 = eng.submit(toks[1], SamplingParams(temperature=0.6, seed=4,
                                            max_new_tokens=4))
    seen = {r1: [], r2: []}
    for snap in eng.stream():
        assert snap.request_id in seen
        prev = seen[snap.request_id]
        # each snapshot extends the previous by exactly one token
        assert len(snap.token_ids) == len(prev) + 1
        assert list(snap.token_ids[:len(prev)]) == prev
        seen[snap.request_id] = list(snap.token_ids)
    assert len(seen[r1]) == 6 and len(seen[r2]) == 4
    assert eng.scheduler.done()
    # callback saw the same snapshots as the iterator, in order
    assert [len(s.token_ids) for s in got_cb] == [1, 2, 3, 4, 5, 6]
    assert got_cb[-1].finished and got_cb[-1].finish_reason == "length"
    assert list(got_cb[-1].token_ids) == seen[r1]
    assert got_cb[-1].metrics.ttft is not None


def test_legacy_engine_rejects_stop_params(engine_env):
    """The lockstep one-shot engine cannot honor eos/stop; it must refuse
    rather than silently decode past them."""
    cfg, params, toks = engine_env[:3]
    legacy = Engine(params, cfg, kv_mode="sparse")
    for bad in (SamplingParams(eos_id=2), SamplingParams(stop_ids=(5,))):
        with pytest.raises(ValueError, match="ContinuousEngine"):
            legacy.generate({"tokens": toks}, bad)


def test_run_returns_request_outputs(engine_env):
    cfg, params, toks, eng = engine_env
    rid = eng.submit(toks[0], SamplingParams(max_new_tokens=3))
    out = eng.run()
    assert set(out) >= {rid}
    o = out[rid]
    assert o.finished and len(o.token_ids) == 3
    assert o.prompt_token_ids == tuple(int(t) for t in np.asarray(toks[0]))
    assert o.metrics.e2e_latency >= o.metrics.ttft >= 0


def test_request_output_logprobs_lane(engine_env):
    """Every emitted token carries its chosen-token logprob out of the
    jitted sampler: one per token, finite, <= 0, and deterministic for a
    greedy request resubmitted through the (recycled) pool."""
    cfg, params, toks, eng = engine_env
    sp = SamplingParams(max_new_tokens=6)
    rid = eng.submit(toks[0], sp)
    o1 = eng.run()[rid]
    assert len(o1.logprobs) == len(o1.token_ids) == 6
    lp1 = np.asarray(o1.logprobs, np.float64)
    assert np.isfinite(lp1).all() and (lp1 <= 0).all()

    rid2 = eng.submit(toks[0], sp)                 # same prompt again
    o2 = eng.run()[rid2]
    assert o2.token_ids == o1.token_ids
    np.testing.assert_allclose(np.asarray(o2.logprobs, np.float64), lp1)

    # sampled lanes carry logprobs too
    rid3 = eng.submit(toks[1], SamplingParams(temperature=0.8, seed=5,
                                              max_new_tokens=4))
    o3 = eng.run()[rid3]
    assert len(o3.logprobs) == 4
    assert all(lp is not None and lp <= 0 for lp in o3.logprobs)

"""Fused prefix+tail flash-decode: parity and serving acceptance.

The acceptance bar for the one-kernel decode redesign:

* the fused kernel (interpret mode) matches BOTH the fused-semantics
  oracle and the legacy two-pass partial+merge oracle across the pooled
  edge grid — ``tail_len in {0, 1, T}``, ``prefix_len = 0`` (an empty
  prefix must simply contribute nothing), all-inactive ``slot_mask``, and
  mixed per-slot lengths — with poisoned storage beyond each slot's valid
  lengths (masking bugs show up as parity breaks, not luck);
* the continuous engine riding the fused kernel still jits its decode
  exactly once across refreezes and admissions/evictions, and its greedy
  tokens match the XLA-backend engine token for token.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.sparse_kv import freeze_chunk_blocks, pooled_view
from repro.distributed import NULL_CTX
from repro.kernels import ops, ref
from repro.models import lm
from repro.serving import (CachePool, ContinuousEngine, SamplingParams,
                           stable_trace_counts)


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


def _pooled_case(b=4, hkv=2, g=2, d=32, sb=4, bs=16, t=16,
                 ks=0.3, vs=0.5, seed=0):
    """Pool-layout compressed prefix + dense tail ring + query."""
    k = _rand((b, hkv, sb * bs, d), seed)
    v = _rand((b, hkv, sb * bs, d), seed + 1)
    cap = bs * d                                  # full capacity: no drops
    k_bm, k_vl, v_bm, v_vl = freeze_chunk_blocks(k, v, ks, vs, bs, cap, cap)
    k_sp = pooled_view(k_bm, k_vl, bs, d)
    v_sp = pooled_view(v_bm, v_vl, bs, d)
    k_tail = _rand((b, hkv, t, d), seed + 2)
    v_tail = _rand((b, hkv, t, d), seed + 3)
    q = _rand((b, hkv * g, d), seed + 4)
    return q, k_sp, v_sp, k_tail, v_tail


def _poison_tail(tail, tail_len):
    """Blow up every out-of-range tail token: if validity masking leaks,
    parity against the oracles breaks loudly instead of passing by luck."""
    t = tail.shape[2]
    dead = jnp.arange(t)[None, None, :, None] >= \
        jnp.asarray(tail_len)[:, None, None, None]
    return jnp.where(dead, 50.0, tail)


EDGE_GRID = [
    # (prefix_blocks per slot, tail_len per slot)   sb=4, t=16, b=4
    pytest.param([4, 4, 4, 4], [0, 0, 0, 0], id="empty_tail"),
    pytest.param([4, 4, 4, 4], [1, 1, 1, 1], id="one_token_tail"),
    pytest.param([4, 4, 4, 4], [16, 16, 16, 16], id="full_tail"),
    pytest.param([0, 0, 0, 0], [7, 16, 1, 9], id="empty_prefix"),
    pytest.param([0, 0, 0, 0], [0, 0, 0, 0], id="all_empty"),
    pytest.param([0, 4, 2, 1], [0, 1, 16, 9], id="mixed_lengths"),
]


@pytest.mark.parametrize("ks,vs", [(0.0, 0.0), (0.3, 0.5)])
@pytest.mark.parametrize("prefix_blocks,tail_len", EDGE_GRID)
def test_fused_kernel_edge_grid(prefix_blocks, tail_len, ks, vs):
    bs, d, hkv, g = 16, 32, 2, 2
    q, k_sp, v_sp, k_tail, v_tail = _pooled_case(bs=bs, d=d, hkv=hkv, g=g,
                                                 ks=ks, vs=vs)
    tl = jnp.asarray(tail_len, jnp.int32)
    pl_ = jnp.asarray(prefix_blocks, jnp.int32) * bs
    k_tail = _poison_tail(k_tail, tl)
    v_tail = _poison_tail(v_tail, tl)
    sm = 1.0 / d ** 0.5

    with ops.backend("interpret"):
        o_kernel = ops.sparse_decode_attention(
            q, k_sp, v_sp, hkv, sm, k_tail, v_tail, tl, prefix_len=pl_)
    o_fused = ref.sparse_decode_attention_fused_ref(
        q, k_sp, v_sp, sm, k_tail, v_tail, tl, prefix_len=pl_)
    o_merge = ref.sparse_decode_attention_ref(
        q, k_sp, v_sp, sm, k_tail, v_tail, tl, prefix_len=pl_)

    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_fused),
                               rtol=1e-4, atol=1e-4)
    if not np.asarray(tl).any() and not np.asarray(pl_).any():
        # fully-empty slots: the two-pass oracle's merge floor leaves ~0,
        # the fused semantics are exactly 0 — both ignorable
        np.testing.assert_allclose(np.asarray(o_merge), 0.0, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(o_kernel), 0.0)
    else:
        np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_merge),
                                   rtol=1e-4, atol=1e-4)


def test_fused_kernel_unaligned_tail_ring_is_padded():
    """A tail ring that is not a whole number of (bs,)-panels (legacy
    one-shot cache geometry) is zero-padded by the dispatcher; the padding
    must stay masked."""
    bs, d, hkv, g = 16, 32, 2, 2
    q, k_sp, v_sp, _, _ = _pooled_case(bs=bs, d=d, hkv=hkv, g=g)
    t = 11                                        # < bs and not a multiple
    k_tail = _rand((4, hkv, t, d), 50) + 10.0     # large: leaks are loud
    v_tail = _rand((4, hkv, t, d), 51)
    tl = jnp.asarray([0, 1, 11, 5], jnp.int32)
    sm = 1.0 / d ** 0.5
    with ops.backend("interpret"):
        o_kernel = ops.sparse_decode_attention(
            q, k_sp, v_sp, hkv, sm, k_tail, v_tail, tl)
    o_ref = ref.sparse_decode_attention_ref(q, k_sp, v_sp, sm,
                                            k_tail, v_tail, tl)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


def test_no_tail_uses_prefix_partial_and_matches():
    """Without a tail the dispatcher keeps the prefix-partial kernel; a
    fully-skipped prefix must return zeros (nothing to attend to)."""
    bs, d, hkv, g = 16, 32, 2, 2
    q, k_sp, v_sp, _, _ = _pooled_case(bs=bs, d=d, hkv=hkv, g=g)
    pl_ = jnp.asarray([0, 64, 32, 16], jnp.int32)
    sm = 1.0 / d ** 0.5
    with ops.backend("interpret"):
        o_kernel = ops.sparse_decode_attention(q, k_sp, v_sp, hkv, sm,
                                               prefix_len=pl_)
    o_ref = ref.sparse_decode_attention_ref(q, k_sp, v_sp, sm,
                                            prefix_len=pl_)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(o_kernel)[0], 0.0)


# ---------------------------------------------------------------------------
# serving-level: the fused kernel under the continuous engine
# ---------------------------------------------------------------------------

def _setup(seed=0, b=2, s=16, kv_tail=16, dtype=None):
    cfg = get_config("qwen3-0.6b").reduced()
    kw = dict(kv_k_sparsity=0.0, kv_v_sparsity=0.0, kv_tail=kv_tail)
    if dtype is not None:
        kw.update(compute_dtype=dtype, param_dtype=dtype)
    cfg = dataclasses.replace(cfg, **kw)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jnp.asarray(np.random.default_rng(seed).integers(
        0, cfg.vocab, (b, s)), jnp.int32)
    return cfg, params, toks


def _two_pass_sparse_decode_attention(q, k_sp, v_sp, hkv, sm_scale,
                                      k_tail=None, v_tail=None,
                                      tail_len=None, prefix_len=None):
    """The PRE-FUSION dispatch, reconstructed: prefix-partial Pallas kernel
    + XLA-side grouped tail attention + lse merge.  The fused engine must
    be token-identical to an engine decoding through this."""
    from repro.kernels.sparse_attention import sparse_decode_attention_pallas
    if q.ndim == 4:          # unified panel forward at Q == 1: a decode tick
        assert q.shape[1] == 1, q.shape
        return _two_pass_sparse_decode_attention(
            q[:, 0], k_sp, v_sp, hkv, sm_scale, k_tail, v_tail,
            tail_len, prefix_len)[:, None]
    interp = ops._pallas()
    assert interp is not None
    b, hq, d = q.shape
    g = hq // hkv
    bs = k_sp.block[0]
    words = k_sp.bitmap.shape[-1]
    sb = k_sp.bitmap.shape[2]
    qg = q.reshape(b, hkv, g, d)
    kbm = k_sp.bitmap.reshape(b, hkv, sb, words)
    kvv = k_sp.values.reshape(b, hkv, sb, k_sp.capacity)
    vbm = v_sp.bitmap.reshape(b, hkv, sb, words)
    vvv = v_sp.values.reshape(b, hkv, sb, v_sp.capacity)
    n_blocks = None
    if prefix_len is not None:
        n_blocks = jnp.broadcast_to(
            jnp.asarray(prefix_len, jnp.int32) // bs, (b,))
    o, lse = sparse_decode_attention_pallas(
        qg, kbm, kvv, vbm, vvv, bs=bs, sm_scale=sm_scale, interpret=interp,
        n_blocks=n_blocks)
    o = o.reshape(b, hq, d)
    lse = lse.reshape(b, hq)
    if prefix_len is not None:
        empty_p = jnp.broadcast_to(jnp.atleast_1d(
            jnp.asarray(prefix_len)) <= 0, (b,))
        lse = jnp.where(empty_p[:, None], -1e30, lse)
    if k_tail is not None and k_tail.shape[2] > 0:
        t = k_tail.shape[2]
        valid = ref._len_valid(t, tail_len if tail_len is not None else t, b)
        o2, lse2 = ref.gqa_partial_ref(qg, k_tail, v_tail, sm_scale, valid)
        o2 = o2.reshape(b, hq, d)
        lse2 = lse2.reshape(b, hq)
        empty = ~jnp.any(valid, axis=-1)
        lse2 = jnp.where(empty[:, None], -jnp.inf, lse2)
        lse2 = jnp.where(jnp.isfinite(lse2), lse2, lse.min() - 60.0)
        o, _ = ref._merge_attn(o, lse, o2, lse2)
    return o.astype(q.dtype)


def test_all_inactive_slot_mask_is_passthrough():
    """A decode tick (the panel forward at Q == 1) with every slot masked
    off must leave the pooled state bit-identical (lengths and cache
    leaves) through the fused kernel path."""
    cfg, params, toks = _setup()
    pool = CachePool.build(cfg, slots=2, max_tokens=64, bs=16)
    state = pool.init_state()
    rng = np.random.default_rng(3)
    for leaf in state["layers"].values():
        kv = leaf["kv"]
        kv["k_tail"] = jnp.asarray(rng.normal(
            size=kv["k_tail"].shape)).astype(kv["k_tail"].dtype)
        kv["v_tail"] = kv["k_tail"] * 0.25
    state["tail_len"] = jnp.asarray([3, 0], jnp.int32)
    state["pos"] = jnp.asarray([3, 0], jnp.int32)
    mask = jnp.zeros((2,), bool)
    with ops.backend("interpret"):
        logits, out = jax.jit(
            lambda p, st, t, m: lm.forward_panel_pooled(
                p, st, t, m, cfg, NULL_CTX, pool.bs))(
                    params, state, toks[:, :1], mask)
    logits = logits[:, 0]
    assert logits.shape == (2, cfg.vocab)
    for a, b_ in zip(jax.tree_util.tree_leaves(state),
                     jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_fused_engine_zero_retrace_and_token_parity():
    """The engine on the fused Pallas path (interpret mode): decode jits
    exactly once across refreeze + admission/eviction waves, and greedy
    tokens are IDENTICAL to an engine decoding through the reconstructed
    two-pass partial+merge dispatch on the same backend (f32 compute so
    the comparison is numerics-matched, not near-tie roulette)."""
    cfg, params, toks = _setup(dtype="float32")
    sp = SamplingParams(max_new_tokens=24)

    def _wave2(eng):
        # staggered second wave: 3 requests through 2 slots (admission
        # from the queue + evictions), unaligned prompts -> tail remainder
        rids = [eng.submit(toks[i % 2][:9 + 4 * i],
                           SamplingParams(max_new_tokens=20 - 2 * i))
                for i in range(3)]
        res = eng.run()
        return [res[r].token_ids for r in rids]

    def _run_waves(eng):
        out = eng.generate_batch(toks, sp)        # 24 > kv_tail: refreezes
        return out, _wave2(eng)

    with ops.backend("interpret"):
        eng = ContinuousEngine(params, cfg, slots=2, max_tokens=96, bs=16)
        out_fused = eng.generate_batch(toks, sp)
        warm = eng.trace_counts()
        assert warm["decode"] == 1
        wave2_fused = _wave2(eng)
        after = eng.trace_counts()
        # new prompt lengths legitimately add prefill-chunk traces (one
        # per distinct length); everything else must stay flat
        assert (stable_trace_counts(after) == stable_trace_counts(warm)
                and after["decode"] == 1), \
            f"fused decode retraced: {warm} -> {after}"

        orig = ops.sparse_decode_attention
        ops.sparse_decode_attention = _two_pass_sparse_decode_attention
        try:
            eng2 = ContinuousEngine(params, cfg, slots=2, max_tokens=96,
                                    bs=16)
            out_two, wave2_two = _run_waves(eng2)
        finally:
            ops.sparse_decode_attention = orig

    np.testing.assert_array_equal(np.asarray(out_fused), np.asarray(out_two))
    assert wave2_fused == wave2_two

"""Training infrastructure: determinism, restart equivalence, microbatch
accumulation, checkpoint manager behaviour, data pipeline, optimizer."""
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, host_batch
from repro.distributed import NULL_CTX
from repro.models import lm
from repro.optim import (OptConfig, init_opt_state, adamw_step, lr_schedule,
                         global_norm)
from repro.train import make_train_step
from repro.launch.train import train_loop


CFG = get_config("qwen3-0.6b").reduced()
DC = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=4)


def test_loss_decreases():
    _, _, losses = train_loop(CFG, 8, DC)
    assert losses[-1] < losses[0]


def test_restart_equivalent(tmp_path):
    """train 6 straight == train 3, checkpoint, restore, train 3 more
    (same optimizer schedule across runs)."""
    optc = OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=6)
    _, _, straight = train_loop(CFG, 6, DC, optc=optc)
    ck = CheckpointManager(str(tmp_path / "ck"))
    train_loop(CFG, 3, DC, ckpt=ck, ckpt_every=3, optc=optc)
    _, _, resumed = train_loop(CFG, 6, DC, ckpt=ck, optc=optc)
    np.testing.assert_allclose(straight[3:], resumed, rtol=1e-4, atol=1e-5)


def test_microbatch_equals_full_batch():
    params = lm.init_params(CFG, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = {k: jnp.asarray(v) for k, v in host_batch(DC, 0).items()}
    optc = OptConfig(peak_lr=1e-3)
    s_full = jax.jit(make_train_step(CFG, NULL_CTX, optc))
    s_micro = jax.jit(make_train_step(CFG, NULL_CTX, optc, microbatch=2))
    p1, o1, m1 = s_full(params, opt, batch)
    p2, o2, m2 = s_micro(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    l1 = jax.tree_util.tree_leaves(o1["master"])[0]
    l2 = jax.tree_util.tree_leaves(o2["master"])[0]
    # bf16 forward/backward: accumulation-order noise ~1e-5 on the master
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-2, atol=5e-5)


def test_checkpoint_keep_k_and_atomicity(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        ck.save(s, state, blocking=True)
    assert ck.steps() == [3, 4]
    # a stale tmp dir is garbage-collected on next init
    os.makedirs(tmp_path / ".tmp-99", exist_ok=True)
    CheckpointManager(str(tmp_path))
    assert not (tmp_path / ".tmp-99").exists()


def test_checkpoint_elastic_dtype_roundtrip(tmp_path):
    ck = CheckpointManager(str(tmp_path))
    state = {"bf16": jnp.ones((3,), jnp.bfloat16) * 1.5,
             "f32": jnp.ones((3,), jnp.float32) * 2.5,
             "i32": jnp.arange(3, dtype=jnp.int32)}
    ck.save(7, state, blocking=True)
    out, man = ck.restore(7, state)
    assert man["step"] == 7
    for k in state:
        assert out[k].dtype == state[k].dtype
        np.testing.assert_array_equal(np.asarray(out[k], np.float32),
                                      np.asarray(state[k], np.float32))


def test_data_determinism_and_elasticity():
    b1 = host_batch(DC, 5)
    b2 = host_batch(DC, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = host_batch(DC, 6)
    assert np.any(b1["tokens"] != b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # elastic: per-example determinism regardless of batch slicing
    from repro.data.pipeline import _example_tokens
    full = _example_tokens(DC, 5, np.arange(4))
    half = _example_tokens(DC, 5, np.arange(2, 4))
    np.testing.assert_array_equal(full[2:], half)


def test_lr_schedule_shape():
    optc = OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100)
    lrs = [float(lr_schedule(optc, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1e-3) < 1e-9
    assert lrs[3] < lrs[2] and lrs[4] <= lrs[3]
    assert lrs[4] >= optc.peak_lr * optc.end_lr_frac - 1e-9


def test_adamw_clip_and_decay():
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = init_opt_state(params)
    grads = {"w": jnp.full((4,), 100.0)}   # huge -> clipped
    optc = OptConfig(peak_lr=1e-2, warmup_steps=1, decay_steps=10,
                     clip_norm=1.0, weight_decay=0.0)
    p2, o2, mets = adamw_step(grads, opt, optc, params)
    assert float(mets["grad_norm"]) == pytest.approx(200.0)
    assert np.all(np.asarray(p2["w"]) < 1.0)   # moved against gradient
    assert np.all(np.isfinite(np.asarray(o2["m"]["w"])))


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((4,))}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(7.0))

"""Best-effort TPU (Mosaic) lowering smoke: the Pallas kernels should lower
to StableHLO for the TPU platform even without a TPU runtime.  Skipped when
this jaxlib build cannot produce TPU lowerings on a CPU-only host."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pack, make_mask
from repro.kernels.sparse_matmul import sparse_matmul_pallas


def _try_tpu_lowering():
    w = jnp.asarray(np.random.default_rng(0).normal(
        size=(256, 256)).astype(np.float32))
    mask = make_mask(w, 0.5, "balanced", (128, 128))
    sw = pack(w, mask, (128, 128))
    x = jnp.ones((16, 256), jnp.float32)

    def f(x, bitmap, values):
        from repro.core.sparse_format import BlockSparseWeight
        sw2 = BlockSparseWeight(bitmap, values, None, sw.shape, sw.block)
        return sparse_matmul_pallas(x, sw2, tm=16, interpret=False)

    traced = jax.jit(f).trace(x, sw.bitmap, sw.values)
    return traced.lower(lowering_platforms=("tpu",))


def test_sparse_matmul_lowers_for_tpu():
    try:
        lowered = _try_tpu_lowering()
    except Exception as e:           # no Mosaic pipeline on this host
        pytest.skip(f"TPU lowering unavailable on CPU host: "
                    f"{type(e).__name__}")
    txt = lowered.as_text()
    assert "custom_call" in txt or "tpu_custom_call" in txt
